//! The `Controller` trait and its three shipped implementations.
//!
//! A controller is a pure function of its own state and the per-tick
//! observation — it draws no RNG and sees no wall-clock, so a controlled
//! run is reproducible from `(spec, workload, seed)` alone.

/// A feedback controller over one capacity domain. Called once per
/// `Event::ControlTick` with the observed utilization signal and the
/// current capacity; returns the requested capacity delta (positive =
/// scale out). The caller clamps the result into the domain's
/// `[min, max]` bounds — cooldown/step bookkeeping inside the controller
/// is based on the *requested* move, not the clamped one.
pub trait Controller: Send {
    /// The signal value the controller steers toward (used for error
    /// reporting and settling-band analysis).
    fn setpoint(&self) -> f64;

    /// Observe `observed` (utilization signal) at simulated time `now`
    /// with `capacity` units currently provisioned; return the requested
    /// capacity delta.
    fn actuate(&mut self, now: f64, observed: f64, capacity: u64) -> i64;
}

/// Hold a target utilization ratio: each tick computes the capacity that
/// would bring the observed signal back to `target`
/// (`ceil(capacity * observed / target)`), moves at most `max_step` units,
/// and gates scale-in behind a cooldown since the last scale activity so
/// transient dips don't flap the fleet. `max_step == 0` is inert.
pub struct TargetTracking {
    target: f64,
    cooldown: f64,
    max_step: u32,
    last_scale: f64,
}

impl TargetTracking {
    /// Build a target-tracking controller steering toward `target`
    /// utilization, with `cooldown` simulated seconds between scale-ins
    /// and at most `max_step` capacity units moved per tick.
    pub fn new(target: f64, cooldown: f64, max_step: u32) -> TargetTracking {
        TargetTracking { target, cooldown, max_step, last_scale: f64::NEG_INFINITY }
    }
}

impl Controller for TargetTracking {
    fn setpoint(&self) -> f64 {
        self.target
    }

    fn actuate(&mut self, now: f64, observed: f64, capacity: u64) -> i64 {
        let cap = capacity.max(1) as f64;
        let desired = (cap * observed / self.target).ceil();
        let step = i64::from(self.max_step);
        let mut delta = (desired as i64 - capacity as i64).clamp(-step, step);
        if delta < 0 && now - self.last_scale < self.cooldown {
            delta = 0; // scale-in cooldown: hold until the fleet settles
        }
        if delta != 0 {
            self.last_scale = now;
        }
        delta
    }
}

/// Classic PID over the utilization error (`observed - target`): the
/// normalized output `kp*e + ki*∫e + kd*de/dt` is clamped to `[-1, 1]`
/// and scaled by the current capacity, so a saturated controller at most
/// doubles or halves the fleet per tick. Anti-windup clamps the integral
/// so the I-term alone cannot exceed the output clamp. All gains 0 is
/// inert.
pub struct Pid {
    kp: f64,
    ki: f64,
    kd: f64,
    target: f64,
    integral: f64,
    prev_error: Option<f64>,
    prev_t: f64,
}

impl Pid {
    /// Build a PID controller with the given gains steering toward
    /// `target` utilization.
    pub fn new(kp: f64, ki: f64, kd: f64, target: f64) -> Pid {
        Pid { kp, ki, kd, target, integral: 0.0, prev_error: None, prev_t: 0.0 }
    }

    fn windup_limit(&self) -> f64 {
        // Keep |ki * integral| <= 1 (the output clamp); with ki == 0 the
        // integral is pinned at 0 so it cannot accumulate unobserved.
        if self.ki > 0.0 { 1.0 / self.ki } else { 0.0 }
    }
}

impl Controller for Pid {
    fn setpoint(&self) -> f64 {
        self.target
    }

    fn actuate(&mut self, now: f64, observed: f64, capacity: u64) -> i64 {
        let error = observed - self.target;
        let dt = (now - self.prev_t).max(0.0);
        let limit = self.windup_limit();
        self.integral = (self.integral + error * dt).clamp(-limit, limit);
        let derivative = match self.prev_error {
            Some(prev) if dt > 0.0 => (error - prev) / dt,
            _ => 0.0,
        };
        self.prev_error = Some(error);
        self.prev_t = now;
        let output = (self.kp * error + self.ki * self.integral + self.kd * derivative)
            .clamp(-1.0, 1.0);
        (output * capacity.max(1) as f64).round() as i64
    }
}

/// Threshold ladder (the AWS-style baseline): above `high` add `step`
/// units, below `low` remove `step`, otherwise hold. No memory, no
/// cooldown — deliberately the simplest (and most oscillation-prone)
/// policy, which is exactly what makes it a useful comparison baseline.
pub struct StepPolicy {
    low: f64,
    high: f64,
    step: u32,
}

impl StepPolicy {
    /// Build a step policy holding the signal inside `[low, high]`,
    /// moving `step` capacity units per breach.
    pub fn new(low: f64, high: f64, step: u32) -> StepPolicy {
        StepPolicy { low, high, step }
    }
}

impl Controller for StepPolicy {
    fn setpoint(&self) -> f64 {
        (self.low + self.high) / 2.0
    }

    fn actuate(&mut self, _now: f64, observed: f64, _capacity: u64) -> i64 {
        if observed > self.high {
            i64::from(self.step)
        } else if observed < self.low {
            -i64::from(self.step)
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_tracking_steps_toward_target_with_limits() {
        let mut c = TargetTracking::new(0.5, 60.0, 2);
        // observed 1.0 at cap 10 -> desired 20, clamped to +2.
        assert_eq!(c.actuate(10.0, 1.0, 10), 2);
        // observed 0.1 at cap 10 -> desired 2, clamped to -2, but the
        // scale at t=10 started the cooldown: held at t=20...
        assert_eq!(c.actuate(20.0, 0.1, 12), 0);
        // ...and released once the cooldown has elapsed.
        assert_eq!(c.actuate(80.0, 0.1, 12), -2);
        // On target: hold (desired == capacity).
        assert_eq!(c.actuate(200.0, 0.5, 10), 0);
    }

    #[test]
    fn target_tracking_recovers_from_zero_capacity() {
        let mut c = TargetTracking::new(0.7, 0.0, 4);
        // capacity clamps to >=1 in the desired computation, so a fully
        // loaded signal still requests scale-out instead of sticking at 0.
        assert!(c.actuate(10.0, 3.0, 0) > 0);
    }

    #[test]
    fn pid_output_is_clamped_and_anti_windup_bounds_integral() {
        let mut c = Pid::new(10.0, 0.5, 0.0, 0.5);
        // Huge proportional error: output clamps to +1.0 * capacity.
        assert_eq!(c.actuate(10.0, 10.0, 8), 8);
        // Long saturation cannot wind the integral past 1/ki.
        for i in 1..100 {
            c.actuate(10.0 + i as f64 * 10.0, 10.0, 8);
        }
        assert!(c.integral <= 1.0 / 0.5 + 1e-9);
        // Error flips sign: the bounded integral lets the output follow.
        assert!(c.actuate(2000.0, 0.0, 8) < 0);
    }

    #[test]
    fn pid_zero_gains_is_inert() {
        let mut c = Pid::new(0.0, 0.0, 0.0, 0.7);
        for i in 1..50 {
            assert_eq!(c.actuate(i as f64 * 5.0, (i % 3) as f64, 16), 0);
        }
    }

    #[test]
    fn step_policy_ladder() {
        let mut c = StepPolicy::new(0.3, 0.8, 3);
        assert_eq!(c.actuate(10.0, 0.9, 5), 3);
        assert_eq!(c.actuate(20.0, 0.1, 5), -3);
        assert_eq!(c.actuate(30.0, 0.5, 5), 0);
        assert!((c.setpoint() - 0.55).abs() < 1e-12);
    }
}
