//! One invoker host: finite memory/CPU capacity with per-container
//! resource accounting and time-weighted utilization counters.
//!
//! A [`Host`] is pure bookkeeping — it draws no RNG and schedules no
//! events, so the cluster layer composes with the engines' bit-identity
//! contracts (DESIGN.md §Cluster). Capacities are `f64` so a host can be
//! unbounded (`f64::INFINITY`) for equivalence tests; allocation uses a
//! small epsilon so long add/release chains cannot reject a container
//! that nominally fits.

/// Slack for floating-point capacity comparisons (MB / cores).
const EPS: f64 = 1e-9;

/// One invoker host with finite memory and CPU capacity.
#[derive(Debug, Clone)]
pub struct Host {
    memory_mb: f64,
    cpus: f64,
    used_memory_mb: f64,
    used_cpus: f64,
    containers: u32,
    /// Cordoned hosts (an active drain window) accept no new placements;
    /// existing containers keep running and drain naturally.
    cordoned: bool,
    /// Containers ever placed on this host.
    placements: u64,
    /// Time integral of `used_memory_mb` (MB·s), advanced lazily on every
    /// allocation/release so idle events cost nothing.
    mem_mb_seconds: f64,
    last_advance: f64,
}

impl Host {
    /// A fresh, empty host with the given capacities.
    pub fn new(memory_mb: f64, cpus: f64) -> Host {
        Host {
            memory_mb,
            cpus,
            used_memory_mb: 0.0,
            used_cpus: 0.0,
            containers: 0,
            cordoned: false,
            placements: 0,
            mem_mb_seconds: 0.0,
            last_advance: 0.0,
        }
    }

    /// Memory capacity in MB.
    #[inline]
    pub fn memory_mb(&self) -> f64 {
        self.memory_mb
    }

    /// CPU capacity in cores.
    #[inline]
    pub fn cpus(&self) -> f64 {
        self.cpus
    }

    /// Remaining memory in MB.
    #[inline]
    pub fn free_memory_mb(&self) -> f64 {
        self.memory_mb - self.used_memory_mb
    }

    /// Remaining CPU capacity in cores.
    #[inline]
    pub fn free_cpus(&self) -> f64 {
        self.cpus - self.used_cpus
    }

    /// Containers currently resident.
    #[inline]
    pub fn containers(&self) -> u32 {
        self.containers
    }

    /// Containers ever placed here.
    #[inline]
    pub fn placements(&self) -> u64 {
        self.placements
    }

    /// Whether the host is cordoned (active drain window).
    #[inline]
    pub fn is_cordoned(&self) -> bool {
        self.cordoned
    }

    /// Cordon or uncordon the host (drain-window boundaries).
    pub fn set_cordoned(&mut self, cordoned: bool) {
        self.cordoned = cordoned;
    }

    /// Whether a container of the given footprint can be placed now.
    /// Cordoned hosts accept nothing.
    #[inline]
    pub fn fits(&self, memory_mb: f64, cpus: f64) -> bool {
        !self.cordoned
            && self.used_memory_mb + memory_mb <= self.memory_mb + EPS
            && self.used_cpus + cpus <= self.cpus + EPS
    }

    /// Charge one container's footprint (caller checked [`fits`](Self::fits)).
    pub fn allocate(&mut self, memory_mb: f64, cpus: f64, now: f64) {
        self.advance(now);
        self.used_memory_mb += memory_mb;
        self.used_cpus += cpus;
        self.containers += 1;
        self.placements += 1;
    }

    /// Release one container's footprint (clamped at zero so accounting
    /// drift can never go negative).
    pub fn release(&mut self, memory_mb: f64, cpus: f64, now: f64) {
        self.advance(now);
        self.used_memory_mb = (self.used_memory_mb - memory_mb).max(0.0);
        self.used_cpus = (self.used_cpus - cpus).max(0.0);
        self.containers = self.containers.saturating_sub(1);
    }

    /// Instantaneous memory utilization in `[0, 1]` (0 for unbounded hosts).
    pub fn memory_utilization(&self) -> f64 {
        if self.memory_mb.is_finite() && self.memory_mb > 0.0 {
            self.used_memory_mb / self.memory_mb
        } else {
            0.0
        }
    }

    /// Advance the time-weighted accumulator to `now` (idempotent; called
    /// from every allocate/release and once at the horizon).
    pub fn advance(&mut self, now: f64) {
        if now > self.last_advance {
            self.mem_mb_seconds += self.used_memory_mb * (now - self.last_advance);
            self.last_advance = now;
        }
    }

    /// Time-averaged memory utilization over `[0, elapsed]` in `[0, 1]`
    /// (0 for unbounded hosts or a zero-length window). Call
    /// [`advance`](Self::advance) to the window end first.
    pub fn time_avg_memory_utilization(&self, elapsed: f64) -> f64 {
        if self.memory_mb.is_finite() && self.memory_mb > 0.0 && elapsed > 0.0 {
            self.mem_mb_seconds / (self.memory_mb * elapsed)
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_release_round_trip() {
        let mut h = Host::new(1024.0, 4.0);
        assert!(h.fits(512.0, 1.0));
        h.allocate(512.0, 1.0, 10.0);
        h.allocate(512.0, 1.0, 10.0);
        assert_eq!(h.containers(), 2);
        assert_eq!(h.placements(), 2);
        assert!(!h.fits(1.0, 1.0), "memory exhausted");
        h.release(512.0, 1.0, 20.0);
        assert!(h.fits(512.0, 1.0));
        assert_eq!(h.containers(), 1);
        assert!((h.free_memory_mb() - 512.0).abs() < 1e-6);
    }

    #[test]
    fn cpu_capacity_binds_independently() {
        let mut h = Host::new(1e9, 2.0);
        h.allocate(1.0, 1.0, 0.0);
        h.allocate(1.0, 1.0, 0.0);
        assert!(!h.fits(1.0, 1.0), "cpus exhausted before memory");
    }

    #[test]
    fn cordoned_host_rejects_everything() {
        let mut h = Host::new(1024.0, 4.0);
        h.set_cordoned(true);
        assert!(!h.fits(1.0, 0.0));
        h.set_cordoned(false);
        assert!(h.fits(1.0, 0.0));
    }

    #[test]
    fn unbounded_host_always_fits() {
        let h = Host::new(f64::INFINITY, f64::INFINITY);
        assert!(h.fits(1e12, 1e12));
        assert_eq!(h.memory_utilization(), 0.0);
        assert_eq!(h.time_avg_memory_utilization(100.0), 0.0);
    }

    #[test]
    fn time_weighted_utilization() {
        // 512 of 1024 MB held for 50 of 100 s -> 25% average.
        let mut h = Host::new(1024.0, 4.0);
        h.allocate(512.0, 1.0, 0.0);
        h.release(512.0, 1.0, 50.0);
        h.advance(100.0);
        assert!((h.time_avg_memory_utilization(100.0) - 0.25).abs() < 1e-12);
    }
}
