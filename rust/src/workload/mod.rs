//! Workload generation substrate: open-loop arrival generators (the
//! equivalent of the paper's `pacswg` Poisson load generator) and synthetic
//! Azure-style multi-function traces.

pub mod azure;
pub mod generator;

pub use azure::{FunctionProfile, SyntheticTrace};
pub use generator::{batch, deterministic, from_process, nonhomogeneous, poisson, Workload};
