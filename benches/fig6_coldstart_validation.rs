//! Bench: regenerate Fig. 6 — cold-start probability vs arrival rate,
//! simulation vs "experiment" (the platform emulator standing in for AWS
//! Lambda; DESIGN.md §3). The paper reports 12.75% average error with a
//! 10.14% experiment standard error.
#[path = "harness.rs"]
mod harness;

use simfaas::figures::{self, ValidationOpts};

fn main() {
    harness::header(
        "Fig 6",
        "P(cold) vs arrival rate: simulator prediction vs emulated platform",
        "sim tracks experiment; paper avg error 12.75% (experiment SE 10.14%)",
    );
    // NOTE: this testbed has a single CPU core; the emulator's threads
    // timeshare it, so validation is restricted to arrival rates whose
    // thread count the core can serve faithfully (see EXPERIMENTS.md).
    let quick = harness::quick();
    let rates: Vec<f64> =
        if quick { vec![0.25, 0.5, 1.0] } else { vec![0.25, 0.5, 0.75, 1.0] };
    let opts = ValidationOpts {
        emu_horizon: if quick { 6_000.0 } else { 30_000.0 },
        time_scale: 500.0,
        sim_horizon: 400_000.0,
        skip: 600.0,
        seed: 0xF16,
    };
    let (_, rows) = harness::bench("fig6/validation_sweep", 1, || {
        figures::validation_rows(&rates, &opts)
    });
    println!();
    println!("rate    sim p_cold%   emu p_cold%");
    for r in &rows {
        println!(
            "{:<7.2} {:>10.4}   {:>10.4}",
            r.rate,
            r.sim.cold_start_prob * 100.0,
            r.emu.cold_start_prob * 100.0
        );
    }
    let (e6, _, _) = figures::validation_errors(&rows);
    println!("avg % error (p_cold): {e6:.2}%   (paper: 12.75%)");
}
