"""Layer-1 Pallas kernel: tiled two-layer MLP forward.

This is the compute hot-spot of the emulated serverless function (an
ML-inference app). The kernel is written for the TPU memory hierarchy:

* The batch dimension is tiled with ``BLOCK_B`` rows per grid step; each
  grid step's activations live in VMEM.
* Weights (``w1``, ``w2``) use whole-array BlockSpecs: they fit in VMEM for
  the payload sizes we ship (<= 512x1024 f32 = 2 MiB) and are reused across
  every grid step, so HBM traffic is one weight read amortized over the
  batch — the standard inference-serving schedule.
* Matmuls contract over the feature axis with ``preferred_element_type=
  float32`` so the MXU accumulates in f32.
* Tile sizes are MXU/VPU-aligned: BLOCK_B is a multiple of 8 (f32 sublane),
  feature dims are multiples of 128 (lane).

VMEM footprint per grid step (defaults, f32):
  x tile   128x256  = 128 KiB
  w1       256x512  = 512 KiB
  h        128x512  = 256 KiB
  w2       512x128  = 256 KiB  (d_out padded to 128)
  out      128x128  =  64 KiB
  total ~= 1.2 MiB  << 16 MiB VMEM -> double-buffering headroom.

NOTE: lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; on a real TPU the same code lowers to Mosaic (see
DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block size over the batch dimension (8-sublane aligned).
BLOCK_B = 128


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One batch tile: o = relu(x @ w1 + b1) @ w2 + b2."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = o + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mlp_forward(x, w1, b1, w2, b2, *, block_b: int = BLOCK_B, interpret: bool = True):
    """Tiled MLP forward via ``pallas_call``.

    ``x`` rows must be a multiple of ``block_b`` (the AOT entry points pad
    the batch; `python/tests` sweeps non-multiples through the padded path).
    """
    batch, d_in = x.shape
    d_hidden = w1.shape[1]
    d_out = w2.shape[1]
    assert w1.shape == (d_in, d_hidden)
    assert b1.shape == (d_hidden,)
    assert w2.shape == (d_hidden, d_out)
    assert b2.shape == (d_out,)
    assert batch % block_b == 0, f"batch {batch} not a multiple of {block_b}"

    grid = (batch // block_b,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            # One batch tile per grid step.
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            # Weights/biases: whole array resident, reused across steps.
            pl.BlockSpec((d_in, d_hidden), lambda i: (0, 0)),
            pl.BlockSpec((d_hidden,), lambda i: (0,)),
            pl.BlockSpec((d_hidden, d_out), lambda i: (0, 0)),
            pl.BlockSpec((d_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


def mlp_forward_padded(x, w1, b1, w2, b2, *, block_b: int = BLOCK_B):
    """MLP forward for arbitrary batch sizes: pads to the tile size and
    slices the result back (the AOT model entry uses fixed shapes, but the
    tests exercise this wrapper to check padding correctness)."""
    batch = x.shape[0]
    padded = ((batch + block_b - 1) // block_b) * block_b
    if padded != batch:
        pad = jnp.zeros((padded - batch, x.shape[1]), x.dtype)
        x = jnp.concatenate([x, pad], axis=0)
    out = mlp_forward(x, w1, b1, w2, b2, block_b=block_b)
    return out[:batch]
