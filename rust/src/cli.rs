//! Minimal CLI argument parsing (no external crates in this environment).
//!
//! Supports `--flag value`, `--flag=value` and boolean `--flag` forms, a
//! positional subcommand, plus further positional operands (e.g. `simfaas
//! run <scenario.json>`). Unknown flags — and positionals the command
//! never consumed — are errors (catches typos in experiment scripts).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed arguments: subcommand + positional operands + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    /// Positional operands after the subcommand, in order.
    positionals: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were consumed by a getter (for unknown-flag detection).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
    /// How many leading positionals a getter consumed.
    positionals_seen: std::cell::Cell<usize>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, val) = if let Some((k, v)) = stripped.split_once('=') {
                    (k.to_string(), v.to_string())
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    (stripped.to_string(), it.next().unwrap())
                } else {
                    (stripped.to_string(), "true".to_string())
                };
                if args.flags.insert(key.clone(), val).is_some() {
                    bail!("duplicate flag --{key}");
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positionals.push(a);
            }
        }
        Ok(args)
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    /// Positional operand `idx` (0 = first after the subcommand). Like the
    /// flag getters, consuming marks it for [`check_unknown`](Self::check_unknown).
    pub fn positional(&self, idx: usize) -> Option<&str> {
        let watermark = self.positionals_seen.get().max(idx + 1);
        self.positionals_seen.set(watermark);
        self.positionals.get(idx).map(|s| s.as_str())
    }

    /// Number of positional operands parsed (does not mark them consumed —
    /// lets the dispatcher fail fast on operands a command cannot take,
    /// before any simulation runs).
    pub fn positional_count(&self) -> usize {
        self.positionals.len()
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(key, default as u64)? as usize)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Comma-separated f64 list.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .with_context(|| format!("--{key}: bad number {s:?}"))
                })
                .collect(),
        }
    }

    /// Error on any flag or positional never queried by the command.
    pub fn check_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.contains(*k)).collect();
        if !unknown.is_empty() {
            bail!("unknown flag(s): {unknown:?}");
        }
        if self.positionals.len() > self.positionals_seen.get() {
            bail!(
                "unexpected positional argument {:?}",
                self.positionals[self.positionals_seen.get()]
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse("steady --rate 0.9 --json --horizon=5000");
        assert_eq!(a.command.as_deref(), Some("steady"));
        assert_eq!(a.get_f64("rate", 1.0).unwrap(), 0.9);
        assert_eq!(a.get_f64("horizon", 0.0).unwrap(), 5000.0);
        assert!(a.get_bool("json"));
        a.check_unknown().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse("steady");
        assert_eq!(a.get_f64("rate", 0.9).unwrap(), 0.9);
        assert_eq!(a.get_str("payload", "none"), "none");
    }

    #[test]
    fn lists_parse() {
        let b = parse("sweep --rates 0.1,0.5,1.0");
        assert_eq!(b.get_f64_list("rates", &[]).unwrap(), vec![0.1, 0.5, 1.0]);
        b.check_unknown().unwrap();
        // A stray positional the command never consumes is an error (the
        // CLI always runs check_unknown after dispatch).
        let b = Args::parse(["sweep", "--rates", "0.1,", "1.0"].map(String::from)).unwrap();
        let _ = b.get_f64_list("rates", &[]);
        let err = b.check_unknown().unwrap_err().to_string();
        assert!(err.contains("unexpected positional"), "{err}");
    }

    #[test]
    fn positionals_consumed_in_order() {
        let a = parse("run scenario.json --json");
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional(0), Some("scenario.json"));
        assert_eq!(a.positional(1), None);
        assert!(a.get_bool("json"));
        a.check_unknown().unwrap();
    }

    #[test]
    fn unknown_flags_detected() {
        let a = parse("steady --ratee 0.9");
        let _ = a.get_f64("rate", 0.9);
        assert!(a.check_unknown().is_err());
    }

    #[test]
    fn duplicate_flag_is_error() {
        assert!(Args::parse(["--x", "1", "--x", "2"].map(String::from)).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("steady --rate abc");
        assert!(a.get_f64("rate", 1.0).is_err());
    }
}
