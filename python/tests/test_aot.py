"""AOT path: lowering to HLO text must succeed for every entry point, the
text must parse back through XLA's HLO parser (structural round-trip), and
jitted execution must match the eager composition.

The full text -> PJRT compile -> execute numeric round-trip is owned by the
Rust side (`rust/tests/runtime_roundtrip.rs`), which is the consumer of
these artifacts; jaxlib's in-Python loaded-executable API is not stable
across versions, so we don't duplicate it here."""

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import aot, model


class TestAot:
    def test_lowering_produces_hlo_text(self):
        for name, (fn, example) in model.ENTRY_POINTS.items():
            text = aot.to_hlo_text(fn, example)
            assert "HloModule" in text, name
            assert "ROOT" in text, name

    def test_hlo_text_parses_back(self):
        # The Rust loader uses XLA's HLO text parser
        # (HloModuleProto::from_text_file); the same parser must accept our
        # artifacts, with a program shape matching the example args.
        for name, (fn, example) in model.ENTRY_POINTS.items():
            text = aot.to_hlo_text(fn, example)
            module = xc._xla.hlo_module_from_text(text)
            # Parse succeeded; the re-rendered module must still declare one
            # parameter per example argument.
            rendered = module.to_string()
            for i in range(len(example)):
                assert f"parameter({i})" in rendered, (name, i)

    def test_jit_matches_eager_payload(self):
        fn, (spec,) = model.ENTRY_POINTS["payload_small"]
        x = jax.random.normal(jax.random.PRNGKey(0), spec.shape, spec.dtype)
        (eager,) = fn(x)
        (jitted,) = jax.jit(fn)(x)
        np.testing.assert_allclose(jitted, eager, rtol=1e-5, atol=1e-6)

    def test_jit_matches_eager_histogram(self):
        fn, example = model.ENTRY_POINTS["trace_histogram"]
        x = jax.random.exponential(jax.random.PRNGKey(1), example[0].shape).astype(
            jnp.float32
        )
        lo = jnp.float32(0.0)
        hi = jnp.float32(8.0)
        (eager,) = fn(x, lo, hi)
        (jitted,) = jax.jit(fn)(x, lo, hi)
        np.testing.assert_allclose(jitted, eager)

    def test_describe_format(self):
        _, example = model.ENTRY_POINTS["trace_histogram"]
        desc = aot.describe(example)
        assert "float32" in desc
        assert "scalar" in desc

    def test_manifest_entries_one_per_entry_point(self, tmp_path):
        import subprocess, sys, os
        env = dict(os.environ)
        out = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
             "--only", "trace_histogram"],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True, text=True, env=env,
        )
        assert out.returncode == 0, out.stderr
        files = sorted(p.name for p in tmp_path.iterdir())
        assert "trace_histogram.hlo.txt" in files
        assert "manifest.txt" in files
        manifest = (tmp_path / "manifest.txt").read_text()
        assert manifest.startswith("trace_histogram ")
