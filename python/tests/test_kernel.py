"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and value ranges; every case asserts allclose
against ``kernels/ref.py``. These tests run the kernels in interpret mode —
the same lowering the AOT artifacts use — so what passes here is what the
Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hist as hist_kernel
from compile.kernels import mlp as mlp_kernel
from compile.kernels import ref


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# MLP kernel
# ---------------------------------------------------------------------------

class TestMlpKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        tiles=st.integers(min_value=1, max_value=3),
        d_in=st.sampled_from([128, 256]),
        d_hidden=st.sampled_from([128, 512]),
        d_out=st.sampled_from([128, 256]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_reference_tiled_shapes(self, tiles, d_in, d_hidden, d_out, seed):
        block_b = 64
        batch = tiles * block_b
        x = rand(seed, (batch, d_in))
        w1 = rand(seed + 1, (d_in, d_hidden), -0.1, 0.1)
        b1 = rand(seed + 2, (d_hidden,), -0.1, 0.1)
        w2 = rand(seed + 3, (d_hidden, d_out), -0.1, 0.1)
        b2 = rand(seed + 4, (d_out,), -0.1, 0.1)
        got = mlp_kernel.mlp_forward(x, w1, b1, w2, b2, block_b=block_b)
        want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(batch=st.integers(min_value=1, max_value=300),
           seed=st.integers(min_value=0, max_value=1000))
    def test_padded_path_arbitrary_batch(self, batch, seed):
        d_in, d_hidden, d_out = 128, 256, 128
        x = rand(seed, (batch, d_in))
        w1 = rand(1, (d_in, d_hidden), -0.1, 0.1)
        b1 = rand(2, (d_hidden,), -0.1, 0.1)
        w2 = rand(3, (d_hidden, d_out), -0.1, 0.1)
        b2 = rand(4, (d_out,), -0.1, 0.1)
        got = mlp_kernel.mlp_forward_padded(x, w1, b1, w2, b2, block_b=128)
        want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
        assert got.shape == (batch, d_out)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_actually_clamps(self):
        # Force negative pre-activations; a kernel that skipped the ReLU
        # would differ from the oracle.
        x = -jnp.ones((128, 128), jnp.float32)
        w1 = jnp.eye(128, 128, dtype=jnp.float32)
        b1 = jnp.zeros((128,), jnp.float32)
        w2 = jnp.eye(128, 128, dtype=jnp.float32)
        b2 = jnp.ones((128,), jnp.float32)
        got = mlp_kernel.mlp_forward(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, jnp.ones((128, 128)))

    def test_rejects_misaligned_batch(self):
        x = jnp.zeros((100, 128), jnp.float32)
        w1 = jnp.zeros((128, 128), jnp.float32)
        b1 = jnp.zeros((128,), jnp.float32)
        w2 = jnp.zeros((128, 128), jnp.float32)
        b2 = jnp.zeros((128,), jnp.float32)
        with pytest.raises(AssertionError):
            mlp_kernel.mlp_forward(x, w1, b1, w2, b2, block_b=128)


# ---------------------------------------------------------------------------
# Histogram kernel
# ---------------------------------------------------------------------------

class TestHistogramKernel:
    @settings(max_examples=12, deadline=None)
    @given(
        blocks=st.integers(min_value=1, max_value=4),
        nbins=st.sampled_from([16, 64, 128]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        lo=st.floats(min_value=-5.0, max_value=0.0),
        span=st.floats(min_value=0.5, max_value=20.0),
    )
    def test_matches_reference(self, blocks, nbins, seed, lo, span):
        block_n = 4096
        n = blocks * block_n
        hi = lo + span
        # Samples straddle the range so under/overflow paths are exercised.
        x = jax.random.uniform(
            jax.random.PRNGKey(seed), (n,), jnp.float32, lo - span, hi + span
        )
        got = hist_kernel.histogram(x, lo, hi, nbins=nbins, block_n=block_n)
        want = ref.histogram_ref(x, lo, hi, nbins)
        np.testing.assert_allclose(got, want)

    def test_multi_block_accumulation(self):
        # Two blocks of identical data must give exactly double the counts.
        block_n = 4096
        x1 = jax.random.uniform(jax.random.PRNGKey(7), (block_n,), jnp.float32, 0.0, 1.0)
        x2 = jnp.concatenate([x1, x1])
        h1 = hist_kernel.histogram(x1, 0.0, 1.0, nbins=16, block_n=block_n)
        h2 = hist_kernel.histogram(x2, 0.0, 1.0, nbins=16, block_n=block_n)
        np.testing.assert_allclose(h2, 2.0 * h1)

    def test_total_count_conserved_in_range(self):
        block_n = 4096
        x = jax.random.uniform(jax.random.PRNGKey(8), (block_n,), jnp.float32, 0.0, 1.0)
        h = hist_kernel.histogram(x, 0.0, 1.0, nbins=64, block_n=block_n)
        assert float(h.sum()) == block_n

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(min_value=1, max_value=10_000),
           seed=st.integers(min_value=0, max_value=1000))
    def test_padded_path(self, n, seed):
        x = jax.random.uniform(jax.random.PRNGKey(seed), (n,), jnp.float32, 0.0, 1.0)
        got = hist_kernel.histogram_padded(x, 0.0, 1.0, nbins=32, block_n=4096)
        want = ref.histogram_ref(x, 0.0, 1.0, 32)
        np.testing.assert_allclose(got, want)

    def test_exponential_cdf_shape(self):
        # End-to-end sanity: histogram of exponential samples approximates
        # the analytic CDF (the simulator-side use case).
        n = 65536
        x = jax.random.exponential(jax.random.PRNGKey(9), (n,)).astype(jnp.float32)
        nbins = 64
        counts = hist_kernel.histogram_padded(x, 0.0, 8.0, nbins=nbins, block_n=65536)
        cdf = np.cumsum(np.asarray(counts)) / n
        edges = np.linspace(0.0, 8.0, nbins + 1)[1:]
        true_cdf = 1.0 - np.exp(-edges)
        np.testing.assert_allclose(cdf, true_cdf, atol=0.02)
