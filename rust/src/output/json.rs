//! Minimal JSON reader/writer (no serde in this environment). The writer
//! covers what the CLI and benches need: objects, arrays, numbers, strings,
//! bools. [`JsonValue::parse`] is the reader half — strict JSON with full
//! string escapes — added for the declarative scenario layer
//! (`crate::scenario`), which deserializes `ScenarioSpec` files through it.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (build with the `From` impls and [`JsonValue::object`]).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn object() -> JsonValue {
        JsonValue::Object(BTreeMap::new())
    }

    /// Parse JSON text. Strict grammar (no comments, no trailing commas);
    /// numbers parse as `f64` (JSON has no integer type — see
    /// [`JsonValue::as_u64`] for the exact-integer window); duplicate
    /// object keys keep the last value. Errors carry the byte offset.
    pub fn parse(input: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0, depth: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters after JSON value at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object member lookup (None on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    /// Exact non-negative integer, if this is one. Numbers ride as `f64`,
    /// so only integers strictly below 2^53 are unambiguous; 2^53 itself
    /// is rejected (the literal 2^53 + 1 also rounds to it, so accepting
    /// it would silently corrupt that neighbour), as is anything larger,
    /// fractional, or negative.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Number(n)
                if *n >= 0.0 && n.fract() == 0.0 && *n < 9_007_199_254_740_992.0 =>
            {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(map) => Some(map),
            _ => None,
        }
    }

    /// Insert into an object (panics on non-objects).
    pub fn set<K: Into<String>, V: Into<JsonValue>>(&mut self, key: K, value: V) -> &mut Self {
        match self {
            JsonValue::Object(map) => {
                map.insert(key.into(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            JsonValue::String(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    JsonValue::String(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact JSON serialization (`value.to_string()` via the blanket
/// `ToString`).
impl std::fmt::Display for JsonValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Recursion ceiling for nested arrays/objects: descent is one stack
/// frame per level, so an unbounded input (e.g. 100k `[`s) would abort
/// the process with a stack overflow instead of a parse error.
const MAX_DEPTH: usize = 128;

/// Recursive-descent JSON parser over the input bytes. The input comes in
/// as `&str`, so raw string segments are valid UTF-8 by construction (the
/// scanner only splits at ASCII bytes).
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    /// Current container nesting level (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl Parser<'_> {
    /// Enter a nested container; the matching decrement happens in
    /// [`array`](Self::array)/[`object`](Self::object) (errors abandon
    /// the whole parse, so no unwinding bookkeeping is needed).
    fn descend(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("nesting deeper than {MAX_DEPTH} levels at byte {}", self.pos);
        }
        Ok(())
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.peek() {
            Some(c) if c == b => {
                self.pos += 1;
                Ok(())
            }
            Some(c) => bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos,
                c as char
            ),
            None => bail!("expected {:?} at byte {}, got end of input", b as char, self.pos),
        }
    }

    fn literal(&mut self, word: &str) -> Result<()> {
        let end = self.pos + word.len();
        if self.bytes.len() >= end && &self.bytes[self.pos..end] == word.as_bytes() {
            self.pos = end;
            Ok(())
        } else {
            bail!("invalid token at byte {} (expected {word:?})", self.pos)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            None => bail!("unexpected end of input at byte {}", self.pos),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => {
                self.literal("true")?;
                Ok(JsonValue::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                Ok(JsonValue::Bool(false))
            }
            Some(b'n') => {
                self.literal("null")?;
                Ok(JsonValue::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected character {:?} at byte {}", c as char, self.pos),
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while matches!(
            self.bytes.get(self.pos),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii number bytes");
        // Enforce the RFC 8259 grammar before handing to f64's (laxer)
        // FromStr — "01", "1." and "-.5" must fail like any JSON parser.
        if !is_json_number(text) {
            bail!("invalid number {text:?} at byte {start}");
        }
        let n: f64 = text
            .parse()
            .with_context(|| format!("invalid number {text:?} at byte {start}"))?;
        if !n.is_finite() {
            bail!("number {text:?} at byte {start} overflows f64");
        }
        Ok(JsonValue::Number(n))
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        if self.bytes.len() < end {
            bail!("truncated \\u escape at byte {}", self.pos);
        }
        let text = std::str::from_utf8(&self.bytes[self.pos..end])
            .ok()
            .filter(|t| t.bytes().all(|b| b.is_ascii_hexdigit()))
            .with_context(|| format!("invalid \\u escape at byte {}", self.pos))?;
        self.pos = end;
        Ok(u32::from_str_radix(text, 16).expect("validated hex digits"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            while let Some(&c) = self.bytes.get(self.pos) {
                if c == b'"' || c == b'\\' || c < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(
                std::str::from_utf8(&self.bytes[start..self.pos])
                    .expect("input is UTF-8 and the scan splits at ASCII bytes"),
            );
            match self.peek() {
                None => bail!("unterminated string at byte {}", self.pos),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .with_context(|| format!("truncated escape at byte {}", self.pos))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..=0xDBFF).contains(&hi) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    bail!(
                                        "invalid surrogate pair before byte {}",
                                        self.pos
                                    );
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            out.push(char::from_u32(code).with_context(|| {
                                format!("invalid \\u code point before byte {}", self.pos)
                            })?);
                        }
                        other => bail!(
                            "invalid escape \\{} at byte {}",
                            other as char,
                            self.pos - 1
                        ),
                    }
                }
                Some(c) => bail!(
                    "unescaped control character 0x{c:02x} in string at byte {}",
                    self.pos
                ),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.descend()?;
        let v = self.array_body();
        self.depth -= 1;
        v
    }

    fn array_body(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                Some(c) => bail!(
                    "expected ',' or ']' at byte {}, got {:?}",
                    self.pos,
                    c as char
                ),
                None => bail!("unterminated array at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.descend()?;
        let v = self.object_body();
        self.depth -= 1;
        v
    }

    fn object_body(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                bail!("expected string object key at byte {}", self.pos);
            }
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                Some(c) => bail!(
                    "expected ',' or '}}' at byte {}, got {:?}",
                    self.pos,
                    c as char
                ),
                None => bail!("unterminated object at byte {}", self.pos),
            }
        }
    }
}

/// RFC 8259 number grammar: `-? int frac? exp?` with `int = 0 | [1-9][0-9]*`.
fn is_json_number(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0;
    if b.get(i) == Some(&b'-') {
        i += 1;
    }
    match b.get(i) {
        Some(b'0') => i += 1,
        Some(b'1'..=b'9') => {
            while matches!(b.get(i), Some(b'0'..=b'9')) {
                i += 1;
            }
        }
        _ => return false,
    }
    if b.get(i) == Some(&b'.') {
        i += 1;
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return false;
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    if matches!(b.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        if !matches!(b.get(i), Some(b'0'..=b'9')) {
            return false;
        }
        while matches!(b.get(i), Some(b'0'..=b'9')) {
            i += 1;
        }
    }
    i == b.len()
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Number(v)
    }
}
impl From<u64> for JsonValue {
    fn from(v: u64) -> Self {
        JsonValue::Number(v as f64)
    }
}
impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Number(v as f64)
    }
}
impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        JsonValue::Bool(v)
    }
}
impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::String(v.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        JsonValue::String(v)
    }
}
impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(v: Vec<T>) -> Self {
        JsonValue::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Serialize `SimResults` (used by the CLI's `--json` flag).
pub fn results_to_json(r: &crate::sim::SimResults) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("measured_time", r.measured_time)
        .set("total_requests", r.total_requests)
        .set("cold_requests", r.cold_requests)
        .set("warm_requests", r.warm_requests)
        .set("rejected_requests", r.rejected_requests)
        .set("cold_start_prob", r.cold_start_prob)
        .set("rejection_prob", r.rejection_prob)
        .set("avg_lifespan", r.avg_lifespan)
        .set("avg_server_count", r.avg_server_count)
        .set("avg_running_count", r.avg_running_count)
        .set("avg_idle_count", r.avg_idle_count)
        .set("max_server_count", r.max_server_count)
        .set("wasted_capacity", r.wasted_capacity)
        .set("avg_response_time", r.avg_response_time)
        .set("response_p50", r.response_p50)
        .set("response_p95", r.response_p95)
        .set("response_p99", r.response_p99)
        .set("billed_instance_seconds", r.billed_instance_seconds)
        .set("observed_arrival_rate", r.observed_arrival_rate)
        .set("instance_count_pmf", r.instance_count_pmf.clone())
        .set("prewarm_starts", r.prewarm_starts)
        .set("wasted_prewarm_seconds", r.wasted_prewarm_seconds)
        .set("failed_requests", r.failed_requests)
        .set("timeout_requests", r.timeout_requests)
        .set("coldstart_failures", r.coldstart_failures)
        .set("retry_attempts", r.retry_attempts)
        .set("retry_exhausted", r.retry_exhausted)
        .set("wasted_work_seconds", r.wasted_work_seconds)
        .set("success_rate", r.success_rate())
        .set("goodput", r.goodput);
    o
}

/// Serialize a fleet run (used by `simfaas fleet --json`): the aggregate
/// rollup, a per-function array, and (optionally) the priced cost totals.
pub fn fleet_to_json(
    results: &crate::fleet::FleetResults,
    cost: Option<&crate::fleet::FleetCostReport>,
) -> JsonValue {
    let a = &results.aggregate;
    let mut agg = JsonValue::object();
    agg.set("functions", a.functions)
        .set("measured_time", a.measured_time)
        .set("total_requests", a.total_requests)
        .set("cold_requests", a.cold_requests)
        .set("warm_requests", a.warm_requests)
        .set("rejected_requests", a.rejected_requests)
        .set("cap_rejections", a.cap_rejections)
        .set("cold_start_prob", a.cold_start_prob)
        .set("rejection_prob", a.rejection_prob)
        .set("avg_server_count", a.avg_server_count)
        .set("avg_running_count", a.avg_running_count)
        .set("avg_idle_count", a.avg_idle_count)
        .set("wasted_capacity", a.wasted_capacity)
        .set("avg_response_time", a.avg_response_time)
        .set("response_p50", a.response_p50)
        .set("response_p95", a.response_p95)
        .set("response_p99", a.response_p99)
        .set("billed_instance_seconds", a.billed_instance_seconds)
        .set("observed_arrival_rate", a.observed_arrival_rate)
        .set("prewarm_starts", a.prewarm_starts)
        .set("wasted_prewarm_seconds", a.wasted_prewarm_seconds)
        .set("failed_requests", a.failed_requests)
        .set("timeout_requests", a.timeout_requests)
        .set("coldstart_failures", a.coldstart_failures)
        .set("retry_attempts", a.retry_attempts)
        .set("retry_exhausted", a.retry_exhausted)
        .set("wasted_work_seconds", a.wasted_work_seconds)
        .set("success_rate", a.success_rate())
        .set("goodput", a.goodput);
    // Cluster keys appear only for cluster-configured runs, keeping flat
    // fleet output byte-identical to the pre-cluster schema.
    if !a.host_utilization.is_empty() {
        agg.set("placement_failures", a.placement_failures)
            .set("evictions", a.evictions)
            .set("host_utilization", a.host_utilization.clone());
    }

    let functions: Vec<JsonValue> = results
        .names
        .iter()
        .zip(&results.per_function)
        .map(|(name, r)| {
            let mut f = JsonValue::object();
            f.set("name", name.as_str())
                .set("total_requests", r.total_requests)
                .set("cold_start_prob", r.cold_start_prob)
                .set("rejection_prob", r.rejection_prob)
                .set("avg_server_count", r.avg_server_count)
                .set("avg_response_time", r.avg_response_time)
                .set("billed_instance_seconds", r.billed_instance_seconds);
            f
        })
        .collect();

    let mut o = JsonValue::object();
    o.set("aggregate", agg).set("functions", JsonValue::Array(functions));
    if let Some(c) = cost {
        let mut cj = JsonValue::object();
        cj.set("requests", c.total.requests)
            .set("gb_seconds", c.total.gb_seconds)
            .set("request_charges", c.total.request_charges)
            .set("runtime_charges", c.total.runtime_charges)
            .set("developer_total", c.total.developer_total())
            .set("provider_infra_cost", c.total.provider_infra_cost);
        o.set("cost", cj);
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_encoding() {
        assert_eq!(JsonValue::from(1.5).to_string(), "1.5");
        assert_eq!(JsonValue::from(true).to_string(), "true");
        assert_eq!(JsonValue::Null.to_string(), "null");
        assert_eq!(JsonValue::from("a\"b\n").to_string(), "\"a\\\"b\\n\"");
        assert_eq!(JsonValue::Number(f64::NAN).to_string(), "null");
    }

    #[test]
    fn object_and_array_encoding() {
        let mut o = JsonValue::object();
        o.set("b", 2u64).set("a", vec![1.0, 2.5]);
        // BTreeMap: keys sorted.
        assert_eq!(o.to_string(), r#"{"a":[1,2.5],"b":2}"#);
    }

    #[test]
    fn fleet_json_has_aggregate_and_functions() {
        use crate::fleet::{fleet_cost, FleetConfig, PolicySpec};
        use crate::sim::SimConfig;
        let cfg = FleetConfig::from_sim_configs(
            &[SimConfig::table1().with_horizon(2_000.0)],
            PolicySpec::fixed(600.0),
        );
        let res = cfg.run();
        let cost = fleet_cost(&cfg, &res, &crate::cost::PricingTable::aws_lambda());
        let j = fleet_to_json(&res, Some(&cost)).to_string();
        assert!(j.contains("\"aggregate\":{"));
        assert!(j.contains("\"functions\":["));
        assert!(j.contains("\"cold_start_prob\""));
        assert!(j.contains("\"cost\":{"));
        assert!(j.contains("\"developer_total\""));
        assert!(j.contains("\"retry_attempts\""));
        assert!(j.contains("\"success_rate\""));
    }

    #[test]
    fn parse_scalars_and_containers() {
        assert_eq!(JsonValue::parse("1.5").unwrap(), JsonValue::Number(1.5));
        assert_eq!(JsonValue::parse(" -2e3 ").unwrap(), JsonValue::Number(-2000.0));
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(
            JsonValue::parse(r#"[1, 2.5, "x"]"#).unwrap(),
            JsonValue::Array(vec![
                JsonValue::Number(1.0),
                JsonValue::Number(2.5),
                JsonValue::String("x".to_string()),
            ])
        );
        let v = JsonValue::parse(r#"{ "a": [true, {}], "b": "c" }"#).unwrap();
        assert_eq!(v.get("b").and_then(JsonValue::as_str), Some("c"));
        assert_eq!(v.get("a").and_then(JsonValue::as_array).map(<[_]>::len), Some(2));
    }

    #[test]
    fn parse_string_escapes() {
        assert_eq!(
            JsonValue::parse(r#""a\"b\\c\n\tAé""#).unwrap(),
            JsonValue::String("a\"b\\c\n\tA\u{e9}".to_string())
        );
        // Surrogate pair: U+1F600 via \u escapes.
        assert_eq!(
            JsonValue::parse("\"\\ud83d\\ude00\"").unwrap(),
            JsonValue::String("\u{1F600}".to_string())
        );
        // Non-ASCII passes through raw.
        assert_eq!(
            JsonValue::parse("\"héllo\"").unwrap(),
            JsonValue::String("héllo".to_string())
        );
    }

    #[test]
    fn parse_rejects_malformed_input() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\" 1}",
            "{\"a\":}",
            "{a:1}",
            "tru",
            "1.5x",
            "\"unterminated",
            "\"bad \\q escape\"",
            "[1] trailing",
            "nan",
            "1e999",
            // Laxer-than-JSON numeric forms f64::from_str would accept.
            "01",
            "1.",
            "[-.5]",
            "[1.5e]",
        ] {
            let err = JsonValue::parse(bad);
            assert!(err.is_err(), "accepted {bad:?}");
            assert!(
                format!("{:#}", err.unwrap_err()).contains("byte"),
                "error for {bad:?} lacks a byte offset"
            );
        }
    }

    #[test]
    fn deep_nesting_is_an_error_not_a_stack_overflow() {
        // Deeply nested containers must hit the depth ceiling cleanly.
        let deep = "[".repeat(100_000);
        let err = format!("{:#}", JsonValue::parse(&deep).unwrap_err());
        assert!(err.contains("nesting"), "{err}");
        // Sibling containers at the same level do not accumulate depth.
        let wide = format!("[{}]", vec!["[[]]"; 64].join(","));
        JsonValue::parse(&wide).unwrap();
        // And 64 levels is comfortably within the limit.
        let ok = format!("{}{}", "[".repeat(64), "]".repeat(64));
        JsonValue::parse(&ok).unwrap();
    }

    #[test]
    fn parse_roundtrips_serializer_output() {
        // Writer → parser is the identity on everything the crate emits
        // (NaN excepted: it serializes as null by design).
        let mut o = JsonValue::object();
        o.set("pi", 3.141592653589793)
            .set("n", 1e6)
            .set("neg", -0.25)
            .set("flag", true)
            .set("name", "sim\\faas \"quoted\"\n")
            .set("items", vec![1.0, 2.0, 4.5])
            .set("nested", {
                let mut n = JsonValue::object();
                n.set("empty", JsonValue::Array(vec![])).set("z", JsonValue::Null);
                n
            });
        let text = o.to_string();
        assert_eq!(JsonValue::parse(&text).unwrap(), o);
    }

    #[test]
    fn integer_accessor_window() {
        assert_eq!(JsonValue::Number(42.0).as_u64(), Some(42));
        assert_eq!(JsonValue::Number(0.0).as_u64(), Some(0));
        assert_eq!(JsonValue::Number(1.5).as_u64(), None);
        assert_eq!(JsonValue::Number(-1.0).as_u64(), None);
        assert_eq!(JsonValue::Number(1e300).as_u64(), None);
        assert_eq!(JsonValue::from("7").as_u64(), None);
        // 2^53 - 1 is the last unambiguous integer; 2^53 is rejected
        // because the literal 2^53 + 1 also rounds to it.
        assert_eq!(
            JsonValue::Number(9_007_199_254_740_991.0).as_u64(),
            Some(9_007_199_254_740_991)
        );
        assert_eq!(JsonValue::Number(9_007_199_254_740_992.0).as_u64(), None);
        assert_eq!(
            JsonValue::parse("9007199254740993").unwrap().as_u64(),
            None,
            "a rounded literal must not silently become a different integer"
        );
    }

    #[test]
    fn results_json_has_key_fields() {
        use crate::sim::{ServerlessSimulator, SimConfig};
        let mut cfg = SimConfig::table1();
        cfg.horizon = 2_000.0;
        let r = ServerlessSimulator::new(cfg).run();
        let j = results_to_json(&r).to_string();
        assert!(j.contains("\"cold_start_prob\""));
        assert!(j.contains("\"instance_count_pmf\":["));
        assert!(j.contains("\"failed_requests\""));
        assert!(j.contains("\"goodput\""));
    }
}
