//! Discrete-event engine: the future event list.
//!
//! Two interchangeable future-event lists live behind the [`EventQueue`]
//! trait, with two SimFaaS-specific features shared by both:
//!
//! * **Deterministic tie-breaking** — events at equal times pop in insertion
//!   order (a monotone sequence number), so runs are bit-reproducible.
//! * **Generation-tagged expiration events** — per the paper, each idle
//!   instance expires `expiration_threshold` seconds after its last request.
//!   Reusing the instance must cancel its pending expiration; instead of an
//!   O(n) heap removal we tag expiration events with the instance's
//!   *generation* counter and drop stale ones on pop (lazy cancellation).
//!
//! [`HeapEventQueue`] is the classic binary heap (O(log n) per op);
//! [`CalendarEventQueue`] wraps [`super::calendar::CalendarQueue`] for
//! O(1) amortized scheduling on the hot path. Their pop sequences are
//! identical by construction — the property tests below drive both under
//! randomized interleavings and assert it.

use super::calendar::CalendarQueue;
use super::instance::InstanceId;
use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Events the serverless simulator reacts to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A request arrives at the platform.
    Arrival,
    /// The request being processed on `InstanceId` completes.
    Departure(InstanceId),
    /// Provider-initiated prewarm trigger: start provisioning an instance
    /// ahead of a predicted arrival. Handled by [`crate::sim::core`] when a
    /// provisioning lead time is configured; the instance becomes warm one
    /// lead later via [`Event::ProvisioningDone`].
    Provision,
    /// Instance finished provisioning and joins the warm pool (scheduled by
    /// the prewarm path; lifecycle core only).
    ProvisioningDone(InstanceId),
    /// Idle-expiration check for an instance; `gen` guards staleness.
    Expiration { id: InstanceId, gen: u64 },
    /// The request running on `InstanceId` hit the fault profile's
    /// execution timeout with kill semantics: the execution is cut off and
    /// the instance torn down with it. Scheduled *instead of* the
    /// request's [`Event::Departure`] (never alongside it), so no
    /// generation guard is needed.
    RequestTimeout(InstanceId),
    /// A failed or timed-out request re-enters the platform after its
    /// backoff delay. `attempt` is the dispatch attempt this arrival makes
    /// (2 = first retry); `prev_delay_bits` carries the previous backoff
    /// delay as raw `f64` bits — the decorrelated-jitter state — so
    /// `Event` stays `Copy + Eq`.
    RetryArrival {
        /// Dispatch attempt number for this re-arrival (first attempt = 1).
        attempt: u32,
        /// Previous backoff delay, as `f64::to_bits`.
        prev_delay_bits: u64,
    },
    /// Degradation window `window` of the fault profile begins: effective
    /// capacity shrinks by its factor.
    DegradationStart {
        /// Index into [`crate::sim::FaultProfile::degradation`].
        window: u32,
    },
    /// Degradation window `window` of the fault profile ends.
    DegradationEnd {
        /// Index into [`crate::sim::FaultProfile::degradation`].
        window: u32,
    },
    /// Fleet-level autoscaling tick: observe the capacity signal and
    /// actuate the configured controller (`crate::control`). Scheduled
    /// and intercepted by the fleet run loops before any engine core
    /// sees it; never dispatched to a single-function simulator.
    ControlTick,
    /// End of simulation horizon.
    Horizon,
}

/// The future-event-list contract shared by the heap and calendar
/// implementations: schedule at absolute times, pop in `(time,
/// insertion-order)` order, bit-identically across implementations.
pub trait EventQueue {
    /// Schedule `event` at absolute time `at`.
    fn schedule(&mut self, at: SimTime, event: Event);
    /// Pop the earliest event (ties in insertion order).
    fn pop(&mut self) -> Option<(SimTime, Event)>;
    /// Time of the next event without popping.
    fn peek_time(&self) -> Option<SimTime>;
    /// Number of pending events.
    fn len(&self) -> usize;
    /// Whether no events are pending.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Drop all pending events (the tie-break counter survives).
    fn clear(&mut self);
}

#[derive(Debug, Clone)]
struct Scheduled {
    at: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse to get earliest-first, then
        // lowest-seq-first among equal times.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Binary-heap future event list (the reference implementation).
#[derive(Debug, Default)]
pub struct HeapEventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl HeapEventQueue {
    pub fn new() -> Self {
        HeapEventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        HeapEventQueue { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    /// Schedule `event` at absolute time `at`.
    #[inline]
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        debug_assert!(at.is_finite(), "cannot schedule at infinity");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Pop the earliest event.
    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.heap.pop().map(|s| (s.at, s.event))
    }

    /// Time of the next event without popping.
    #[inline]
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

impl EventQueue for HeapEventQueue {
    #[inline]
    fn schedule(&mut self, at: SimTime, event: Event) {
        HeapEventQueue::schedule(self, at, event);
    }
    #[inline]
    fn pop(&mut self) -> Option<(SimTime, Event)> {
        HeapEventQueue::pop(self)
    }
    #[inline]
    fn peek_time(&self) -> Option<SimTime> {
        HeapEventQueue::peek_time(self)
    }
    #[inline]
    fn len(&self) -> usize {
        HeapEventQueue::len(self)
    }
    #[inline]
    fn is_empty(&self) -> bool {
        HeapEventQueue::is_empty(self)
    }
    fn clear(&mut self) {
        HeapEventQueue::clear(self);
    }
}

/// Calendar-queue future event list: the hot-path implementation used by
/// the engines (O(1) amortized schedule/pop; see [`super::calendar`]).
#[derive(Debug, Default)]
pub struct CalendarEventQueue {
    cal: CalendarQueue<Event>,
}

impl CalendarEventQueue {
    pub fn new() -> Self {
        CalendarEventQueue { cal: CalendarQueue::new() }
    }

    /// Queue sized for roughly `cap` concurrently pending events.
    pub fn with_capacity(cap: usize) -> Self {
        CalendarEventQueue { cal: CalendarQueue::with_capacity(cap) }
    }

    /// Schedule `event` at absolute time `at`.
    #[inline]
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        self.cal.push(at, event);
    }

    /// Pop the earliest event.
    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.cal.pop().map(|(at, _, ev)| (at, ev))
    }

    /// Time of the next event without popping (O(n); diagnostic use).
    #[inline]
    pub fn peek_time(&self) -> Option<SimTime> {
        self.cal.peek_time()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.cal.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.cal.is_empty()
    }

    pub fn clear(&mut self) {
        self.cal.clear();
    }
}

impl EventQueue for CalendarEventQueue {
    #[inline]
    fn schedule(&mut self, at: SimTime, event: Event) {
        CalendarEventQueue::schedule(self, at, event);
    }
    #[inline]
    fn pop(&mut self) -> Option<(SimTime, Event)> {
        CalendarEventQueue::pop(self)
    }
    #[inline]
    fn peek_time(&self) -> Option<SimTime> {
        CalendarEventQueue::peek_time(self)
    }
    #[inline]
    fn len(&self) -> usize {
        CalendarEventQueue::len(self)
    }
    #[inline]
    fn is_empty(&self) -> bool {
        CalendarEventQueue::is_empty(self)
    }
    fn clear(&mut self) {
        CalendarEventQueue::clear(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::{Rng, SplitMix64};

    /// Run a contract check against both implementations.
    fn on_both(check: impl Fn(&mut dyn EventQueue)) {
        let mut heap = HeapEventQueue::new();
        check(&mut heap);
        let mut cal = CalendarEventQueue::new();
        check(&mut cal);
    }

    #[test]
    fn pops_in_time_order() {
        on_both(|q| {
            q.schedule(SimTime::from_secs(3.0), Event::Arrival);
            q.schedule(SimTime::from_secs(1.0), Event::Horizon);
            q.schedule(SimTime::from_secs(2.0), Event::Departure(InstanceId(7)));
            let (t1, e1) = q.pop().unwrap();
            let (t2, e2) = q.pop().unwrap();
            let (t3, e3) = q.pop().unwrap();
            assert_eq!((t1.as_secs(), e1), (1.0, Event::Horizon));
            assert_eq!(
                (t2.as_secs(), e2),
                (2.0, Event::Departure(InstanceId(7)))
            );
            assert_eq!((t3.as_secs(), e3), (3.0, Event::Arrival));
            assert!(q.pop().is_none());
        });
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        on_both(|q| {
            let t = SimTime::from_secs(5.0);
            for i in 0..100 {
                q.schedule(t, Event::Departure(InstanceId(i)));
            }
            for i in 0..100 {
                let (_, e) = q.pop().unwrap();
                assert_eq!(e, Event::Departure(InstanceId(i)));
            }
        });
    }

    #[test]
    fn peek_does_not_remove() {
        on_both(|q| {
            q.schedule(SimTime::from_secs(1.5), Event::Arrival);
            assert_eq!(q.peek_time().unwrap().as_secs(), 1.5);
            assert_eq!(q.len(), 1);
        });
    }

    #[test]
    fn interleaved_schedule_pop() {
        on_both(|q| {
            q.schedule(SimTime::from_secs(10.0), Event::Arrival);
            q.schedule(SimTime::from_secs(5.0), Event::Arrival);
            let (t, _) = q.pop().unwrap();
            assert_eq!(t.as_secs(), 5.0);
            q.schedule(SimTime::from_secs(7.0), Event::Horizon);
            let (t, e) = q.pop().unwrap();
            assert_eq!((t.as_secs(), e), (7.0, Event::Horizon));
            let (t, _) = q.pop().unwrap();
            assert_eq!(t.as_secs(), 10.0);
        });
    }

    /// Property test (satellite): under randomized insert/pop
    /// interleavings — including inserts into the past, dense ties, and
    /// sparse far-future gaps — the calendar queue pops the exact
    /// `(time, event)` sequence the binary heap does. Sequence numbers
    /// advance in lockstep because both queues see the same schedule
    /// calls in the same order.
    #[test]
    fn calendar_matches_heap_under_randomized_interleavings() {
        for trial in 0..20u64 {
            let mut rng = Rng::new(SplitMix64::new(0xCA1E_0DA8 ^ trial).next_u64());
            let mut heap = HeapEventQueue::new();
            let mut cal = CalendarEventQueue::new();
            let mut clock = 0.0f64;
            let mut next_id = 0u64;
            for _ in 0..4000 {
                let r = rng.uniform();
                if r < 0.55 || heap.is_empty() {
                    // Schedule: mostly near the clock, sometimes a dense
                    // tie, sometimes far future, sometimes in the past.
                    let u = rng.uniform();
                    let at = if u < 0.2 {
                        clock // exact tie pile-up
                    } else if u < 0.8 {
                        clock + rng.uniform() * 10.0
                    } else if u < 0.9 {
                        clock + rng.uniform() * 5000.0 // sparse far future
                    } else {
                        (clock - rng.uniform() * 3.0).max(0.0) // the past
                    };
                    let ev = match next_id % 3 {
                        0 => Event::Arrival,
                        1 => Event::Departure(InstanceId(next_id)),
                        _ => Event::Expiration { id: InstanceId(next_id), gen: next_id },
                    };
                    next_id += 1;
                    let t = SimTime::from_secs(at);
                    heap.schedule(t, ev);
                    cal.schedule(t, ev);
                } else {
                    let h = heap.pop();
                    let c = cal.pop();
                    assert_eq!(h, c, "trial {trial}: pop diverged");
                    if let Some((t, _)) = h {
                        clock = t.as_secs();
                    }
                }
                assert_eq!(heap.len(), cal.len());
            }
            // Drain: the full remaining sequence must match too.
            loop {
                let h = heap.pop();
                let c = cal.pop();
                assert_eq!(h, c, "trial {trial}: drain diverged");
                if h.is_none() {
                    break;
                }
            }
        }
    }
}
