//! PJRT runtime bridge: loads the `artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py` (Layer 2 lowering of the Layer-1 Pallas kernels)
//! and executes them from Rust. Python never runs on the request path.

pub mod engine;
pub mod payload;
pub mod pool;

pub use engine::Engine;
pub use payload::{PayloadKind, HIST_ARTIFACT, HIST_N, HIST_NBINS};
pub use pool::ComputePool;

use std::path::PathBuf;

/// Default artifacts directory: `$SIMFAAS_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SIMFAAS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}
