//! Bench: regenerate Fig. 7 — average instance count vs arrival rate,
//! simulation vs emulated platform. Paper MAPE: 3.43%.
#[path = "harness.rs"]
mod harness;

use simfaas::figures::{self, ValidationOpts};

fn main() {
    harness::header(
        "Fig 7",
        "average instance count vs arrival rate: simulator vs emulator",
        "MAPE 3.43%; count grows sublinearly with rate",
    );
    // NOTE: this testbed has a single CPU core; the emulator's threads
    // timeshare it, so validation is restricted to arrival rates whose
    // thread count the core can serve faithfully (see EXPERIMENTS.md).
    let quick = harness::quick();
    let rates: Vec<f64> =
        if quick { vec![0.25, 0.5, 1.0] } else { vec![0.25, 0.5, 0.75, 1.0] };
    let opts = ValidationOpts {
        emu_horizon: if quick { 6_000.0 } else { 30_000.0 },
        time_scale: 500.0,
        sim_horizon: 400_000.0,
        skip: 600.0,
        seed: 0x717,
    };
    let (_, rows) = harness::bench("fig7/validation_sweep", 1, || {
        figures::validation_rows(&rates, &opts)
    });
    println!();
    println!("rate    sim servers   emu servers");
    for r in &rows {
        println!(
            "{:<7.2} {:>10.4}   {:>10.4}",
            r.rate, r.sim.avg_server_count, r.emu.avg_server_count
        );
    }
    let (_, e7, _) = figures::validation_errors(&rows);
    println!("MAPE (servers): {e7:.2}%   (paper: 3.43%)");
    // Shape: server count increases with rate.
    let counts: Vec<f64> = rows.iter().map(|r| r.emu.avg_server_count).collect();
    assert!(counts.windows(2).all(|w| w[1] > w[0] * 0.95), "count should grow with rate");
    println!("shape OK: instance count grows with arrival rate");
}
