//! Cost calculation (paper §4.4): predict developer charges (per-request +
//! GB-s runtime) and provider infrastructure cost under different loads and
//! providers, directly from simulation outputs.
//!
//! Run with: `cargo run --release --example cost_planning`

use simfaas::cost::{estimate, scale_to, FunctionConfig, PricingTable, Provider};
use simfaas::output::Table;
use simfaas::sim::{ServerlessSimulator, SimConfig};

fn main() {
    println!("== monthly cost vs load (AWS Lambda pricing, 128 MB) ==\n");
    let month = 30.0 * 86_400.0;
    let mut t = Table::new(vec![
        "rate req/s",
        "p_cold %",
        "avg servers",
        "dev $/month",
        "infra $/month",
        "waste %",
    ]);
    for rate in [0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
        let cfg = SimConfig::table1().with_arrival_rate(rate).with_horizon(200_000.0);
        let r = ServerlessSimulator::new(cfg).run();
        let est = estimate(&r, &FunctionConfig::new(128.0), &PricingTable::aws_lambda());
        let m = scale_to(&est, month);
        t.row_f64(
            &[
                rate,
                r.cold_start_prob * 100.0,
                r.avg_server_count,
                m.developer_total(),
                m.provider_infra_cost,
                r.wasted_capacity * 100.0,
            ],
            3,
        );
    }
    print!("{t}");

    println!("\n== provider comparison at 1 req/s, 256 MB ==\n");
    let cfg = SimConfig::table1().with_arrival_rate(1.0).with_horizon(200_000.0);
    let r = ServerlessSimulator::new(cfg).run();
    let mut t = Table::new(vec!["provider", "dev $/month", "requests %", "runtime %"]);
    for (name, p) in [
        ("AWS Lambda", Provider::AwsLambda),
        ("Google Cloud Functions", Provider::GoogleCloudFunctions),
        ("Azure Functions", Provider::AzureFunctions),
        ("IBM Cloud Functions", Provider::IbmCloudFunctions),
    ] {
        let est = estimate(&r, &FunctionConfig::new(256.0), &PricingTable::for_provider(p));
        let m = scale_to(&est, month);
        let total = m.developer_total();
        t.row(vec![
            name.to_string(),
            format!("{:.2}", total),
            format!("{:.1}", 100.0 * m.request_charges / total),
            format!("{:.1}", 100.0 * m.runtime_charges / total),
        ]);
    }
    print!("{t}");

    println!("\n== expiration threshold: provider cost vs developer QoS ==\n");
    let mut t = Table::new(vec!["threshold s", "p_cold %", "infra $/month", "dev $/month"]);
    for th in [60.0, 300.0, 600.0, 1800.0] {
        let cfg = SimConfig::table1().with_expiration_threshold(th).with_horizon(200_000.0);
        let r = ServerlessSimulator::new(cfg).run();
        let est = estimate(&r, &FunctionConfig::new(128.0), &PricingTable::aws_lambda());
        let m = scale_to(&est, month);
        t.row_f64(
            &[th, r.cold_start_prob * 100.0, m.provider_infra_cost, m.developer_total()],
            3,
        );
    }
    print!("{t}");
    println!("(longer threshold: fewer cold starts, linearly higher provider cost)");
}
