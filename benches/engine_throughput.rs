//! Bench: core engine performance (the §Perf hot path in DESIGN.md) —
//! simulator event throughput (scale-per-request and concurrency-value
//! simulators), multi-threaded ensemble throughput, the calendar event
//! queue vs the binary-heap reference, the capacity-domain-sharded capped
//! fleet at 10k functions, the PJRT payload latency, and the PJRT
//! histogram vs the pure-Rust histogram.
//!
//! Emits a machine-readable `BENCH_engine.json` (path overridable via
//! `SIMFAAS_BENCH_JSON`) so CI can archive the events/s trajectory.
#[path = "harness.rs"]
mod harness;

use simfaas::cluster::{ClusterConfig, SchedulerSpec};
use simfaas::control::ControllerSpec;
use simfaas::fleet::{FleetConfig, FleetResults, PolicySpec};
use simfaas::output::JsonValue;
use simfaas::runtime::{Engine, PayloadKind};
use simfaas::sim::ensemble::{run_ensemble, EnsembleOpts};
use simfaas::sim::{
    CalendarEventQueue, Event, EventQueue, FaultProfile, HeapEventQueue, Histogram, InstanceId,
    ParServerlessSimulator, RetryPolicy, Rng, ServerlessSimulator, SimConfig, SimTime,
};
use simfaas::workload::{AzureDataset, SyntheticTrace, TraceSource};

/// arrival + departure per served request, plus expirations (~#instances).
fn event_count(r: &simfaas::sim::SimResults) -> u64 {
    r.total_requests * 2 + r.instances_expired
}

/// Replay a schedule/pop script against any `EventQueue`, logging the pop
/// sequence as `(time bits, payload)` pairs for exact cross-impl comparison.
/// Each op schedules one tagged departure, then pops 0..=2 events, so the
/// queue stays near a steady-state size; the tail drain empties it.
fn drive_queue<Q: EventQueue>(q: &mut Q, ops: &[(f64, u32)]) -> Vec<(u64, u64)> {
    let mut log = Vec::with_capacity(ops.len());
    for (k, &(at, pops)) in ops.iter().enumerate() {
        q.schedule(SimTime::from_secs(at), Event::Departure(InstanceId(k as u64)));
        for _ in 0..pops {
            match q.pop() {
                Some((t, Event::Departure(id))) => log.push((t.as_secs().to_bits(), id.0)),
                Some(_) => unreachable!("only departures are scheduled"),
                None => break,
            }
        }
    }
    while let Some((t, Event::Departure(id))) = q.pop() {
        log.push((t.as_secs().to_bits(), id.0));
    }
    log
}

fn main() {
    harness::header(
        "Engine",
        "simulator events/s; ensemble scaling; PJRT payload latency; histogram backends",
        "(perf targets in DESIGN.md §Perf)",
    );
    let mut json = JsonValue::object();
    json.set("bench", "engine_throughput").set("quick", harness::quick());
    let mut rates = JsonValue::object();

    // --- scale-per-request simulator throughput ---
    let horizon = if harness::quick() { 2e5 } else { 1e6 };
    let cfg = SimConfig::table1().with_horizon(horizon);
    let (res, results) = harness::bench("sim/table1_horizon_1e6", 5, || {
        ServerlessSimulator::new(cfg.clone()).run()
    });
    let events = event_count(&results);
    let eps_table1 = events as f64 / res.mean_s;
    println!(
        "  -> {:.2} M events/s ({} events in {:.3} s)",
        eps_table1 / 1e6,
        events,
        res.mean_s
    );
    rates.set("sim_table1_events_per_sec", eps_table1);

    // High-load variant: bigger pools stress the idle-pool data structure.
    let cfg_hi = SimConfig::table1().with_arrival_rate(50.0).with_horizon(horizon / 10.0);
    let (res_hi, results_hi) = harness::bench("sim/high_load_rate50", 3, || {
        ServerlessSimulator::new(cfg_hi.clone()).run()
    });
    let eps_hi = event_count(&results_hi) as f64 / res_hi.mean_s;
    println!("  -> {:.2} M events/s at ~100-instance pool", eps_hi / 1e6);
    rates.set("sim_high_load_events_per_sec", eps_hi);

    // Concurrency-value simulator under the same high load: this is the
    // case the seed's per-event O(all-instances) busy scan made quadratic
    // (DESIGN.md §Perf targets ≥5x here post-fix).
    let (res_par, results_par) = harness::bench("par/high_load_rate50", 3, || {
        ParServerlessSimulator::new(cfg_hi.clone(), 4).run()
    });
    let eps_par = event_count(&results_par) as f64 / res_par.mean_s;
    println!("  -> {:.2} M events/s (concurrency value c=4)", eps_par / 1e6);
    rates.set("par_high_load_events_per_sec", eps_par);

    // --- multi-threaded ensemble throughput ---
    // 8 replications of a shorter Table-1 run; aggregate events/s across
    // the whole ensemble shows the replication-level scaling.
    let cfg_ens = SimConfig::table1().with_horizon(horizon / 10.0);
    let opts = EnsembleOpts::new(8, 0x5EED);
    let (res_ens, ens) = harness::bench("ensemble/8_replications_all_cores", 3, || {
        run_ensemble(&cfg_ens, &opts)
    });
    let ens_events: u64 = ens.runs.iter().map(event_count).sum();
    let eps_ens = ens_events as f64 / res_ens.mean_s;
    let s = ens.summary();
    println!(
        "  -> {:.2} M events/s aggregate; p_cold {:.4}% ± {:.4}",
        eps_ens / 1e6,
        s.cold_start_prob.mean * 100.0,
        s.cold_start_prob.ci_half * 100.0
    );
    rates.set("ensemble_events_per_sec", eps_ens);

    // --- fleet simulator throughput (500-function synthetic tenant mix) ---
    // The acceptance bar for the fleet subsystem: a 500-function
    // Azure-style mix completes under the bench harness AND its output is
    // bit-identical at 1/2/8 shards (checked here, untimed) before the
    // timed all-cores runs.
    let fleet_horizon = if harness::quick() { 4_000.0 } else { 40_000.0 };
    let mut trace_rng = Rng::new(0xF1EE7);
    let trace = SyntheticTrace::generate(500, &mut trace_rng);
    let fleet_cfg =
        FleetConfig::from_trace(&trace, fleet_horizon, 0.0, 0xF1EE7, PolicySpec::fixed(600.0));
    let fleet_digest = |r: &FleetResults| {
        let a = &r.aggregate;
        [
            a.total_requests,
            a.cold_requests,
            a.rejected_requests,
            a.avg_server_count.to_bits(),
            a.billed_instance_seconds.to_bits(),
            a.response_p95.to_bits(),
        ]
    };
    let ref_digest = fleet_digest(&fleet_cfg.clone().with_threads(1).run());
    for threads in [2, 8] {
        let d = fleet_digest(&fleet_cfg.clone().with_threads(threads).run());
        assert_eq!(d, ref_digest, "fleet output depends on shard count ({threads} threads)");
    }
    let (res_fleet, fleet_res) = harness::bench("fleet/500_functions_all_cores", 3, || {
        fleet_cfg.run()
    });
    assert_eq!(fleet_digest(&fleet_res), ref_digest, "all-cores fleet run diverged");
    let fleet_events =
        fleet_res.aggregate.total_requests * 2 + fleet_res.aggregate.instances_expired;
    let eps_fleet = fleet_events as f64 / res_fleet.mean_s;
    println!(
        "  -> {:.2} M events/s across 500 functions ({} requests, p_cold {:.3}%)",
        eps_fleet / 1e6,
        fleet_res.aggregate.total_requests,
        fleet_res.aggregate.cold_start_prob * 100.0
    );
    rates.set("fleet_events_per_sec", eps_fleet);

    // --- real-trace ingestion + streaming arrivals ---
    // Parse the checked-in Azure sample dataset, scale its ~2 req/s mix up
    // 40x, and run a fleet through the streaming ArrivalSource seam: the
    // timed loop covers CSV ingestion AND lazy arrival generation (no
    // materialized arrival vectors anywhere).
    let sample_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/traces/azure_sample");
    let trace_horizon = if harness::quick() { 21_600.0 } else { 86_400.0 };
    let (res_trace, trace_res) = harness::bench("trace/ingest_and_stream", 3, || {
        let ds = AzureDataset::load(&sample_dir)
            .and_then(|ds| ds.scale_rates(40.0))
            .expect("sample trace parses");
        FleetConfig::from_source(
            &TraceSource::AzureDataset(ds),
            trace_horizon,
            0.0,
            0xA22E,
            PolicySpec::fixed(600.0),
        )
        .run()
    });
    let trace_events =
        trace_res.aggregate.total_requests * 2 + trace_res.aggregate.instances_expired;
    let eps_trace = trace_events as f64 / res_trace.mean_s;
    println!(
        "  -> {:.2} M events/s incl. ingestion ({} requests from {} functions)",
        eps_trace / 1e6,
        trace_res.aggregate.total_requests,
        trace_res.per_function.len()
    );
    rates.set("trace_ingest_events_per_sec", eps_trace);

    // --- fault-injection + retry-storm overhead ---
    // The reliability layer's hot path: the same 500-function mix where
    // 20% of dispatches fail and every failure re-enters through the
    // exponential-backoff retry queue. Guards the enabled-path overhead
    // (fault-lane RNG draws + retry scheduling); the cases above all run
    // with the disabled profile, so they pin the zero-overhead contract.
    let fault_cfg = fleet_cfg
        .clone()
        .with_fault(FaultProfile::disabled().with_failure_prob(0.2).with_timeout(30.0))
        .with_retry(RetryPolicy::exponential(0.1, 5.0, 4));
    let (res_fault, fault_res) =
        harness::bench("fleet/faults_retry_storm", 3, || fault_cfg.run());
    let fault_events =
        fault_res.aggregate.total_requests * 2 + fault_res.aggregate.instances_expired;
    let eps_fault = fault_events as f64 / res_fault.mean_s;
    println!(
        "  -> {:.2} M events/s under faults+retries ({} failures, {} retries)",
        eps_fault / 1e6,
        fault_res.aggregate.failed_requests,
        fault_res.aggregate.retry_attempts
    );
    assert!(fault_res.aggregate.failed_requests > 0, "fault profile did not fire");
    assert!(fault_res.aggregate.retry_attempts > 0, "retry layer did not fire");
    rates.set("fault_events_per_sec", eps_fault);

    // --- telemetry recording overhead ---
    // Same 500-function mix with the observer enabled: every request
    // appends a span and every 60 sim-seconds each function appends a
    // state sample. Telemetry draws no RNG and schedules no events, so
    // this isolates the pure buffer-append cost against fleet/500 above.
    let telem_cfg = fleet_cfg.clone().with_telemetry(60.0);
    let (res_telem, telem_res) =
        harness::bench("telemetry/record_overhead", 3, || telem_cfg.run());
    assert_eq!(fleet_digest(&telem_res), ref_digest, "recording changed the simulation");
    let recorders = telem_res.telemetry.as_ref().expect("telemetry enabled");
    let span_total: u64 = recorders.iter().map(|r| r.spans.len() as u64).sum();
    assert_eq!(span_total, telem_res.aggregate.total_requests, "span stream incomplete");
    let telem_events =
        telem_res.aggregate.total_requests * 2 + telem_res.aggregate.instances_expired;
    let eps_telem = telem_events as f64 / res_telem.mean_s;
    let sample_total: usize = recorders.iter().map(|r| r.samples.len()).sum();
    println!(
        "  -> {:.2} M events/s while recording ({} spans, {} samples)",
        eps_telem / 1e6,
        span_total,
        sample_total
    );
    rates.set("telemetry_events_per_sec", eps_telem);

    // --- cluster placement + eviction overhead ---
    // The same 500-function mix packed onto 32 finite hosts under the
    // least-loaded scheduler: every cold start routes through host
    // selection and accounting, and memory pressure exercises the
    // eviction path. The clustered runner is single-queue (threads are
    // ignored), so this also bounds the worst-case serial throughput.
    let cluster_cfg = fleet_cfg.clone().with_cluster(
        ClusterConfig::new(32, 4_096.0, 32.0).with_scheduler(SchedulerSpec::LeastLoaded),
    );
    let (res_cluster, cluster_res) =
        harness::bench("cluster/bin_packing_500fn", 3, || cluster_cfg.run());
    assert_eq!(
        cluster_res.aggregate.host_utilization.len(),
        32,
        "cluster metrics missing from the aggregate"
    );
    let cluster_events =
        cluster_res.aggregate.total_requests * 2 + cluster_res.aggregate.instances_expired;
    let eps_cluster = cluster_events as f64 / res_cluster.mean_s;
    println!(
        "  -> {:.2} M events/s on 32 hosts ({} placement failures, {} evictions)",
        eps_cluster / 1e6,
        cluster_res.aggregate.placement_failures,
        cluster_res.aggregate.evictions
    );
    rates.set("cluster_events_per_sec", eps_cluster);

    // --- event-queue microbench: calendar vs binary heap ---
    // One randomized schedule/pop interleaving (mostly near-future inserts
    // with an occasional far-future outlier, as simulations produce) drives
    // both EventQueue impls. Their pop logs must be bit-identical — the
    // calendar's bucket layout may not leak into ordering — and the timed
    // loops then measure each impl on the same op stream.
    let queue_n = if harness::quick() { 200_000 } else { 2_000_000 };
    let mut qrng = Rng::new(0xCA7);
    let mut qt = 0.0f64;
    let mut qops: Vec<(f64, u32)> = Vec::with_capacity(queue_n);
    for _ in 0..queue_n {
        qt += qrng.exponential(4.0);
        let at = if qrng.uniform() < 0.03 {
            qt + qrng.uniform_range(1.0e3, 1.0e5)
        } else {
            qt + qrng.uniform_range(0.0, 2.0)
        };
        qops.push((at, qrng.below(3) as u32));
    }
    let cal_log = drive_queue(&mut CalendarEventQueue::with_capacity(1024), &qops);
    let heap_log = drive_queue(&mut HeapEventQueue::with_capacity(1024), &qops);
    assert!(cal_log == heap_log, "calendar and heap pop sequences diverged");
    let (res_q, cal_pops) = harness::bench("queue/calendar_vs_heap", 3, || {
        drive_queue(&mut CalendarEventQueue::with_capacity(1024), &qops).len() as u64
    });
    // One schedule + one pop per scripted op = 2 queue events each.
    let eps_q = cal_pops as f64 * 2.0 / res_q.mean_s;
    let (res_qh, _) = harness::bench("queue/heap_reference", 3, || {
        drive_queue(&mut HeapEventQueue::with_capacity(1024), &qops).len() as u64
    });
    println!(
        "  -> {:.2} M queue events/s (heap reference {:.2} M; identical pop order)",
        eps_q / 1e6,
        cal_pops as f64 * 2.0 / res_qh.mean_s / 1e6
    );
    rates.set("queue_events_per_sec", eps_q);

    // --- capped fleet at 10k functions: capacity-domain sharding ---
    // The extreme-scale stress case: a 10k-function synthetic mix under a
    // binding fleet cap. K=1 is the exactly-pinned serial admission path;
    // K=8 shards cap and functions into 8 independently deterministic
    // domains, so the output must be invariant to the worker thread count
    // (each domain is a sequential simulation wherever it runs). K=8 and
    // K=1 legitimately differ: sharding partitions the cap itself.
    let stress_n = if harness::quick() { 2_000 } else { 10_000 };
    let stress_horizon = if harness::quick() { 1_500.0 } else { 6_000.0 };
    let mut stress_rng = Rng::new(0xD0A1);
    let stress = SyntheticTrace::generate(stress_n, &mut stress_rng);
    let capped =
        FleetConfig::from_trace(&stress, stress_horizon, 0.0, 0xD0A1, PolicySpec::fixed(300.0))
            .with_fleet_cap(stress_n / 5);
    let sharded = capped.clone().with_capacity_domains(8);
    let ref_shard = fleet_digest(&sharded.clone().with_threads(1).run());
    for threads in [2, 8] {
        let d = fleet_digest(&sharded.clone().with_threads(threads).run());
        assert_eq!(d, ref_shard, "sharded fleet output depends on thread count ({threads})");
    }
    let (res_serial, _) = harness::bench("fleet/capped_10k_fn_k1", 3, || {
        capped.clone().with_threads(1).run()
    });
    let (res_shard, shard_res) =
        harness::bench("fleet/capped_sharded_10k_fn", 3, || sharded.run());
    assert_eq!(fleet_digest(&shard_res), ref_shard, "all-cores sharded run diverged");
    let shard_events =
        shard_res.aggregate.total_requests * 2 + shard_res.aggregate.instances_expired;
    let eps_shard = shard_events as f64 / res_shard.mean_s;
    println!(
        "  -> {:.2} M events/s sharded x8 ({:.2}x vs K=1 serial; {} rejected under cap)",
        eps_shard / 1e6,
        res_serial.mean_s / res_shard.mean_s,
        shard_res.aggregate.rejected_requests
    );
    rates.set("capped_fleet_events_per_sec", eps_shard);

    // --- autoscaling control overhead: target-tracking on the 500-fn mix ---
    // The control loop's hot path: the same 500-function mix behind a
    // tight gate cap with a target-tracking controller ticking every 10
    // simulated seconds. Thread invariance is asserted untimed first (the
    // controller lives with the domain's single-queue loop), then the
    // timed runs measure the per-tick observe/actuate overhead on top of
    // the coupled capped path.
    let control_spec = ControllerSpec::target_tracking(0.7).with_tick(10.0).with_bounds(20, 400);
    let control_cfg = fleet_cfg.clone().with_fleet_cap(100).with_controller(control_spec);
    let ref_ctl = fleet_digest(&control_cfg.clone().with_threads(1).run());
    for threads in [2, 8] {
        let d = fleet_digest(&control_cfg.clone().with_threads(threads).run());
        assert_eq!(d, ref_ctl, "controlled fleet output depends on thread count ({threads})");
    }
    let (res_ctl, ctl_res) =
        harness::bench("control/target_tracking_500fn", 3, || control_cfg.run());
    assert_eq!(fleet_digest(&ctl_res), ref_ctl, "all-cores controlled run diverged");
    let report = ctl_res.control.as_ref().expect("control report");
    assert!(report.ticks > 0, "controller never ticked");
    assert!(report.scale_up_events + report.scale_down_events > 0, "controller never actuated");
    let ctl_events = ctl_res.aggregate.total_requests * 2
        + ctl_res.aggregate.instances_expired
        + report.ticks as u64;
    let eps_ctl = ctl_events as f64 / res_ctl.mean_s;
    println!(
        "  -> {:.2} M events/s under control ({} ticks, +{}/-{} scale events, cap {} -> {})",
        eps_ctl / 1e6,
        report.ticks,
        report.scale_up_events,
        report.scale_down_events,
        100,
        report.final_capacity
    );
    rates.set("control_events_per_sec", eps_ctl);

    json.set("events_per_sec", rates);
    let path = std::env::var("SIMFAAS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_engine.json".to_string());
    match std::fs::write(&path, json.to_string() + "\n") {
        Ok(()) => println!("  (events/s recorded in {path})"),
        Err(e) => println!("  (could not write {path}: {e})"),
    }

    // --- PJRT payload latency ---
    match Engine::load_dir(simfaas::runtime::default_artifacts_dir()) {
        Ok(engine) => {
            for kind in PayloadKind::ALL {
                let x = vec![0.25f32; kind.input_len()];
                let iters = if harness::quick() { 20 } else { 100 };
                let (r, _) = harness::bench(
                    &format!("pjrt/{}", kind.artifact_name()),
                    iters,
                    || engine.run_payload(kind, &x).unwrap(),
                );
                let (b, d_in, _) = kind.shape();
                let flops = 2.0 * b as f64 * (d_in * 2 * d_in + 2 * d_in * 128) as f64;
                println!("  -> ~{:.2} MFLOP/exec, {:.1} us/exec", flops / 1e6, r.mean_s * 1e6);
            }

            // --- histogram backends on a 4M-sample trace ---
            let mut rng = Rng::new(1);
            let n = if harness::quick() { 500_000 } else { 4_000_000 };
            let samples_f32: Vec<f32> = (0..n).map(|_| rng.exponential(0.5) as f32).collect();
            let samples_f64: Vec<f64> = samples_f32.iter().map(|&x| x as f64).collect();
            let (rust_r, h) = harness::bench("hist/pure_rust_4M", 5, || {
                let mut h = Histogram::new(0.0, 16.0, 64);
                for &s in &samples_f64 {
                    h.push(s);
                }
                h
            });
            let (pjrt_r, counts) = harness::bench("hist/pjrt_kernel_4M", 5, || {
                engine.run_histogram(&samples_f32, 0.0, 16.0).unwrap()
            });
            let expect: Vec<f64> = h.counts().iter().map(|&c| c as f64).collect();
            assert_eq!(counts, expect, "backends must agree exactly");
            println!(
                "  -> pure rust {:.1} Msamples/s, pjrt kernel {:.1} Msamples/s (identical counts)",
                n as f64 / rust_r.mean_s / 1e6,
                n as f64 / pjrt_r.mean_s / 1e6
            );
        }
        Err(e) => println!("(pjrt benches skipped: {e:#})"),
    }
}
