//! The scale-per-request platform emulator — this repo's stand-in for the
//! paper's AWS Lambda testbed (see DESIGN.md §3 Substitutions).
//!
//! Unlike the discrete-event simulator (`sim::ServerlessSimulator`), the
//! emulator is a *real concurrent system*: OS threads, channels, wall-clock
//! scheduling on a scaled [`VirtualClock`], and function bodies that
//! actually execute the AOT-compiled JAX/Pallas payload via PJRT. It
//! implements the management behaviour the paper reverse-engineered:
//!
//! * scale-per-request autoscaling — an arrival with no idle instance spins
//!   up a new one (cold start) unless the max concurrency level is reached
//!   (rejection);
//! * newest-first routing — the youngest idle instance absorbs traffic;
//! * per-instance idle expiration after the threshold;
//! * cold start = provisioning delay + application init + service, with the
//!   whole cold response observed by the client, as on Lambda.
//!
//! Validation (paper Figs. 6–8) compares the simulator's predictions
//! against the emulator's measured traces, which flow through the same
//! `trace::` pipeline a real Lambda experiment would.

use super::clock::VirtualClock;
use crate::runtime::{ComputePool, PayloadKind};
use crate::sim::process::SimProcess;
use crate::sim::rng::Rng;
use crate::trace::record::{Outcome, RequestRecord};
use crate::workload::Workload;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

/// Emulated function/service configuration.
#[derive(Clone)]
pub struct EmulatorConfig {
    /// Compute payload executed per request (None = synthetic-only service).
    pub payload: Option<PayloadKind>,
    /// Payload repetitions per request (service-time knob).
    pub payload_reps: u32,
    /// Additional synthetic (IO-like) service component, in virtual seconds,
    /// drawn per request. None disables it.
    pub synthetic_service: Option<Arc<dyn SimProcess>>,
    /// Cold-start provisioning delay in virtual seconds (platform init).
    pub provisioning_delay: f64,
    /// Extra application-init work on cold start: payload reps (the "load
    /// the ML model" phase; billed, per the paper).
    pub app_init_reps: u32,
    /// Idle expiration threshold, virtual seconds.
    pub expiration_threshold: f64,
    /// Maximum concurrency level.
    pub max_concurrency: usize,
    /// Virtual seconds per wall second.
    pub time_scale: f64,
    /// Expiration sweep granularity in virtual seconds (threshold accuracy).
    pub tick: f64,
    /// Seed for the synthetic service draws.
    pub seed: u64,
}

impl EmulatorConfig {
    /// A Lambda-like default: 600 s threshold, 1000 concurrency, no compute
    /// payload (pure synthetic service — fastest; tests and validation use
    /// this plus payload variants).
    pub fn lambda_like(time_scale: f64) -> Self {
        EmulatorConfig {
            payload: None,
            payload_reps: 1,
            synthetic_service: None,
            provisioning_delay: 0.25,
            app_init_reps: 0,
            expiration_threshold: 600.0,
            max_concurrency: 1000,
            time_scale,
            tick: 1.0,
            seed: 0xEB,
        }
    }
}

/// Per-instance summary from the emulator.
#[derive(Debug, Clone)]
pub struct InstanceRecord {
    pub id: String,
    pub created_at: f64,
    /// Termination time (or the horizon if still alive at shutdown).
    pub terminated_at: f64,
    pub requests_served: u64,
    /// Total busy (billed) virtual seconds.
    pub busy_time: f64,
    /// True if the instance was expired (vs alive at shutdown).
    pub expired: bool,
}

/// Emulation output: the client-side request trace plus instance lifecycles.
#[derive(Debug, Clone)]
pub struct EmulationResult {
    pub records: Vec<RequestRecord>,
    pub instances: Vec<InstanceRecord>,
    /// Virtual time when the run ended (all requests drained).
    pub horizon: f64,
}

/// Derived metrics matching the simulator's headline outputs.
#[derive(Debug, Clone, Copy)]
pub struct EmuMetrics {
    pub cold_start_prob: f64,
    pub rejection_prob: f64,
    pub avg_server_count: f64,
    pub avg_running_count: f64,
    pub avg_idle_count: f64,
    pub wasted_capacity: f64,
    pub avg_lifespan: f64,
    pub avg_warm_response: f64,
    pub avg_cold_response: f64,
}

impl EmulationResult {
    /// Compute time-averaged metrics over `[skip, horizon]`.
    ///
    /// Server integral: sum of instance lifespan overlaps with the window.
    /// Running integral: each in-flight request occupies exactly one
    /// instance for its response duration (scale-per-request), so the busy
    /// integral is the sum of response times clipped to the window.
    pub fn metrics(&self, skip: f64) -> EmuMetrics {
        let t0 = skip;
        let t1 = self.horizon;
        let window = (t1 - t0).max(1e-9);
        let overlap = |a: f64, b: f64| -> f64 { (b.min(t1) - a.max(t0)).max(0.0) };

        let mut server_integral = 0.0;
        let mut lifespans = Vec::new();
        for inst in &self.instances {
            server_integral += overlap(inst.created_at, inst.terminated_at);
            if inst.expired && inst.created_at >= t0 {
                lifespans.push(inst.terminated_at - inst.created_at);
            }
        }
        let mut running_integral = 0.0;
        let mut cold = 0u64;
        let mut warm = 0u64;
        let mut rejected = 0u64;
        let mut warm_resp = 0.0;
        let mut cold_resp = 0.0;
        for r in &self.records {
            if r.arrived_at < t0 {
                continue;
            }
            match r.outcome {
                Outcome::Cold => {
                    cold += 1;
                    cold_resp += r.response_time;
                }
                Outcome::Warm => {
                    warm += 1;
                    warm_resp += r.response_time;
                }
                Outcome::Rejected => rejected += 1,
            }
            running_integral += overlap(r.arrived_at, r.arrived_at + r.response_time);
        }
        let served = (cold + warm).max(1);
        let total = (cold + warm + rejected).max(1);
        let avg_server = server_integral / window;
        let avg_running = running_integral / window;
        EmuMetrics {
            cold_start_prob: cold as f64 / served as f64,
            rejection_prob: rejected as f64 / total as f64,
            avg_server_count: avg_server,
            avg_running_count: avg_running,
            avg_idle_count: avg_server - avg_running,
            wasted_capacity: if avg_server > 0.0 {
                (avg_server - avg_running) / avg_server
            } else {
                0.0
            },
            avg_lifespan: if lifespans.is_empty() {
                f64::NAN
            } else {
                lifespans.iter().sum::<f64>() / lifespans.len() as f64
            },
            avg_warm_response: if warm > 0 { warm_resp / warm as f64 } else { f64::NAN },
            avg_cold_response: if cold > 0 { cold_resp / cold as f64 } else { f64::NAN },
        }
    }
}

// ---------------------------------------------------------------------------
// Internal plumbing
// ---------------------------------------------------------------------------

/// Router-bound events. Each carries its virtual timestamp so the router
/// can process drained batches in virtual-time order: cross-thread channel
/// delivery adds wall-clock jitter that, multiplied by the time scale,
/// would otherwise reorder a completion past a later arrival and produce
/// spurious cold starts (see `Platform::run`).
enum Ev {
    /// Client submits a request (its observed virtual arrival time).
    Arrival { arrived_at: f64 },
    /// Instance finished a request (at virtual time `at`) and is idle again.
    Idle { at: f64, inst: usize, record: RequestRecord, busy: f64 },
    /// Periodic expiration sweep at virtual time `at`.
    Tick { at: f64 },
    /// Client sent everything.
    ClientDone,
}

impl Ev {
    fn ts(&self) -> f64 {
        match self {
            Ev::Arrival { arrived_at } => *arrived_at,
            Ev::Idle { at, .. } => *at,
            Ev::Tick { at } => *at,
            Ev::ClientDone => f64::INFINITY,
        }
    }
}

/// Job sent to an instance worker.
enum Job {
    Serve { arrived_at: f64, cold: bool },
    Shutdown,
}

struct InstanceHandle {
    tx: mpsc::Sender<Job>,
    join: std::thread::JoinHandle<()>,
}

/// The platform emulator.
pub struct Platform {
    cfg: EmulatorConfig,
    pool: Option<Arc<ComputePool>>,
}

impl Platform {
    pub fn new(cfg: EmulatorConfig, pool: Option<Arc<ComputePool>>) -> Self {
        assert!(
            cfg.payload.is_none() || pool.is_some(),
            "a compute pool is required when a payload is configured"
        );
        Platform { cfg, pool }
    }

    /// Run the workload to completion and return the trace.
    pub fn run(&self, workload: &Workload) -> Result<EmulationResult> {
        let clock = VirtualClock::new(self.cfg.time_scale);
        let (ev_tx, ev_rx) = mpsc::channel::<Ev>();

        // --- client thread: open-loop arrival schedule ---
        let client = {
            let ev_tx = ev_tx.clone();
            let arrivals = workload.arrivals.clone();
            let clock = clock;
            std::thread::spawn(move || {
                for t in arrivals {
                    clock.sleep_until(t);
                    if ev_tx.send(Ev::Arrival { arrived_at: clock.now() }).is_err() {
                        return;
                    }
                }
                let _ = ev_tx.send(Ev::ClientDone);
            })
        };

        // --- ticker thread: expiration sweeps ---
        let tick_stop = Arc::new(AtomicUsize::new(0));
        let ticker = {
            let ev_tx = ev_tx.clone();
            let clock = clock;
            let tick = self.cfg.tick;
            let stop = Arc::clone(&tick_stop);
            std::thread::spawn(move || {
                while stop.load(Ordering::Relaxed) == 0 {
                    clock.sleep(tick);
                    if ev_tx.send(Ev::Tick { at: clock.now() }).is_err() {
                        return;
                    }
                }
            })
        };

        // --- router loop (this thread) ---
        let mut instances: Vec<InstanceHandle> = Vec::new();
        let mut instance_records: Vec<InstanceRecord> = Vec::new();
        // idle pool: instance index -> idle-since; BTreeMap keyed by index
        // (monotone creation order) makes "newest idle" the max key.
        let mut idle: BTreeMap<usize, f64> = Default::default();
        let mut live = 0usize;
        let mut in_flight = 0usize;
        let mut client_done = false;
        let mut records: Vec<RequestRecord> = Vec::new();

        // Event loop: drain everything already enqueued and handle the batch
        // in virtual-timestamp order (see `Ev` docs).
        let mut batch: Vec<Ev> = Vec::new();
        let mut done_flag = false;
        'outer: loop {
            batch.clear();
            match ev_rx.recv() {
                Ok(e) => batch.push(e),
                Err(_) => break,
            }
            while let Ok(e) = ev_rx.try_recv() {
                batch.push(e);
            }
            batch.sort_by(|a, b| a.ts().partial_cmp(&b.ts()).unwrap());
            for ev in batch.drain(..) {
                match ev {
                Ev::Arrival { arrived_at } => {
                    if let Some((&inst, _)) = idle.iter().next_back() {
                        // Warm start on the newest idle instance.
                        idle.remove(&inst);
                        in_flight += 1;
                        let _ = instances[inst]
                            .tx
                            .send(Job::Serve { arrived_at, cold: false });
                    } else if live < self.cfg.max_concurrency {
                        // Cold start: spin up an instance thread.
                        let inst = instances.len();
                        let handle = self.spawn_instance(inst, clock, ev_tx.clone())?;
                        instances.push(handle);
                        instance_records.push(InstanceRecord {
                            id: format!("em-{inst:06}"),
                            created_at: arrived_at,
                            terminated_at: f64::NAN,
                            requests_served: 0,
                            busy_time: 0.0,
                            expired: false,
                        });
                        live += 1;
                        in_flight += 1;
                        let _ = instances[inst]
                            .tx
                            .send(Job::Serve { arrived_at, cold: true });
                    } else {
                        records.push(RequestRecord {
                            arrived_at,
                            outcome: Outcome::Rejected,
                            response_time: 0.0,
                            instance_id: String::new(),
                        });
                    }
                }
                Ev::Idle { at, inst, record, busy } => {
                    in_flight -= 1;
                    instance_records[inst].requests_served += 1;
                    instance_records[inst].busy_time += busy;
                    records.push(record);
                    idle.insert(inst, at);
                    if client_done && in_flight == 0 {
                        done_flag = true;
                    }
                }
                Ev::Tick { at } => {
                    let expired: Vec<usize> = idle
                        .iter()
                        .filter(|(_, &since)| at - since >= self.cfg.expiration_threshold)
                        .map(|(&i, _)| i)
                        .collect();
                    for inst in expired {
                        idle.remove(&inst);
                        let _ = instances[inst].tx.send(Job::Shutdown);
                        live -= 1;
                        let rec = &mut instance_records[inst];
                        rec.terminated_at = at;
                        rec.expired = true;
                    }
                }
                Ev::ClientDone => {
                    client_done = true;
                    if in_flight == 0 {
                        done_flag = true;
                    }
                }
                }
            }
            if done_flag {
                break 'outer;
            }
        }

        // Shutdown: stop ticker, drain instance threads.
        tick_stop.store(1, Ordering::Relaxed);
        let horizon = clock.now();
        for (i, inst) in instances.iter().enumerate() {
            let _ = inst.tx.send(Job::Shutdown);
            if instance_records[i].terminated_at.is_nan() {
                instance_records[i].terminated_at = horizon;
            }
        }
        for inst in instances.drain(..) {
            let _ = inst.join.join();
        }
        let _ = client.join();
        drop(ev_tx);
        let _ = ticker.join();

        records.sort_by(|a, b| a.arrived_at.partial_cmp(&b.arrived_at).unwrap());
        Ok(EmulationResult { records, instances: instance_records, horizon })
    }

    /// Spawn one instance worker thread.
    fn spawn_instance(
        &self,
        idx: usize,
        clock: VirtualClock,
        ev_tx: mpsc::Sender<Ev>,
    ) -> Result<InstanceHandle> {
        let (tx, rx) = mpsc::channel::<Job>();
        let cfg = self.cfg.clone();
        let pool = self.pool.clone();
        let id = format!("em-{idx:06}");
        let join = std::thread::spawn(move || {
            let mut rng = Rng::new(cfg.seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut first = true;
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Serve { arrived_at, cold } => {
                        if cold {
                            debug_assert!(first);
                            // Platform init.
                            clock.sleep(cfg.provisioning_delay);
                            // Application init (model load) — compute work.
                            if let (Some(kind), Some(pool)) = (cfg.payload, pool.as_ref()) {
                                for _ in 0..cfg.app_init_reps {
                                    let x = vec![0.1f32; kind.input_len()];
                                    let _ = pool.run_payload(kind, x);
                                }
                            }
                            first = false;
                        }
                        // Service: compute payload reps + synthetic IO.
                        if let (Some(kind), Some(pool)) = (cfg.payload, pool.as_ref()) {
                            for r in 0..cfg.payload_reps {
                                let x = vec![(r as f32 + 1.0) * 0.01; kind.input_len()];
                                let _ = pool.run_payload(kind, x);
                            }
                        }
                        if let Some(p) = &cfg.synthetic_service {
                            let dt = p.sample(&mut rng);
                            clock.sleep(dt);
                        }
                        let done = clock.now();
                        let record = RequestRecord {
                            arrived_at,
                            outcome: if cold { Outcome::Cold } else { Outcome::Warm },
                            response_time: done - arrived_at,
                            instance_id: id.clone(),
                        };
                        if ev_tx
                            .send(Ev::Idle {
                                at: done,
                                inst: idx,
                                record,
                                busy: done - arrived_at,
                            })
                            .is_err()
                        {
                            return;
                        }
                    }
                    Job::Shutdown => return,
                }
            }
        });
        Ok(InstanceHandle { tx, join })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::ConstProcess;
    use crate::workload;

    fn quick_cfg() -> EmulatorConfig {
        // 500x keeps wall jitter small relative to the 2 s service times on
        // this single-core testbed (see EXPERIMENTS.md).
        let mut cfg = EmulatorConfig::lambda_like(500.0);
        cfg.synthetic_service = Some(Arc::new(ConstProcess::new(2.0)));
        cfg.provisioning_delay = 0.5;
        cfg.tick = 2.0;
        cfg
    }

    #[test]
    fn single_burst_scales_per_request() {
        let _guard = crate::emulator::emu_test_guard();
        // 4 simultaneous arrivals with nothing warm: 4 cold starts.
        let cfg = quick_cfg();
        let platform = Platform::new(cfg, None);
        let w = Workload { arrivals: vec![1.0, 1.0, 1.0, 1.0] };
        let res = platform.run(&w).unwrap();
        assert_eq!(res.records.len(), 4);
        let cold = res.records.iter().filter(|r| r.outcome == Outcome::Cold).count();
        assert_eq!(cold, 4, "each concurrent request must spawn an instance");
        assert_eq!(res.instances.len(), 4);
    }

    #[test]
    fn warm_reuse_after_completion() {
        let _guard = crate::emulator::emu_test_guard();
        // Arrivals 10 virtual-seconds apart with 2 s service: one instance
        // handles everything after the first cold start.
        let cfg = quick_cfg();
        let platform = Platform::new(cfg, None);
        let w = workload::deterministic(10.0, 1.0, 100.0);
        let res = platform.run(&w).unwrap();
        let cold = res.records.iter().filter(|r| r.outcome == Outcome::Cold).count();
        // A rare scheduler stall can bunch arrivals and cold-start one
        // extra instance; systematic reuse failure would cold-start many.
        assert!(cold <= 2, "records: {:?}", res.records);
        assert!(res.instances.len() <= 2);
        let warm = res.records.len() - cold;
        assert!(warm >= res.records.len() - 2);
    }

    #[test]
    fn expiration_after_threshold() {
        let _guard = crate::emulator::emu_test_guard();
        let mut cfg = quick_cfg();
        cfg.expiration_threshold = 20.0;
        cfg.tick = 1.0;
        let platform = Platform::new(cfg, None);
        // Two arrivals 60 virtual seconds apart: the second is cold again.
        let w = Workload { arrivals: vec![1.0, 61.0] };
        let res = platform.run(&w).unwrap();
        let cold = res.records.iter().filter(|r| r.outcome == Outcome::Cold).count();
        assert_eq!(cold, 2);
        assert!(res.instances[0].expired);
        let life = res.instances[0].terminated_at - res.instances[0].created_at;
        // busy ~2.5 (provisioning+service) + idle 20 (+tick jitter)
        assert!(life > 20.0 && life < 30.0, "life={life}");
    }

    #[test]
    fn rejection_at_max_concurrency() {
        let _guard = crate::emulator::emu_test_guard();
        let mut cfg = quick_cfg();
        cfg.max_concurrency = 2;
        let platform = Platform::new(cfg, None);
        let w = Workload { arrivals: vec![1.0, 1.0, 1.0, 1.0, 1.0] };
        let res = platform.run(&w).unwrap();
        let rejected = res.records.iter().filter(|r| r.outcome == Outcome::Rejected).count();
        assert_eq!(rejected, 3);
        assert_eq!(res.instances.len(), 2);
    }

    #[test]
    fn metrics_running_count_littles_law() {
        let _guard = crate::emulator::emu_test_guard();
        // lambda=1/s, service 2 s deterministic => E[running] ~ 2.
        let cfg = quick_cfg();
        let platform = Platform::new(cfg, None);
        let mut rng = crate::sim::Rng::new(5);
        let w = workload::poisson(1.0, 400.0, &mut rng);
        let res = platform.run(&w).unwrap();
        let m = res.metrics(50.0);
        assert!(
            (m.avg_running_count - 2.0).abs() < 0.5,
            "running={}",
            m.avg_running_count
        );
        assert!(m.cold_start_prob < 0.2);
        assert!(m.avg_warm_response >= 2.0 && m.avg_warm_response < 2.6);
    }

    #[test]
    fn records_round_trip_through_trace_pipeline() {
        let _guard = crate::emulator::emu_test_guard();
        let cfg = quick_cfg();
        let platform = Platform::new(cfg, None);
        let mut rng = crate::sim::Rng::new(6);
        let w = workload::poisson(0.5, 200.0, &mut rng);
        let res = platform.run(&w).unwrap();
        let mut buf = Vec::new();
        crate::trace::write_csv(&mut buf, &res.records).unwrap();
        let parsed = crate::trace::read_csv(&buf[..]).unwrap();
        assert_eq!(parsed.len(), res.records.len());
        let p = crate::trace::identify(&parsed);
        assert!((p.warm_mean - 2.0).abs() < 0.5, "warm={}", p.warm_mean);
    }
}
