//! Integration test: the full paper-§5 validation pipeline on a small
//! workload — emulate → trace CSV round-trip → parameter identification →
//! simulate with identified parameters → compare. This is the CI-sized
//! version of `examples/validate_end_to_end.rs` (no PJRT payload, so it
//! stays fast and timing-robust).

use simfaas::emulator::{EmulatorConfig, Platform};
use simfaas::sim::{ExpProcess, Process, ServerlessSimulator, SimConfig};
use simfaas::trace;
use simfaas::workload;
use std::sync::Arc;

/// The emulator is a real-time concurrent system; on this single-core
/// testbed two emulations running in parallel distort each other's thread
/// timing, so the emulator-backed tests serialize on this lock.
static EMULATOR_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn emulator_guard() -> std::sync::MutexGuard<'static, ()> {
    // A panicking sibling test must not poison this lock into a second
    // failure — the lock only serializes timing, it protects no data.
    EMULATOR_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Retry-once runner: the emulator carries genuine testbed timing noise
/// (single core); a tolerance breach on one window is retried on a fresh
/// window before declaring failure, mirroring how the paper averages
/// multiple experiment windows.
fn with_retry(name: &str, attempt: impl Fn(u64) -> Result<(), String>) {
    let mut last = String::new();
    for seed_bump in 0..2 {
        match attempt(seed_bump) {
            Ok(()) => return,
            Err(e) => last = e,
        }
    }
    panic!("{name} failed on both windows: {last}");
}

#[test]
fn emulate_identify_simulate_compare() {
    let _guard = emulator_guard();
    with_retry("pipeline", |bump| pipeline_attempt(bump));
}

fn pipeline_attempt(seed_bump: u64) -> Result<(), String> {
    // 1. Emulate.
    let mut cfg = EmulatorConfig::lambda_like(500.0);
    cfg.synthetic_service = Some(Arc::new(ExpProcess::with_mean(1.991)));
    cfg.provisioning_delay = 0.253;
    cfg.expiration_threshold = 600.0;
    cfg.tick = 2.0;
    let mut rng = simfaas::sim::Rng::new(7 + seed_bump);
    let w = workload::poisson(1.0, 6_000.0, &mut rng);
    let res = Platform::new(cfg, None).run(&w).unwrap();
    assert!(res.records.len() as f64 > 5_500.0 * 0.9);
    // (assertions below return Err for retry; hard invariants stay asserts)

    // 2. CSV round-trip.
    let mut buf = Vec::new();
    trace::write_csv(&mut buf, &res.records).unwrap();
    let records = trace::read_csv(&buf[..]).unwrap();
    assert_eq!(records.len(), res.records.len());

    // 3. Identify.
    let p = trace::identify(&records);
    assert!((p.arrival_rate - 1.0).abs() < 0.05, "rate={}", p.arrival_rate);
    assert!(p.warm_mean > 1.8 && p.warm_mean < 2.6, "warm={}", p.warm_mean);
    assert!(p.cold_mean > p.warm_mean, "cold {} <= warm {}", p.cold_mean, p.warm_mean);

    // 4. Simulate with identified parameters (empirical service bootstrap).
    let warm: Vec<f64> = records
        .iter()
        .filter(|r| r.outcome == trace::Outcome::Warm)
        .map(|r| r.response_time)
        .collect();
    let mut sim_cfg = SimConfig::table1()
        .with_arrival_rate(p.arrival_rate)
        .with_horizon(150_000.0);
    sim_cfg.skip_initial = 300.0;
    sim_cfg.warm_service = Process::empirical(warm);
    sim_cfg.cold_service = Process::exp_mean(p.cold_mean);
    let sim = ServerlessSimulator::new(sim_cfg).run();

    // 5. Compare: pool size and waste agree within tolerance on a short
    //    emulated window (P(cold) is too rare to compare at this scale).
    let emu = res.metrics(300.0);
    let server_err =
        (sim.avg_server_count - emu.avg_server_count).abs() / emu.avg_server_count;
    // Tolerances are deliberately loose: this is a pipeline test on a
    // single-core testbed where the emulator carries real timing noise;
    // EXPERIMENTS.md records the precision achieved on quiet full runs.
    if server_err >= 0.35 {
        return Err(format!(
            "server count error {:.1}%: sim {} vs emu {}",
            server_err * 100.0,
            sim.avg_server_count,
            emu.avg_server_count
        ));
    }
    let waste_err = (sim.wasted_capacity - emu.wasted_capacity).abs();
    if waste_err >= 0.18 {
        return Err(format!("waste differs by {waste_err}"));
    }
    Ok(())
}

#[test]
fn warm_pool_reconstruction_tracks_true_pool() {
    let _guard = emulator_guard();
    with_retry("warm_pool", |bump| warm_pool_attempt(bump));
}

fn warm_pool_attempt(seed_bump: u64) -> Result<(), String> {
    // The paper's §5.3 estimator (unique instance ids in a trailing window)
    // applied to emulator records approximates the emulator's true pool.
    let mut cfg = EmulatorConfig::lambda_like(500.0);
    cfg.synthetic_service = Some(Arc::new(ExpProcess::with_mean(1.991)));
    cfg.provisioning_delay = 0.253;
    cfg.expiration_threshold = 300.0;
    cfg.tick = 2.0;
    let mut rng = simfaas::sim::Rng::new(8 + seed_bump);
    let w = workload::poisson(1.5, 5_000.0, &mut rng);
    let res = Platform::new(cfg, None).run(&w).unwrap();
    let est = trace::mean_warm_pool(&res.records, 300.0, 600.0);
    let emu = res.metrics(600.0);
    // Window-based reconstruction undercounts instances idle longer than
    // the window; agreement within ~35% is what the method achieves (the
    // paper uses it only as an observational proxy).
    let err = (est - emu.avg_server_count).abs() / emu.avg_server_count;
    if err >= 0.35 {
        return Err(format!(
            "estimated pool {est} vs emulated {} (err {:.0}%)",
            emu.avg_server_count,
            err * 100.0
        ));
    }
    Ok(())
}
