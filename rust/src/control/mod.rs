//! Autoscaling control subsystem: feedback controllers that move fleet
//! capacity at simulated time (DESIGN.md §Control).
//!
//! Every capacity knob elsewhere in the repo is static for the whole run;
//! this module closes the loop. A [`Controller`] is observed and actuated
//! on a fixed simulated-time tick (`Event::ControlTick` through
//! `sim::core`'s event queue): each tick it sees the backend's utilization
//! signal and current capacity and returns a capacity delta, which the
//! fleet applies to one of two backends through the `ScalableCapacity`
//! seam in `fleet`:
//!
//! * the flat `FleetGate` cap — raised/lowered instantly (lowering never
//!   kills busy instances, it just stops admitting), or
//! * the cluster host set — scale-out adds warm hosts after the spec's
//!   provisioning delay; scale-in retires hosts through the existing
//!   drain-window cordon/evict machinery.
//!
//! Three implementations ship behind the serializable [`ControllerSpec`]
//! (`parse`/`as_str`/JSON round-trip like `cluster::SchedulerSpec`):
//! [`TargetTracking`], [`Pid`], and [`StepPolicy`].
//!
//! **Determinism contract** (the same shape as every prior layer): with no
//! controller configured, no tick is ever scheduled and the engines are
//! bit-identical to the uncontrolled run. A configured controller lives
//! with its capacity domain's single-queue loop — ticks are intercepted
//! before any engine sees them — so controlled runs are thread-count- and
//! (for fixed K) domain-count-invariant, and *inert* controllers
//! ([`TargetTracking`] with step limit 0, [`Pid`] with all gains 0) never
//! actuate and reproduce the uncontrolled engines bit-for-bit
//! (`tests/engine_unification.rs`). With K > 1 capacity domains each
//! domain runs its own controller instance over a proportional share of
//! the min/max capacity bounds, exactly like cap striping.

pub mod controller;
pub mod report;
pub mod spec;

pub use controller::{Controller, Pid, StepPolicy, TargetTracking};
pub use report::{ControlReport, ControlSample};
pub use spec::{ControllerKind, ControllerSpec};

/// Per-domain runtime control state: the controller instance, its striped
/// capacity bounds, and the samples it records. Lives inside the domain's
/// single-queue run loop (one per capacity domain), which is what makes
/// controlled runs thread-count-invariant.
pub struct ControlLoop {
    controller: Box<dyn Controller>,
    domain: u32,
    /// Simulated seconds between control ticks.
    pub tick_interval: f64,
    /// Host provisioning delay for the cluster backend (gate actuation is
    /// instant — see DESIGN.md §Control's actuation-delay model).
    pub provision_delay: f64,
    min_capacity: u64,
    max_capacity: u64,
    /// One record per tick, in tick order (per-domain; the fleet
    /// concatenates domains in domain order).
    pub samples: Vec<ControlSample>,
}

impl ControlLoop {
    /// Build domain `domain` of `domains`' control state. Capacity bounds
    /// stripe proportionally (`x / K`, remainder to the lowest domains) —
    /// the same split as the fleet cap itself.
    pub fn new(spec: &ControllerSpec, domain: usize, domains: usize) -> ControlLoop {
        let k = domains.max(1) as u64;
        let d = domain as u64;
        let stripe = |x: u64| x / k + u64::from(d < x % k);
        let min = stripe(spec.min_capacity);
        let max = if spec.max_capacity == 0 { u64::MAX } else { stripe(spec.max_capacity) };
        ControlLoop {
            controller: spec.kind.build(),
            domain: domain as u32,
            tick_interval: spec.tick_interval,
            provision_delay: spec.provision_delay,
            min_capacity: min,
            max_capacity: max.max(min),
            samples: Vec::new(),
        }
    }

    /// Simulated time of the first tick (one interval in — nothing has
    /// happened at t = 0).
    pub fn first_tick(&self) -> f64 {
        self.tick_interval
    }

    /// Run one control tick: feed the controller the observed utilization
    /// and current capacity, clamp its requested move into the domain's
    /// `[min, max]` bounds, record a [`ControlSample`], and return the new
    /// capacity target (equal to `capacity` when the controller holds).
    pub fn tick(&mut self, now: f64, observed: f64, capacity: u64) -> u64 {
        let delta = self.controller.actuate(now, observed, capacity);
        let moved = if delta >= 0 {
            capacity.saturating_add(delta as u64)
        } else {
            capacity.saturating_sub(delta.unsigned_abs())
        };
        let desired = moved.clamp(self.min_capacity, self.max_capacity);
        self.samples.push(ControlSample {
            domain: self.domain,
            t: now,
            observed,
            error: observed - self.controller.setpoint(),
            actuation: desired as i64 - capacity as i64,
            capacity: desired,
        });
        desired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_stripe_proportionally_across_domains() {
        let spec = ControllerSpec::parse("target:0.7;min=3;max=10").unwrap();
        let mins: Vec<u64> = (0..4).map(|d| ControlLoop::new(&spec, d, 4).min_capacity).collect();
        let maxs: Vec<u64> = (0..4).map(|d| ControlLoop::new(&spec, d, 4).max_capacity).collect();
        assert_eq!(mins, vec![1, 1, 1, 0]);
        assert_eq!(maxs, vec![3, 3, 2, 2]);
        // Unbounded max stays unbounded in every domain.
        let spec = ControllerSpec::parse("target:0.7").unwrap();
        assert_eq!(ControlLoop::new(&spec, 2, 4).max_capacity, u64::MAX);
    }

    #[test]
    fn tick_clamps_into_bounds_and_records_samples() {
        let spec = ControllerSpec::parse("step:0.2,0.8,5;min=2;max=6").unwrap();
        let mut ctl = ControlLoop::new(&spec, 0, 1);
        // Over the high threshold: +5 requested, clamped to max 6.
        assert_eq!(ctl.tick(10.0, 0.95, 4), 6);
        // Under the low threshold: -5 requested, clamped to min 2.
        assert_eq!(ctl.tick(20.0, 0.05, 6), 2);
        // In band: hold.
        assert_eq!(ctl.tick(30.0, 0.5, 2), 2);
        assert_eq!(ctl.samples.len(), 3);
        assert_eq!(ctl.samples[0].actuation, 2);
        assert_eq!(ctl.samples[1].actuation, -4);
        assert_eq!(ctl.samples[2].actuation, 0);
        assert_eq!(ctl.samples[2].capacity, 2);
        assert!((ctl.samples[0].error - 0.45).abs() < 1e-12, "setpoint is the band midpoint");
    }

    #[test]
    fn inert_controllers_never_actuate() {
        for s in ["target:0.7,60,0", "pid:0,0,0,0.7"] {
            let spec = ControllerSpec::parse(s).unwrap();
            let mut ctl = ControlLoop::new(&spec, 0, 1);
            for i in 1..=50u64 {
                let observed = (i % 7) as f64 / 3.0; // wildly out of band
                assert_eq!(ctl.tick(i as f64 * 10.0, observed, 8), 8, "{s}");
            }
            assert!(ctl.samples.iter().all(|s| s.actuation == 0));
        }
    }
}
