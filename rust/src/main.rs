//! `simfaas` — the SimFaaS command-line interface.
//!
//! Subcommands (run `simfaas help` for flags):
//!
//! * `steady`    — steady-state simulation (paper Table 1)
//! * `temporal`  — transient analysis with replications + CI (Fig. 4)
//! * `ensemble`  — multi-threaded replication ensemble, mean ± 95% CI per
//!                 metric; optional expiration-threshold grid
//! * `fleet`     — multi-function fleet simulation under a keep-alive
//!                 policy; optional fleet cap and policy-comparison sweep
//! * `sweep`     — what-if sweeps over rate × expiration threshold (Fig. 5)
//! * `emulate`   — run the platform emulator on a Poisson workload
//! * `validate`  — simulator-vs-emulator validation (Figs. 6–8)
//! * `compare`   — simulator vs the Markovian analytical baseline
//! * `cost`      — developer/provider cost estimation (paper §4.4)
//! * `identify`  — parameter identification from a trace CSV (paper §5.2)
//! * `probe`     — expiration-threshold probing against the emulator
//! * `figures`   — regenerate every paper table/figure (ASCII + CSV)

use anyhow::{bail, Context, Result};
use simfaas::cli::Args;
use simfaas::cost::{estimate, scale_to, FunctionConfig, PricingTable, Provider};
use simfaas::emulator::{EmulatorConfig, Platform};
use simfaas::figures;
use simfaas::output::json::results_to_json;
use simfaas::output::{ascii_histogram, ascii_lines, Series, Table};
use simfaas::sim::{
    InitialState, Process, ServerlessSimulator, ServerlessTemporalSimulator, SimConfig,
};
use simfaas::workload;
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        Some("steady") => cmd_steady(&args),
        Some("temporal") => cmd_temporal(&args),
        Some("ensemble") => cmd_ensemble(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("emulate") => cmd_emulate(&args),
        Some("validate") => cmd_validate(&args),
        Some("compare") => cmd_compare(&args),
        Some("cost") => cmd_cost(&args),
        Some("identify") => cmd_identify(&args),
        Some("probe") => cmd_probe(&args),
        Some("figures") => cmd_figures(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown command {other:?}; see `simfaas help`"),
    }?;
    args.check_unknown()
}

const HELP: &str = r#"simfaas — performance simulator for serverless platforms

usage: simfaas <command> [flags]

commands:
  steady     steady-state simulation (Table 1)
             --rate --warm --cold --threshold --max-concurrency
             --horizon --skip --seed --json
  temporal   transient analysis with CI (Fig. 4)
             --replications --horizon --interval --warm-pool --seed
  ensemble   multi-threaded replication ensemble: mean ± 95% CI per metric
             --replications --threads (0 = all cores) --rate --warm --cold
             --threshold --horizon --skip --seed
             [--thresholds a,b,c  parallel expiration-threshold grid]
  fleet      multi-function fleet simulation (synthetic Azure-style mix)
             --functions N --horizon --skip --seed --threads
             --policy fixed|adaptive --threshold (fixed)
             --range --bin (adaptive) --fleet-cap (0 = none)
             --provider --memory --top K --json
             [--compare-thresholds a,b,c  fixed grid vs adaptive sweep]
  sweep      what-if sweep (Fig. 5)
             --rates a,b,c --thresholds x,y --horizon --seed
  emulate    run the platform emulator
             --rate --horizon --scale --payload none|small|medium|large
             --threshold --csv out.csv
  validate   simulator vs emulator (Figs. 6-8)
             --rates a,b,c --emu-horizon --scale --sim-horizon --seed
  compare    simulator vs Markovian analytical model
             --rate --service --threshold --horizon --markovian-expiration
  cost       cost estimation  --rate --memory --provider --horizon --month
  identify   parameters from a trace CSV  --trace file.csv
  probe      expiration-threshold probe against the emulator
             --threshold --scale --step --max-gap
  figures    regenerate paper tables/figures
             --all | --fig 1|3|4|5|6 (6 covers 6-8) [--out-dir results/]
             [--quick]
"#;

fn sim_cfg_from_args(args: &Args) -> Result<SimConfig> {
    let mut cfg = SimConfig::table1();
    cfg.arrival = Process::exp_rate(args.get_f64("rate", 0.9)?);
    cfg.warm_service = Process::exp_mean(args.get_f64("warm", figures::WARM_MEAN)?);
    cfg.cold_service = Process::exp_mean(args.get_f64("cold", figures::COLD_MEAN)?);
    cfg.expiration_threshold = args.get_f64("threshold", 600.0)?;
    cfg.max_concurrency = args.get_usize("max-concurrency", 1000)?;
    cfg.horizon = args.get_f64("horizon", 1e6)?;
    cfg.skip_initial = args.get_f64("skip", 100.0)?;
    cfg.seed = args.get_u64("seed", 0x5EED)?;
    Ok(cfg)
}

fn cmd_steady(args: &Args) -> Result<()> {
    let cfg = sim_cfg_from_args(args)?;
    let results = ServerlessSimulator::new(cfg).run();
    if args.get_bool("json") {
        println!("{}", results_to_json(&results));
    } else {
        print!("{results}");
    }
    Ok(())
}

fn cmd_temporal(args: &Args) -> Result<()> {
    let mut cfg = sim_cfg_from_args(args)?;
    cfg.horizon = args.get_f64("horizon", 10_000.0)?;
    cfg.sample_interval = args.get_f64("interval", cfg.horizon / 100.0)?;
    let reps = args.get_usize("replications", 10)?;
    let warm_pool = args.get_usize("warm-pool", 0)?;
    let init = if warm_pool > 0 {
        InitialState::warm_pool(warm_pool)
    } else {
        InitialState::empty()
    };
    let res = ServerlessTemporalSimulator::new(cfg, init, reps).run();
    let band = res.average_count_band();
    let series = vec![
        Series::new("mean", band.iter().map(|&(t, m, _)| (t, m)).collect()),
        Series::new("mean+ci", band.iter().map(|&(t, m, h)| (t, m + h)).collect()),
        Series::new("mean-ci", band.iter().map(|&(t, m, h)| (t, m - h)).collect()),
    ];
    println!("Average instance count over time ({reps} runs, 95% CI):");
    print!("{}", ascii_lines(&series, 72, 18));
    let (m, hw) = res.avg_server_count_ci;
    println!("final avg server count: {m:.4} ± {hw:.4} (95% CI)");
    let (pc, pch) = res.cold_start_prob_ci;
    println!("cold start probability: {:.4}% ± {:.4}%", pc * 100.0, pch * 100.0);
    Ok(())
}

fn cmd_ensemble(args: &Args) -> Result<()> {
    use simfaas::sim::ensemble::{run_ensemble, EnsembleOpts};
    let cfg = sim_cfg_from_args(args)?;
    let replications = args.get_usize("replications", 10)?;
    if replications == 0 {
        bail!("--replications must be at least 1");
    }
    let opts = EnsembleOpts {
        replications,
        threads: args.get_usize("threads", 0)?,
        root_seed: cfg.seed,
    };
    let thresholds = args.get_f64_list("thresholds", &[])?;
    if thresholds.is_empty() {
        let res = run_ensemble(&cfg, &opts);
        print!("{}", res.summary().to_table());
    } else {
        let out = simfaas::whatif::expiration_threshold_ensemble(&cfg, &thresholds, &opts);
        println!(
            "{} replications per threshold, 95% CI half-widths:",
            opts.replications
        );
        let mut t = Table::new(vec![
            "threshold s",
            "p_cold %",
            "avg servers",
            "waste %",
        ]);
        for (th, res) in &out {
            let p = res.ci_of(|r| r.cold_start_prob);
            let s = res.ci_of(|r| r.avg_server_count);
            let w = res.ci_of(|r| r.wasted_capacity);
            t.row(vec![
                format!("{th:.0}"),
                format!("{:.4} ± {:.4}", p.mean * 100.0, p.ci_half * 100.0),
                format!("{:.4} ± {:.4}", s.mean, s.ci_half),
                format!("{:.3} ± {:.3}", w.mean * 100.0, w.ci_half * 100.0),
            ]);
        }
        print!("{t}");
    }
    Ok(())
}

fn provider_from_args(args: &Args) -> Result<Provider> {
    Ok(match args.get_str("provider", "aws").as_str() {
        "aws" => Provider::AwsLambda,
        "gcf" | "google" => Provider::GoogleCloudFunctions,
        "azure" => Provider::AzureFunctions,
        "ibm" => Provider::IbmCloudFunctions,
        other => bail!("unknown provider {other:?}"),
    })
}

fn cmd_fleet(args: &Args) -> Result<()> {
    use simfaas::fleet::{fleet_cost, FleetConfig, PolicySpec};
    use simfaas::output::json::fleet_to_json;
    use simfaas::workload::SyntheticTrace;

    let n = args.get_usize("functions", 50)?;
    if n == 0 {
        bail!("--functions must be at least 1");
    }
    let horizon = args.get_f64("horizon", 86_400.0)?;
    let skip = args.get_f64("skip", 0.0)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let threads = args.get_usize("threads", 0)?;
    // Consume both policy parameter sets up front so e.g. `--threshold`
    // with `--policy adaptive` is ignored rather than an unknown flag.
    let threshold = args.get_f64("threshold", 600.0)?;
    let range = args.get_f64("range", 3_600.0)?;
    let bin = args.get_f64("bin", 60.0)?;
    let adaptive = PolicySpec::hybrid_histogram(range, bin);
    let policy = match args.get_str("policy", "fixed").as_str() {
        "fixed" => PolicySpec::fixed(threshold),
        "adaptive" => adaptive.clone(),
        other => bail!("unknown policy {other:?} (expected fixed|adaptive)"),
    };

    let mut rng = simfaas::sim::Rng::new(seed);
    let trace = SyntheticTrace::generate(n, &mut rng);
    let mut cfg = FleetConfig::from_trace(&trace, horizon, skip, seed, policy);
    cfg.threads = threads;
    let cap = args.get_usize("fleet-cap", 0)?;
    if cap > 0 {
        cfg.fleet_max_concurrency = Some(cap);
    }
    let memory = args.get_f64("memory", 128.0)?;
    for f in &mut cfg.functions {
        f.memory_mb = memory;
    }
    let pricing = PricingTable::for_provider(provider_from_args(args)?);
    // Consume the reporting flags up front: they are no-ops in the
    // comparison branch but must not read as unknown flags there.
    let json_out = args.get_bool("json");
    let top_k = args.get_usize("top", 5)?;

    let compare = args.get_f64_list("compare-thresholds", &[])?;
    if !compare.is_empty() {
        let outcomes = simfaas::whatif::keepalive_policy_comparison(
            &cfg,
            &compare,
            std::slice::from_ref(&adaptive),
            &pricing,
        );
        println!(
            "{} functions, horizon {horizon} s, seed {seed}: keep-alive policy comparison",
            cfg.functions.len()
        );
        let mut t = Table::new(vec![
            "policy",
            "p_cold %",
            "rejected",
            "avg servers",
            "waste %",
            "dev cost $",
            "infra cost $",
        ]);
        for o in &outcomes {
            let a = &o.results.aggregate;
            t.row(vec![
                o.label.clone(),
                format!("{:.4}", a.cold_start_prob * 100.0),
                format!("{}", a.rejected_requests),
                format!("{:.3}", a.avg_server_count),
                format!("{:.2}", a.wasted_capacity * 100.0),
                format!("{:.4}", o.cost.total.developer_total()),
                format!("{:.4}", o.cost.total.provider_infra_cost),
            ]);
        }
        print!("{t}");
        return Ok(());
    }

    let results = cfg.run();
    let cost = fleet_cost(&cfg, &results, &pricing);
    if json_out {
        println!("{}", fleet_to_json(&results, Some(&cost)));
        return Ok(());
    }
    println!(
        "fleet: {} functions under {} (horizon {horizon} s, seed {seed})",
        cfg.functions.len(),
        cfg.policy.describe()
    );
    print!("{}", results.aggregate.to_table());
    println!(
        "developer cost ${:.4} (requests ${:.4} + runtime ${:.4}) | provider infra ${:.4}",
        cost.total.developer_total(),
        cost.total.request_charges,
        cost.total.runtime_charges,
        cost.total.provider_infra_cost
    );
    let top = top_k.min(results.per_function.len());
    if top > 0 {
        let mut order: Vec<usize> = (0..results.per_function.len()).collect();
        order.sort_by(|&a, &b| {
            results.per_function[b]
                .total_requests
                .cmp(&results.per_function[a].total_requests)
        });
        let mut t = Table::new(vec![
            "function",
            "requests",
            "p_cold %",
            "avg servers",
            "billed s",
        ]);
        for &i in order.iter().take(top) {
            let r = &results.per_function[i];
            t.row(vec![
                results.names[i].clone(),
                format!("{}", r.total_requests),
                format!("{:.4}", r.cold_start_prob * 100.0),
                format!("{:.4}", r.avg_server_count),
                format!("{:.1}", r.billed_instance_seconds),
            ]);
        }
        println!("top {top} functions by request volume:");
        print!("{t}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let rates = args.get_f64_list("rates", &[0.1, 0.3, 0.5, 0.9, 1.5, 2.5])?;
    let thresholds = args.get_f64_list("thresholds", &[120.0, 300.0, 600.0, 1200.0])?;
    let horizon = args.get_f64("horizon", 200_000.0)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let out = figures::fig5_sweep(&rates, &thresholds, horizon, seed);
    let mut table = Table::new(
        std::iter::once("rate".to_string())
            .chain(out.iter().map(|(th, _)| format!("p_cold@{th}s")))
            .collect::<Vec<_>>(),
    );
    for (i, &rate) in rates.iter().enumerate() {
        let mut row = vec![rate];
        for (_, series) in &out {
            row.push(series[i].1 * 100.0);
        }
        table.row_f64(&row, 4);
    }
    println!("Cold start probability (%) vs arrival rate x expiration threshold:");
    print!("{table}");
    let series: Vec<Series> = out
        .iter()
        .map(|(th, s)| Series::new(format!("{th} s"), s.clone()))
        .collect();
    print!("{}", ascii_lines(&series, 72, 18));
    Ok(())
}

fn emulator_cfg_from_args(
    args: &Args,
) -> Result<(EmulatorConfig, Option<Arc<simfaas::runtime::ComputePool>>)> {
    use simfaas::runtime::{ComputePool, PayloadKind};
    use simfaas::sim::ExpProcess;
    let scale = args.get_f64("scale", 2_000.0)?;
    let mut cfg = EmulatorConfig::lambda_like(scale);
    cfg.expiration_threshold = args.get_f64("threshold", 600.0)?;
    cfg.synthetic_service = Some(Arc::new(ExpProcess::with_mean(
        args.get_f64("warm", figures::WARM_MEAN)?,
    )));
    cfg.provisioning_delay =
        args.get_f64("provisioning", figures::COLD_MEAN - figures::WARM_MEAN)?;
    let payload = args.get_str("payload", "none");
    let pool = match payload.as_str() {
        "none" => None,
        name => {
            let kind = match name {
                "small" => PayloadKind::Small,
                "medium" => PayloadKind::Medium,
                "large" => PayloadKind::Large,
                other => bail!("unknown payload {other:?}"),
            };
            cfg.payload = Some(kind);
            cfg.payload_reps = args.get_u64("payload-reps", 1)? as u32;
            cfg.app_init_reps = args.get_u64("app-init-reps", 2)? as u32;
            let workers = args.get_usize("pool-workers", 4)?;
            Some(Arc::new(ComputePool::new(
                simfaas::runtime::default_artifacts_dir(),
                workers,
            )?))
        }
    };
    Ok((cfg, pool))
}

fn cmd_emulate(args: &Args) -> Result<()> {
    let (cfg, pool) = emulator_cfg_from_args(args)?;
    let rate = args.get_f64("rate", 0.9)?;
    let horizon = args.get_f64("horizon", 10_000.0)?;
    let seed = args.get_u64("seed", 7)?;
    let skip = args.get_f64("skip", 300.0)?;
    let mut rng = simfaas::sim::Rng::new(seed);
    let w = workload::poisson(rate, horizon, &mut rng);
    println!(
        "emulating {} requests over {horizon} virtual s (scale {}x)...",
        w.len(),
        cfg.time_scale
    );
    let platform = Platform::new(cfg, pool);
    let t0 = std::time::Instant::now();
    let res = platform.run(&w)?;
    let wall = t0.elapsed().as_secs_f64();
    let m = res.metrics(skip);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["cold start prob".to_string(), format!("{:.4} %", m.cold_start_prob * 100.0)]);
    t.row(vec!["rejection prob".to_string(), format!("{:.4} %", m.rejection_prob * 100.0)]);
    t.row(vec!["avg server count".to_string(), format!("{:.4}", m.avg_server_count)]);
    t.row(vec!["avg running".to_string(), format!("{:.4}", m.avg_running_count)]);
    t.row(vec!["avg idle".to_string(), format!("{:.4}", m.avg_idle_count)]);
    t.row(vec!["wasted capacity".to_string(), format!("{:.4} %", m.wasted_capacity * 100.0)]);
    t.row(vec!["avg warm response".to_string(), format!("{:.4} s", m.avg_warm_response)]);
    t.row(vec!["avg cold response".to_string(), format!("{:.4} s", m.avg_cold_response)]);
    t.row(vec!["instances".to_string(), format!("{}", res.instances.len())]);
    t.row(vec!["wall time".to_string(), format!("{wall:.2} s")]);
    print!("{t}");
    if let Some(path) = args.get("csv") {
        let path = path.to_string();
        let f = std::fs::File::create(&path).with_context(|| format!("creating {path}"))?;
        simfaas::trace::write_csv(std::io::BufWriter::new(f), &res.records)?;
        println!("trace written to {path}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let rates = args.get_f64_list("rates", &[0.5, 1.0, 2.0])?;
    let opts = figures::ValidationOpts {
        emu_horizon: args.get_f64("emu-horizon", 40_000.0)?,
        time_scale: args.get_f64("scale", 4_000.0)?,
        sim_horizon: args.get_f64("sim-horizon", 400_000.0)?,
        skip: args.get_f64("skip", 600.0)?,
        seed: args.get_u64("seed", 0xF16)?,
    };
    let rows = figures::validation_rows(&rates, &opts);
    print_validation(&rows);
    Ok(())
}

fn print_validation(rows: &[figures::ValidationRow]) {
    let mut t = Table::new(vec![
        "rate",
        "sim p_cold%",
        "emu p_cold%",
        "sim servers",
        "emu servers",
        "sim waste%",
        "emu waste%",
    ]);
    for r in rows {
        t.row_f64(
            &[
                r.rate,
                r.sim.cold_start_prob * 100.0,
                r.emu.cold_start_prob * 100.0,
                r.sim.avg_server_count,
                r.emu.avg_server_count,
                r.sim.wasted_capacity * 100.0,
                r.emu.wasted_capacity * 100.0,
            ],
            3,
        );
    }
    print!("{t}");
    let (e6, e7, e8) = figures::validation_errors(rows);
    println!(
        "Fig6 avg %err (p_cold): {e6:.2}%   Fig7 MAPE (servers): {e7:.2}%   Fig8 MAPE (waste): {e8:.2}%"
    );
    println!("(paper: 12.75%, 3.43%, 0.17%)");
}

fn cmd_compare(args: &Args) -> Result<()> {
    use simfaas::analytical;
    let mut cfg = sim_cfg_from_args(args)?;
    let service = args.get_f64("service", figures::WARM_MEAN)?;
    cfg.cold_service = Process::exp_mean(service);
    cfg.warm_service = Process::exp_mean(service);
    let report = if args.get_bool("markovian-expiration") {
        analytical::compare_steady_state_markovian(&cfg, service)
    } else {
        analytical::compare_steady_state(&cfg, service)
    };
    print!("{}", report.to_table());
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let cfg = sim_cfg_from_args(args)?;
    let results = ServerlessSimulator::new(cfg).run();
    let provider = provider_from_args(args)?;
    let f = FunctionConfig::new(args.get_f64("memory", 128.0)?);
    let est = estimate(&results, &f, &PricingTable::for_provider(provider));
    let month = scale_to(&est, 30.0 * 86_400.0);
    let mut t = Table::new(vec!["item", "per window", "per 30 days"]);
    t.row(vec![
        "requests".to_string(),
        format!("{:.0}", est.requests),
        format!("{:.0}", month.requests),
    ]);
    t.row(vec![
        "GB-seconds".to_string(),
        format!("{:.1}", est.gb_seconds),
        format!("{:.1}", month.gb_seconds),
    ]);
    t.row(vec![
        "request charges".to_string(),
        format!("${:.4}", est.request_charges),
        format!("${:.2}", month.request_charges),
    ]);
    t.row(vec![
        "runtime charges".to_string(),
        format!("${:.4}", est.runtime_charges),
        format!("${:.2}", month.runtime_charges),
    ]);
    t.row(vec![
        "developer total".to_string(),
        format!("${:.4}", est.developer_total()),
        format!("${:.2}", month.developer_total()),
    ]);
    t.row(vec![
        "provider infra cost".to_string(),
        format!("${:.4}", est.provider_infra_cost),
        format!("${:.2}", month.provider_infra_cost),
    ]);
    print!("{t}");
    println!(
        "cold start prob {:.4}% | avg servers {:.3} | wasted {:.1}%",
        results.cold_start_prob * 100.0,
        results.avg_server_count,
        results.wasted_capacity * 100.0
    );
    Ok(())
}

fn cmd_identify(args: &Args) -> Result<()> {
    let path = args.get("trace").context("--trace <file.csv> is required")?.to_string();
    let f = std::fs::File::open(&path).with_context(|| format!("opening {path}"))?;
    let records = simfaas::trace::read_csv(std::io::BufReader::new(f))?;
    let p = simfaas::trace::identify(&records);
    let pool = simfaas::trace::mean_warm_pool(&records, 600.0, 600.0);
    let mut t = Table::new(vec!["parameter", "estimate"]);
    t.row(vec!["arrival rate".to_string(), format!("{:.4} req/s", p.arrival_rate)]);
    t.row(vec!["warm mean".to_string(), format!("{:.4} s (std {:.4})", p.warm_mean, p.warm_std)]);
    t.row(vec!["cold mean".to_string(), format!("{:.4} s (std {:.4})", p.cold_mean, p.cold_std)]);
    t.row(vec!["cold start prob".to_string(), format!("{:.4} %", p.cold_start_prob * 100.0)]);
    t.row(vec!["rejection prob".to_string(), format!("{:.4} %", p.rejection_prob * 100.0)]);
    t.row(vec!["warm pool (10 min window)".to_string(), format!("{pool:.3}")]);
    print!("{t}");
    Ok(())
}

fn cmd_probe(args: &Args) -> Result<()> {
    use simfaas::emulator::EmulatorProbe;
    use simfaas::trace::probe_expiration_threshold;
    let mut cfg = EmulatorConfig::lambda_like(args.get_f64("scale", 10_000.0)?);
    cfg.expiration_threshold = args.get_f64("threshold", 600.0)?;
    cfg.synthetic_service = Some(Arc::new(simfaas::sim::ConstProcess::new(1.0)));
    cfg.provisioning_delay = 0.25;
    cfg.tick = 1.0;
    let step = args.get_f64("step", 60.0)?;
    let max_gap = args.get_f64("max-gap", 1_500.0)?;
    println!(
        "probing emulator (true threshold {} s) with step {} s...",
        cfg.expiration_threshold, step
    );
    let mut probe = EmulatorProbe::new(cfg);
    let (lo, hi) = probe_expiration_threshold(&mut probe, step, step, max_gap);
    println!("expiration threshold bracketed in ({lo:.1} s, {hi:.1} s]");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let all = args.get_bool("all");
    let which = args.get_u64("fig", 0)?;
    let out_dir = args.get_str("out-dir", "results");
    std::fs::create_dir_all(&out_dir)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let quick = args.get_bool("quick");
    let horizon = if quick { 100_000.0 } else { 1e6 };

    if all || which == 0 {
        println!("=== Table 1: steady-state example ===");
        let r = figures::table1(horizon, seed);
        print!("{r}");
        simfaas::output::write_csv_rows(
            format!("{out_dir}/table1.csv"),
            &[
                "cold_start_prob",
                "rejection_prob",
                "avg_lifespan",
                "avg_server",
                "avg_running",
                "avg_idle",
            ],
            &[vec![
                r.cold_start_prob,
                r.rejection_prob,
                r.avg_lifespan,
                r.avg_server_count,
                r.avg_running_count,
                r.avg_idle_count,
            ]],
        )?;
    }
    if all || which == 1 {
        println!("\n=== Fig 1: concurrency value (c=1 vs c=3) ===");
        use simfaas::sim::ParServerlessSimulator;
        let cfg = SimConfig::table1().with_arrival_rate(3.0).with_horizon(horizon.min(2e5));
        let r1 = ParServerlessSimulator::new(cfg.clone(), 1).run();
        let r3 = ParServerlessSimulator::new(cfg, 3).run();
        let mut t = Table::new(vec!["concurrency value", "avg servers", "p_cold %"]);
        t.row_f64(&[1.0, r1.avg_server_count, r1.cold_start_prob * 100.0], 4);
        t.row_f64(&[3.0, r3.avg_server_count, r3.cold_start_prob * 100.0], 4);
        print!("{t}");
    }
    if all || which == 3 {
        println!("\n=== Fig 3: instance count distribution ===");
        let pmf = figures::fig3_distribution(horizon, seed);
        let labels: Vec<String> = (0..pmf.len()).map(|i| i.to_string()).collect();
        print!("{}", ascii_histogram(&labels, &pmf, 48));
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig3.csv"),
            &["count", "p"],
            &pmf.iter().enumerate().map(|(i, &p)| vec![i as f64, p]).collect::<Vec<_>>(),
        )?;
    }
    if all || which == 4 {
        println!("\n=== Fig 4: avg instance count over time (10 runs, 95% CI) ===");
        let band = figures::fig4_band(if quick { 20_000.0 } else { 100_000.0 }, 200.0, 10, seed);
        let series = vec![
            Series::new("mean", band.iter().map(|&(t, m, _)| (t, m)).collect()),
            Series::new("mean+ci", band.iter().map(|&(t, m, h)| (t, m + h)).collect()),
            Series::new("mean-ci", band.iter().map(|&(t, m, h)| (t, m - h)).collect()),
        ];
        print!("{}", ascii_lines(&series, 72, 16));
        let last = band.last().unwrap();
        println!(
            "final: {:.4} ± {:.4} ({:.2}% of mean)",
            last.1,
            last.2,
            100.0 * last.2 / last.1
        );
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig4.csv"),
            &["t", "mean", "ci95_half_width"],
            &band.iter().map(|&(t, m, h)| vec![t, m, h]).collect::<Vec<_>>(),
        )?;
    }
    if all || which == 5 {
        println!("\n=== Fig 5: p_cold vs rate x threshold ===");
        let rates = [0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.2, 1.5, 2.0, 2.5, 3.0];
        let thresholds = [120.0, 300.0, 600.0, 1200.0];
        let out = figures::fig5_sweep(&rates, &thresholds, horizon.min(3e5), seed);
        let series: Vec<Series> = out
            .iter()
            .map(|(th, s)| {
                Series::new(format!("{th} s"), s.iter().map(|&(r, p)| (r, p * 100.0)).collect())
            })
            .collect();
        print!("{}", ascii_lines(&series, 72, 18));
        let rows: Vec<Vec<f64>> = rates
            .iter()
            .enumerate()
            .map(|(i, &r)| std::iter::once(r).chain(out.iter().map(|(_, s)| s[i].1)).collect())
            .collect();
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig5.csv"),
            &["rate", "p_cold_120s", "p_cold_300s", "p_cold_600s", "p_cold_1200s"],
            &rows,
        )?;
    }
    if all || which == 6 {
        println!("\n=== Figs 6-8: validation (simulator vs emulator) ===");
        let rates = if quick {
            vec![0.5, 1.0, 2.0]
        } else {
            vec![0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0]
        };
        let opts = figures::ValidationOpts {
            emu_horizon: if quick { 10_000.0 } else { 40_000.0 },
            ..Default::default()
        };
        let rows = figures::validation_rows(&rates, &opts);
        print_validation(&rows);
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig6_7_8.csv"),
            &[
                "rate",
                "sim_p_cold",
                "emu_p_cold",
                "sim_servers",
                "emu_servers",
                "sim_waste",
                "emu_waste",
            ],
            &rows
                .iter()
                .map(|r| {
                    vec![
                        r.rate,
                        r.sim.cold_start_prob,
                        r.emu.cold_start_prob,
                        r.sim.avg_server_count,
                        r.emu.avg_server_count,
                        r.sim.wasted_capacity,
                        r.emu.wasted_capacity,
                    ]
                })
                .collect::<Vec<_>>(),
        )?;
    }
    println!("\nCSV outputs in {out_dir}/");
    Ok(())
}
