//! `sim::ensemble` — deterministic multi-threaded replication engine.
//!
//! The paper's headline use-cases (cold-start probability, transient CI
//! bands, what-if sweeps) all average many independent replications, and
//! the ROADMAP's "fast as the hardware allows" goal makes replication the
//! cheapest axis to parallelize: replications share nothing, so they scale
//! linearly with cores. This module provides:
//!
//! * [`derive_seeds`] — per-replication seeds expanded from one root seed
//!   via SplitMix64, so an ensemble is fully described by
//!   `(config, root_seed, replications)`.
//! * [`run_indexed`] — the scheduling primitive: a scoped thread pool that
//!   maps `f(0..n)` into an index-ordered `Vec`. Work distribution over
//!   threads is racy (an atomic ticket counter), but results land in their
//!   index slot and every replication's inputs depend only on its index —
//!   so the output is **bit-identical for any thread count**, including 1.
//! * [`run_ensemble`] / [`run_par_ensemble`] — replication ensembles over
//!   [`ServerlessSimulator`] / [`super::par_simulator::ParServerlessSimulator`],
//!   aggregated into per-metric mean ± 95% confidence intervals.
//!
//! Determinism contract: replication `i` simulates `cfg.replica_with_seed
//! (seeds[i])` — stateful built-in processes (MMPP) are re-created per
//! replication so threads never share mutable process state. The one
//! escape: a stateful `Process::Custom` is shared as-is (the trait cannot
//! re-create it); such configs are still *seed*-deterministic only under a
//! single thread.

use super::metrics::confidence_interval_95;
use super::par_simulator::ParServerlessSimulator;
use super::results::SimResults;
use super::rng::SplitMix64;
use super::simulator::{ServerlessSimulator, SimConfig};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Expand one root seed into `n` per-replication seeds (SplitMix64 stream).
pub fn derive_seeds(root_seed: u64, n: usize) -> Vec<u64> {
    let mut sm = SplitMix64::new(root_seed);
    (0..n).map(|_| sm.next_u64()).collect()
}

/// Map `f` over `0..n` on `threads` worker threads (0 = one per available
/// core), returning results in index order. `f(i)` must depend only on `i`
/// for the output to be thread-count-invariant — which is exactly how the
/// ensemble runners call it.
pub fn run_indexed<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = if threads == 0 {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(n)
    } else {
        threads.min(n)
    };
    if workers <= 1 {
        // Inline fast path: no pool, no locks — and the reference order
        // against which the multi-threaded path is bit-compared in tests.
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let slots = Mutex::new(slots);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots
        .into_inner()
        .expect("no worker panicked holding the slot lock")
        .into_iter()
        .map(|s| s.expect("worker filled every slot"))
        .collect()
}

/// Ensemble parameters.
#[derive(Debug, Clone, Copy)]
pub struct EnsembleOpts {
    /// Number of independent replications.
    pub replications: usize,
    /// Worker threads; 0 = one per available core.
    pub threads: usize,
    /// Root seed; per-replication seeds derive from it via SplitMix64.
    pub root_seed: u64,
}

impl EnsembleOpts {
    pub fn new(replications: usize, root_seed: u64) -> Self {
        EnsembleOpts { replications, threads: 0, root_seed }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }
}

/// Mean and 95% confidence half-width of one metric across replications.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricCi {
    pub mean: f64,
    pub ci_half: f64,
}

/// All replication results of one ensemble, in replication order.
#[derive(Debug, Clone)]
pub struct EnsembleResults {
    /// Per-replication seeds (index-aligned with `runs`).
    pub seeds: Vec<u64>,
    pub runs: Vec<SimResults>,
}

impl EnsembleResults {
    /// Mean ± 95% CI of an arbitrary metric extractor across replications.
    pub fn ci_of<F: Fn(&SimResults) -> f64>(&self, f: F) -> MetricCi {
        let xs: Vec<f64> = self.runs.iter().map(f).collect();
        if xs.len() < 2 {
            MetricCi { mean: xs.first().copied().unwrap_or(f64::NAN), ci_half: 0.0 }
        } else {
            let (mean, ci_half) = confidence_interval_95(&xs);
            MetricCi { mean, ci_half }
        }
    }

    /// Aggregate the paper's Table-1 metrics into mean ± 95% CI.
    pub fn summary(&self) -> EnsembleSummary {
        EnsembleSummary {
            replications: self.runs.len(),
            cold_start_prob: self.ci_of(|r| r.cold_start_prob),
            rejection_prob: self.ci_of(|r| r.rejection_prob),
            avg_server_count: self.ci_of(|r| r.avg_server_count),
            avg_running_count: self.ci_of(|r| r.avg_running_count),
            avg_idle_count: self.ci_of(|r| r.avg_idle_count),
            wasted_capacity: self.ci_of(|r| r.wasted_capacity),
            avg_response_time: self.ci_of(|r| r.avg_response_time),
            response_p95: self.ci_of(|r| r.response_p95),
            billed_instance_seconds: self.ci_of(|r| r.billed_instance_seconds),
        }
    }
}

/// Per-metric mean ± 95% CI across an ensemble (the Table 1 output rows
/// with error bars, which a single run cannot provide).
#[derive(Debug, Clone)]
pub struct EnsembleSummary {
    pub replications: usize,
    pub cold_start_prob: MetricCi,
    pub rejection_prob: MetricCi,
    pub avg_server_count: MetricCi,
    pub avg_running_count: MetricCi,
    pub avg_idle_count: MetricCi,
    pub wasted_capacity: MetricCi,
    pub avg_response_time: MetricCi,
    pub response_p95: MetricCi,
    pub billed_instance_seconds: MetricCi,
}

impl EnsembleSummary {
    /// Two-column report: metric, mean ± 95% CI half-width.
    pub fn to_table(&self) -> String {
        let pct = |m: &MetricCi| format!("{:.4} % ± {:.4}", m.mean * 100.0, m.ci_half * 100.0);
        let num = |m: &MetricCi| format!("{:.4} ± {:.4}", m.mean, m.ci_half);
        let rows = [
            ("*Cold Start Probability", pct(&self.cold_start_prob)),
            ("*Rejection Probability", pct(&self.rejection_prob)),
            ("*Average Server Count", num(&self.avg_server_count)),
            ("*Average Running Servers", num(&self.avg_running_count)),
            ("*Average Idle Count", num(&self.avg_idle_count)),
            ("*Average Wasted Capacity", pct(&self.wasted_capacity)),
            ("*Average Response Time", num(&self.avg_response_time)),
            ("*Response Time P95", num(&self.response_p95)),
            ("Billed instance-seconds", num(&self.billed_instance_seconds)),
        ];
        let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        let mut s = format!("{} replications, 95% CI half-widths:\n", self.replications);
        for (k, v) in rows {
            s.push_str(&format!("{k:<w$}  {v}\n"));
        }
        s
    }
}

/// Run a replication ensemble of [`ServerlessSimulator`] over `cfg`.
/// Bit-identical output for any `opts.threads` given the same root seed.
pub fn run_ensemble(cfg: &SimConfig, opts: &EnsembleOpts) -> EnsembleResults {
    assert!(opts.replications >= 1);
    let seeds = derive_seeds(opts.root_seed, opts.replications);
    let runs = run_indexed(opts.replications, opts.threads, |i| {
        ServerlessSimulator::new(cfg.replica_with_seed(seeds[i])).run()
    });
    EnsembleResults { seeds, runs }
}

/// Same, for the concurrency-value-`c` [`ParServerlessSimulator`].
pub fn run_par_ensemble(
    cfg: &SimConfig,
    concurrency_value: u32,
    opts: &EnsembleOpts,
) -> EnsembleResults {
    assert!(opts.replications >= 1);
    let seeds = derive_seeds(opts.root_seed, opts.replications);
    let runs = run_indexed(opts.replications, opts.threads, |i| {
        ParServerlessSimulator::new(cfg.replica_with_seed(seeds[i]), concurrency_value).run()
    });
    EnsembleResults { seeds, runs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SimConfig {
        SimConfig::table1().with_horizon(5_000.0)
    }

    fn fingerprint(res: &EnsembleResults) -> Vec<u64> {
        let mut fp = Vec::new();
        for r in &res.runs {
            fp.push(r.total_requests);
            fp.push(r.cold_requests);
            fp.push(r.avg_server_count.to_bits());
            fp.push(r.billed_instance_seconds.to_bits());
        }
        fp
    }

    #[test]
    fn derive_seeds_is_deterministic_and_distinct() {
        let a = derive_seeds(42, 16);
        let b = derive_seeds(42, 16);
        assert_eq!(a, b);
        let mut dedup = a.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 16, "seeds must be distinct");
        assert_ne!(derive_seeds(43, 16), a);
    }

    #[test]
    fn run_indexed_preserves_order_across_thread_counts() {
        let seq: Vec<usize> = run_indexed(64, 1, |i| i * i);
        for threads in [2, 3, 8] {
            assert_eq!(run_indexed(64, threads, |i| i * i), seq);
        }
        assert!(run_indexed(0, 4, |i| i).is_empty());
    }

    #[test]
    fn ensemble_bit_identical_across_thread_counts() {
        let cfg = quick_cfg();
        let base = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xE15).with_threads(1));
        for threads in [2, 8] {
            let res = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xE15).with_threads(threads));
            assert_eq!(fingerprint(&res), fingerprint(&base), "threads={threads}");
        }
    }

    #[test]
    fn different_root_seeds_differ() {
        let cfg = quick_cfg();
        let a = run_ensemble(&cfg, &EnsembleOpts::new(4, 1));
        let b = run_ensemble(&cfg, &EnsembleOpts::new(4, 2));
        assert_ne!(fingerprint(&a), fingerprint(&b));
    }

    #[test]
    fn summary_ci_is_sane() {
        let cfg = quick_cfg();
        let res = run_ensemble(&cfg, &EnsembleOpts::new(6, 7));
        let s = res.summary();
        assert_eq!(s.replications, 6);
        assert!(s.avg_server_count.mean > 0.0);
        assert!(s.avg_server_count.ci_half >= 0.0);
        // Decomposition holds for the aggregated means too.
        assert!(
            (s.avg_server_count.mean - s.avg_running_count.mean - s.avg_idle_count.mean).abs()
                < 1e-9
        );
        let table = s.to_table();
        assert!(table.contains("Cold Start Probability"));
        assert!(table.contains("95% CI"));
    }

    #[test]
    fn single_replication_has_zero_ci() {
        let res = run_ensemble(&quick_cfg(), &EnsembleOpts::new(1, 3));
        assert_eq!(res.runs.len(), 1);
        assert_eq!(res.summary().cold_start_prob.ci_half, 0.0);
    }

    #[test]
    fn par_ensemble_runs_and_is_deterministic() {
        let cfg = quick_cfg().with_arrival_rate(3.0);
        let a = run_par_ensemble(&cfg, 3, &EnsembleOpts::new(4, 9).with_threads(1));
        let b = run_par_ensemble(&cfg, 3, &EnsembleOpts::new(4, 9).with_threads(4));
        assert_eq!(fingerprint(&a), fingerprint(&b));
        assert!(a.summary().avg_server_count.mean > 0.0);
    }
}
