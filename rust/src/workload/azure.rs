//! Synthetic Azure-Functions-style workload traces.
//!
//! The paper cites Shahrad et al. 2020 ("Serverless in the Wild") for
//! platform behaviour; that work characterizes production Azure Functions
//! invocation patterns: a heavy-tailed popularity distribution across
//! functions, strong diurnal cycles, and a large mass of rarely-invoked
//! functions. We have no access to the production trace (repro gate), so
//! this module generates synthetic traces with those published
//! characteristics — the substitution documented in DESIGN.md §3. They
//! exercise the same code paths: per-function workloads, trace-driven
//! simulation and what-if sweeps over heterogeneous functions.

use super::generator::{nonhomogeneous, Workload};
use crate::sim::rng::Rng;

/// One synthetic function's workload profile.
#[derive(Debug, Clone)]
pub struct FunctionProfile {
    pub name: String,
    /// Mean invocation rate (req/s) averaged over a day.
    pub mean_rate: f64,
    /// Diurnal modulation depth in [0,1): rate(t) = mean*(1 + depth*sin).
    pub diurnal_depth: f64,
    /// Phase offset of the daily peak, seconds.
    pub peak_offset: f64,
    /// Mean warm service time (s).
    pub warm_service_mean: f64,
    /// Mean cold service time (s).
    pub cold_service_mean: f64,
}

/// A bundle of functions approximating an Azure-style tenant mix.
#[derive(Debug, Clone)]
pub struct SyntheticTrace {
    pub functions: Vec<FunctionProfile>,
}

impl SyntheticTrace {
    /// Generate `n` functions whose mean rates follow a Pareto popularity
    /// distribution (alpha ~ 1.1, per Shahrad et al.'s heavy tail), with
    /// random diurnal depth and phase, and a CPU/IO service-time mix
    /// (paper §5: "a combination of CPU intensive and I/O intensive
    /// workloads").
    pub fn generate(n: usize, rng: &mut Rng) -> Self {
        let mut functions = Vec::with_capacity(n);
        for k in 0..n {
            // Popularity: heavy-tailed rates clamped to a sane band.
            let raw = rng.pareto(0.002, 1.1);
            let mean_rate = raw.min(5.0);
            let io_bound = rng.uniform() < 0.5;
            let (warm, cold) = if io_bound {
                // IO-intensive: longer, higher-variance service.
                (rng.uniform_range(0.5, 3.0), rng.uniform_range(1.5, 5.0))
            } else {
                // CPU-intensive: shorter service, dominated by compute.
                (rng.uniform_range(0.05, 0.8), rng.uniform_range(0.3, 2.0))
            };
            functions.push(FunctionProfile {
                name: format!("fn-{k:04}"),
                mean_rate,
                diurnal_depth: rng.uniform_range(0.2, 0.9),
                peak_offset: rng.uniform_range(0.0, 86_400.0),
                warm_service_mean: warm,
                cold_service_mean: cold.max(warm * 1.05),
            });
        }
        SyntheticTrace { functions }
    }

    /// Materialize one function's arrivals over `horizon` seconds.
    pub fn arrivals_for(&self, idx: usize, horizon: f64, rng: &mut Rng) -> Workload {
        let f = &self.functions[idx];
        let day = 86_400.0;
        let depth = f.diurnal_depth;
        let mean = f.mean_rate;
        let offset = f.peak_offset;
        let rate = move |t: f64| {
            mean * (1.0 + depth * (2.0 * std::f64::consts::PI * (t + offset) / day).sin())
        };
        let rate_max = mean * (1.0 + depth);
        nonhomogeneous(rate, rate_max, horizon, rng)
    }

    /// Aggregate mean rate across all functions.
    pub fn total_mean_rate(&self) -> f64 {
        self.functions.iter().map(|f| f.mean_rate).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_heavy_tailed_mix() {
        let mut rng = Rng::new(9);
        let trace = SyntheticTrace::generate(500, &mut rng);
        assert_eq!(trace.functions.len(), 500);
        let mut rates: Vec<f64> = trace.functions.iter().map(|f| f.mean_rate).collect();
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Heavy tail: the top function dominates the median by >10x.
        let median = rates[250];
        let top = rates[499];
        assert!(top / median > 10.0, "top={top} median={median}");
        // Cold > warm for every function.
        assert!(trace.functions.iter().all(|f| f.cold_service_mean > f.warm_service_mean));
    }

    #[test]
    fn arrivals_follow_mean_rate() {
        let mut rng = Rng::new(10);
        let mut trace = SyntheticTrace::generate(3, &mut rng);
        trace.functions[0].mean_rate = 1.0;
        trace.functions[0].diurnal_depth = 0.5;
        let w = trace.arrivals_for(0, 2.0 * 86_400.0, &mut rng);
        // Over whole days the diurnal modulation integrates out.
        let rate = w.rate_over(2.0 * 86_400.0);
        assert!((rate - 1.0).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn deterministic_generation_per_seed() {
        let t1 = SyntheticTrace::generate(10, &mut Rng::new(5));
        let t2 = SyntheticTrace::generate(10, &mut Rng::new(5));
        for (a, b) in t1.functions.iter().zip(&t2.functions) {
            assert_eq!(a.mean_rate, b.mean_rate);
            assert_eq!(a.peak_offset, b.peak_offset);
        }
    }
}
