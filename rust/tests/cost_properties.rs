//! Integration tests for the cost engine at fleet scale.
//!
//! Two pins the ISSUE asks for:
//!
//! 1. **Linearity** — when no fleet cap binds, functions are independent,
//!    so the fleet cost report must equal the sum of solo
//!    `ServerlessSimulator` runs priced one at a time (and the fleet total
//!    must equal the sum of its own per-function rows). Exercised across
//!    heterogeneous rates, memory sizes and providers.
//! 2. **Pricing tables** — the four provider tables are data the paper's
//!    §4.4 math multiplies through; pin the 2020-era constants so a silent
//!    edit can't skew every cost figure.

use simfaas::cost::{estimate, FunctionConfig, PricingTable, Provider};
use simfaas::fleet::{fleet_cost, FleetConfig, PolicySpec};
use simfaas::sim::{Process, ServerlessSimulator, SimConfig};

fn cfg(seed: u64, rate: f64, warm: f64) -> SimConfig {
    let mut c = SimConfig::table1().with_horizon(15_000.0).with_seed(seed);
    c.arrival = Process::exp_rate(rate);
    c.warm_service = Process::exp_mean(warm);
    c
}

#[test]
fn uncapped_fleet_cost_is_sum_of_solo_function_costs() {
    let sim_cfgs = [cfg(11, 0.4, 1.0), cfg(22, 1.2, 2.5), cfg(33, 2.0, 0.5)];
    let memories = [128.0, 512.0, 1024.0];

    let mut fleet_cfg = FleetConfig::from_sim_configs(&sim_cfgs, PolicySpec::fixed(600.0));
    for (spec, &m) in fleet_cfg.functions.iter_mut().zip(&memories) {
        spec.memory_mb = m;
    }
    let results = fleet_cfg.run();

    for provider in [
        Provider::AwsLambda,
        Provider::GoogleCloudFunctions,
        Provider::AzureFunctions,
        Provider::IbmCloudFunctions,
    ] {
        let pricing = PricingTable::for_provider(provider);
        let report = fleet_cost(&fleet_cfg, &results, &pricing);

        // Per-function fleet estimates equal solo-simulator estimates: the
        // uncapped fleet engine is bit-identical to ServerlessSimulator,
        // so the priced numbers match exactly too.
        for ((c, &m), fleet_est) in
            sim_cfgs.iter().zip(&memories).zip(&report.per_function)
        {
            let solo = ServerlessSimulator::new(c.clone()).run();
            let solo_est = estimate(&solo, &FunctionConfig::new(m), &pricing);
            assert_eq!(solo_est.requests.to_bits(), fleet_est.requests.to_bits());
            assert_eq!(solo_est.gb_seconds.to_bits(), fleet_est.gb_seconds.to_bits());
            assert_eq!(
                solo_est.request_charges.to_bits(),
                fleet_est.request_charges.to_bits()
            );
            assert_eq!(
                solo_est.runtime_charges.to_bits(),
                fleet_est.runtime_charges.to_bits()
            );
            assert_eq!(
                solo_est.provider_infra_cost.to_bits(),
                fleet_est.provider_infra_cost.to_bits()
            );
        }

        // The fleet total is the exact sum of its per-function rows.
        let sum = |f: fn(&simfaas::cost::CostEstimate) -> f64| -> f64 {
            report.per_function.iter().map(f).sum()
        };
        assert!((report.total.requests - sum(|e| e.requests)).abs() < 1e-9);
        assert!((report.total.gb_seconds - sum(|e| e.gb_seconds)).abs() < 1e-9);
        assert!(
            (report.total.developer_total() - sum(|e| e.developer_total())).abs() < 1e-12
        );
        assert!(
            (report.total.provider_infra_cost - sum(|e| e.provider_infra_cost)).abs() < 1e-12
        );
    }
}

#[test]
fn capped_fleet_costs_less_than_uncapped() {
    // A binding cap rejects work: fewer served requests and fewer
    // provisioned instances must never cost *more*.
    let sim_cfgs = [cfg(1, 2.5, 2.0), cfg(2, 2.5, 2.0)];
    let base = FleetConfig::from_sim_configs(&sim_cfgs, PolicySpec::fixed(600.0));
    let pricing = PricingTable::aws_lambda();
    let free = base.clone().run();
    let free_cost = fleet_cost(&base, &free, &pricing);
    let capped_cfg = base.with_fleet_cap(3);
    let capped = capped_cfg.run();
    let capped_cost = fleet_cost(&capped_cfg, &capped, &pricing);
    assert!(capped.aggregate.rejected_requests > 0);
    assert!(capped_cost.total.developer_total() < free_cost.total.developer_total());
    assert!(capped_cost.total.provider_infra_cost < free_cost.total.provider_infra_cost);
}

#[test]
fn provider_pricing_tables_pinned() {
    // (provider, per_request, per_gb_second, infra_per_instance_hour)
    let expected = [
        (Provider::AwsLambda, 0.20 / 1e6, 0.000_016_666_7, 0.0116),
        (Provider::GoogleCloudFunctions, 0.40 / 1e6, 0.000_016_5, 0.0118),
        (Provider::AzureFunctions, 0.20 / 1e6, 0.000_016, 0.0115),
        (Provider::IbmCloudFunctions, 0.0, 0.000_017, 0.0117),
    ];
    for (provider, per_request, per_gb_second, infra) in expected {
        let t = PricingTable::for_provider(provider);
        assert_eq!(t.provider, provider);
        assert_eq!(t.per_request.to_bits(), per_request.to_bits(), "{provider:?} per_request");
        assert_eq!(
            t.per_gb_second.to_bits(),
            per_gb_second.to_bits(),
            "{provider:?} per_gb_second"
        );
        assert_eq!(
            t.infra_cost_per_instance_hour.to_bits(),
            infra.to_bits(),
            "{provider:?} infra"
        );
    }
}
