"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests`` sweeps shapes and
dtypes (hypothesis) and asserts the kernels match these oracles — the core
correctness signal for Layer 1.
"""

import jax.numpy as jnp


def mlp_forward_ref(x, w1, b1, w2, b2):
    """Two-layer MLP forward: relu(x @ w1 + b1) @ w2 + b2.

    This is the serverless function's compute payload (an ML-inference
    app — the paper's motivating example of application initialization is
    "loading a machine learning model"). Shapes:
      x: (batch, d_in), w1: (d_in, d_hidden), b1: (d_hidden,)
      w2: (d_hidden, d_out), b2: (d_out,)
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def histogram_ref(samples, lo, hi, nbins):
    """Fixed-bin histogram over ``[lo, hi)`` with ``nbins`` equal bins.

    Returns float32 counts of shape (nbins,). Out-of-range samples are
    dropped (mirrors ``sim::hist::Histogram`` semantics for in-range bins).
    Used by the simulator's PDF/CDF approximation tools for multi-million
    sample traces.
    """
    width = (hi - lo) / nbins
    idx = jnp.floor((samples - lo) / width).astype(jnp.int32)
    in_range = (samples >= lo) & (samples < hi)
    idx = jnp.clip(idx, 0, nbins - 1)
    one_hot = (idx[:, None] == jnp.arange(nbins)[None, :]) & in_range[:, None]
    return one_hot.astype(jnp.float32).sum(axis=0)


def empirical_cdf_ref(samples, lo, hi, nbins):
    """CDF evaluated at the right edge of each bin (in-range mass only)."""
    counts = histogram_ref(samples, lo, hi, nbins)
    total = jnp.maximum(counts.sum(), 1.0)
    return jnp.cumsum(counts) / total
