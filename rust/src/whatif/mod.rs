//! What-if analysis engine (paper §4.3): sweep platform/workload
//! configurations through the simulator, in parallel across OS threads, and
//! find best-performing settings — e.g. the expiration-threshold trade-off
//! of Fig. 5, or a cost/QoS-optimal threshold per workload.

pub mod sweep;

pub use sweep::{sweep, sweep_grid, GridPoint, SweepOutcome};

use crate::sim::ensemble::{derive_seeds, run_indexed, EnsembleOpts, EnsembleResults};
use crate::sim::{ServerlessSimulator, SimConfig, SimResults};

/// Optimize the expiration threshold for a workload: minimize
/// `cost_weight * avg_server_count + coldstart_weight * cold_start_prob`
/// over a threshold grid (both terms normalized by their grid maxima so the
/// weights express relative importance). Returns the best threshold and the
/// per-point outcomes.
///
/// This is the provider-side knob the paper highlights: "provide users with
/// fine-grain control over the cost-performance trade-off by modifying the
/// platform parameters (e.g., expiration threshold)".
pub fn optimize_expiration_threshold(
    base: &SimConfig,
    thresholds: &[f64],
    cost_weight: f64,
    coldstart_weight: f64,
) -> (f64, Vec<(f64, SimResults)>) {
    assert!(!thresholds.is_empty());
    let outcomes: Vec<(f64, SimResults)> = sweep(thresholds, |&th| {
        let cfg = base.clone().with_expiration_threshold(th);
        ServerlessSimulator::new(cfg).run()
    })
    .into_iter()
    .map(|(th, r)| (*th, r))
    .collect();

    let max_servers = outcomes
        .iter()
        .map(|(_, r)| r.avg_server_count)
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let max_cold = outcomes
        .iter()
        .map(|(_, r)| r.cold_start_prob)
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let best = outcomes
        .iter()
        .min_by(|a, b| {
            let score = |r: &SimResults| {
                cost_weight * r.avg_server_count / max_servers
                    + coldstart_weight * r.cold_start_prob / max_cold
            };
            score(&a.1).partial_cmp(&score(&b.1)).unwrap()
        })
        .map(|(th, _)| *th)
        .unwrap();
    (best, outcomes)
}

/// Ensemble what-if over the expiration-threshold grid (Fig. 5 with error
/// bars): every `(threshold, replication)` pair is one job on a single
/// shared thread pool, so the grid and the replications parallelize
/// together instead of nesting pools. Per-threshold results aggregate into
/// an [`EnsembleResults`] (mean ± 95% CI via
/// [`EnsembleResults::summary`]). Deterministic for a fixed
/// `opts.root_seed` regardless of `opts.threads`.
pub fn expiration_threshold_ensemble(
    base: &SimConfig,
    thresholds: &[f64],
    opts: &EnsembleOpts,
) -> Vec<(f64, EnsembleResults)> {
    assert!(!thresholds.is_empty());
    assert!(opts.replications >= 1);
    let seeds = derive_seeds(opts.root_seed, opts.replications);
    let n = thresholds.len() * opts.replications;
    let runs = run_indexed(n, opts.threads, |j| {
        let th = thresholds[j / opts.replications];
        let seed = seeds[j % opts.replications];
        let cfg = base.replica_with_seed(seed).with_expiration_threshold(th);
        ServerlessSimulator::new(cfg).run()
    });
    let mut out = Vec::with_capacity(thresholds.len());
    let mut it = runs.into_iter();
    for &th in thresholds {
        let chunk: Vec<SimResults> = it.by_ref().take(opts.replications).collect();
        out.push((th, EnsembleResults { seeds: seeds.clone(), runs: chunk }));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_ensemble_deterministic_and_monotone() {
        let mut base = SimConfig::table1();
        base.horizon = 8_000.0;
        let thresholds = [60.0, 1200.0];
        let opts = EnsembleOpts::new(4, 0x5EED);
        let a = expiration_threshold_ensemble(&base, &thresholds, &opts.with_threads(1));
        let b = expiration_threshold_ensemble(&base, &thresholds, &opts.with_threads(4));
        assert_eq!(a.len(), 2);
        for ((tha, ra), (thb, rb)) in a.iter().zip(&b) {
            assert_eq!(tha, thb);
            for (x, y) in ra.runs.iter().zip(&rb.runs) {
                assert_eq!(x.total_requests, y.total_requests);
                assert_eq!(x.cold_requests, y.cold_requests);
                assert_eq!(x.avg_server_count.to_bits(), y.avg_server_count.to_bits());
            }
        }
        // Longer threshold -> fewer cold starts (Fig. 5 shape), now with CI.
        let p_short = a[0].1.ci_of(|r| r.cold_start_prob);
        let p_long = a[1].1.ci_of(|r| r.cold_start_prob);
        assert!(p_long.mean < p_short.mean, "short={p_short:?} long={p_long:?}");
    }

    #[test]
    fn optimizer_prefers_long_threshold_when_cold_starts_dominate() {
        let mut base = SimConfig::table1();
        base.horizon = 60_000.0;
        let thresholds = [60.0, 600.0, 1800.0];
        let (best, outcomes) = optimize_expiration_threshold(&base, &thresholds, 0.0, 1.0);
        assert_eq!(best, 1800.0, "outcomes: {:?}", outcomes.iter().map(|(t, r)| (*t, r.cold_start_prob)).collect::<Vec<_>>());
    }

    #[test]
    fn optimizer_prefers_short_threshold_when_cost_dominates() {
        let mut base = SimConfig::table1();
        base.horizon = 60_000.0;
        let thresholds = [60.0, 600.0, 1800.0];
        let (best, _) = optimize_expiration_threshold(&base, &thresholds, 1.0, 0.0);
        assert_eq!(best, 60.0);
    }
}
