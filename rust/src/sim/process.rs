//! Stochastic processes (`SimProcess` in the paper's package diagram).
//!
//! A [`SimProcess`] generates the inter-event times that drive the simulator:
//! request inter-arrival times, warm service times, cold service times, and
//! (optionally) non-deterministic expiration thresholds. The paper ships
//! exponential, deterministic ("fixed-interval") and Gaussian processes and
//! lets users plug their own by subclassing; we mirror that with a trait and
//! provide a wider set of built-ins plus trace-driven (`Empirical`) and
//! Markov-modulated (`Mmpp`) processes, which the paper calls out as beyond
//! the reach of its Markovian analytical predecessors.
//!
//! Where a closed form exists, processes also expose their theoretical
//! `mean`, `pdf` and `cdf` so simulation output can be compared against an
//! analytical model (paper §3: "the user can include their analytically
//! produced PDF and CDF functions to be compared against the simulation
//! trace results").

use super::rng::Rng;
use std::sync::Arc;

/// A stochastic process generating non-negative durations (seconds).
pub trait SimProcess: Send + Sync {
    /// Draw the next duration.
    fn sample(&self, rng: &mut Rng) -> f64;

    /// Theoretical mean, if known in closed form.
    fn mean(&self) -> Option<f64> {
        None
    }

    /// Theoretical PDF at `x`, if known.
    fn pdf(&self, _x: f64) -> Option<f64> {
        None
    }

    /// Theoretical CDF at `x`, if known.
    fn cdf(&self, _x: f64) -> Option<f64> {
        None
    }

    /// Human-readable description (used in reports).
    fn describe(&self) -> String;
}

/// Exponential(rate) process — the paper's default for arrivals and service.
#[derive(Debug, Clone)]
pub struct ExpProcess {
    pub rate: f64,
}

impl ExpProcess {
    /// From rate (events per second).
    pub fn with_rate(rate: f64) -> Self {
        assert!(rate > 0.0, "rate must be positive");
        ExpProcess { rate }
    }

    /// From mean duration (seconds).
    pub fn with_mean(mean: f64) -> Self {
        assert!(mean > 0.0, "mean must be positive");
        ExpProcess { rate: 1.0 / mean }
    }
}

impl SimProcess for ExpProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.exponential(self.rate)
    }

    fn mean(&self) -> Option<f64> {
        Some(1.0 / self.rate)
    }

    fn pdf(&self, x: f64) -> Option<f64> {
        Some(if x < 0.0 { 0.0 } else { self.rate * (-self.rate * x).exp() })
    }

    fn cdf(&self, x: f64) -> Option<f64> {
        Some(if x < 0.0 { 0.0 } else { 1.0 - (-self.rate * x).exp() })
    }

    fn describe(&self) -> String {
        format!("Exponential(rate={:.6}/s, mean={:.6}s)", self.rate, 1.0 / self.rate)
    }
}

/// Deterministic (fixed-interval) process — e.g. cron-triggered workloads.
#[derive(Debug, Clone)]
pub struct ConstProcess {
    pub value: f64,
}

impl ConstProcess {
    pub fn new(value: f64) -> Self {
        assert!(value >= 0.0, "duration must be non-negative");
        ConstProcess { value }
    }
}

impl SimProcess for ConstProcess {
    fn sample(&self, _rng: &mut Rng) -> f64 {
        self.value
    }

    fn mean(&self) -> Option<f64> {
        Some(self.value)
    }

    fn cdf(&self, x: f64) -> Option<f64> {
        Some(if x >= self.value { 1.0 } else { 0.0 })
    }

    fn describe(&self) -> String {
        format!("Deterministic({:.6}s)", self.value)
    }
}

/// Gaussian process truncated at zero (durations cannot be negative).
/// Matches the paper's bundled Gaussian example.
#[derive(Debug, Clone)]
pub struct GaussianProcess {
    pub mean: f64,
    pub std: f64,
}

impl GaussianProcess {
    pub fn new(mean: f64, std: f64) -> Self {
        assert!(std >= 0.0);
        GaussianProcess { mean, std }
    }
}

impl SimProcess for GaussianProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.normal(self.mean, self.std).max(0.0)
    }

    fn mean(&self) -> Option<f64> {
        // Exact only when truncation mass is negligible; good enough for the
        // service-time regimes the simulator targets (mean >> std).
        Some(self.mean)
    }

    fn describe(&self) -> String {
        format!("Gaussian(mean={:.6}s, std={:.6}s, truncated at 0)", self.mean, self.std)
    }
}

/// LogNormal process parameterized by the *observed* mean and coefficient of
/// variation (handier for fitting measured response times than mu/sigma).
#[derive(Debug, Clone)]
pub struct LogNormalProcess {
    mu: f64,
    sigma: f64,
}

impl LogNormalProcess {
    /// From underlying normal parameters.
    pub fn from_mu_sigma(mu: f64, sigma: f64) -> Self {
        assert!(sigma > 0.0);
        LogNormalProcess { mu, sigma }
    }

    /// From target mean and coefficient of variation (std/mean) of the
    /// lognormal variate itself.
    pub fn from_mean_cv(mean: f64, cv: f64) -> Self {
        assert!(mean > 0.0 && cv > 0.0);
        let sigma2 = (1.0 + cv * cv).ln();
        let mu = mean.ln() - sigma2 / 2.0;
        LogNormalProcess { mu, sigma: sigma2.sqrt() }
    }
}

impl SimProcess for LogNormalProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.lognormal(self.mu, self.sigma)
    }

    fn mean(&self) -> Option<f64> {
        Some((self.mu + self.sigma * self.sigma / 2.0).exp())
    }

    fn describe(&self) -> String {
        format!("LogNormal(mu={:.4}, sigma={:.4})", self.mu, self.sigma)
    }
}

/// Gamma process (shape, scale).
#[derive(Debug, Clone)]
pub struct GammaProcess {
    pub shape: f64,
    pub scale: f64,
}

impl GammaProcess {
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && scale > 0.0);
        GammaProcess { shape, scale }
    }
}

impl SimProcess for GammaProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.gamma(self.shape, self.scale)
    }

    fn mean(&self) -> Option<f64> {
        Some(self.shape * self.scale)
    }

    fn describe(&self) -> String {
        format!("Gamma(shape={:.4}, scale={:.4})", self.shape, self.scale)
    }
}

/// Weibull process (shape, scale) — common fit for cold-start provisioning.
#[derive(Debug, Clone)]
pub struct WeibullProcess {
    pub shape: f64,
    pub scale: f64,
}

impl WeibullProcess {
    pub fn new(shape: f64, scale: f64) -> Self {
        assert!(shape > 0.0 && scale > 0.0);
        WeibullProcess { shape, scale }
    }
}

impl SimProcess for WeibullProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.weibull(self.shape, self.scale)
    }

    fn mean(&self) -> Option<f64> {
        Some(self.scale * gamma_fn(1.0 + 1.0 / self.shape))
    }

    fn cdf(&self, x: f64) -> Option<f64> {
        Some(if x < 0.0 {
            0.0
        } else {
            1.0 - (-(x / self.scale).powf(self.shape)).exp()
        })
    }

    fn describe(&self) -> String {
        format!("Weibull(shape={:.4}, scale={:.4})", self.shape, self.scale)
    }
}

/// Pareto process — heavy-tailed service times (batch/analytics workloads).
#[derive(Debug, Clone)]
pub struct ParetoProcess {
    pub x_m: f64,
    pub alpha: f64,
}

impl ParetoProcess {
    pub fn new(x_m: f64, alpha: f64) -> Self {
        assert!(x_m > 0.0 && alpha > 0.0);
        ParetoProcess { x_m, alpha }
    }
}

impl SimProcess for ParetoProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        rng.pareto(self.x_m, self.alpha)
    }

    fn mean(&self) -> Option<f64> {
        if self.alpha > 1.0 {
            Some(self.alpha * self.x_m / (self.alpha - 1.0))
        } else {
            None // infinite mean
        }
    }

    fn describe(&self) -> String {
        format!("Pareto(x_m={:.4}, alpha={:.4})", self.x_m, self.alpha)
    }
}

/// Empirical process: resamples i.i.d. from a measured trace (bootstrap).
/// This is how measured Lambda response-time logs plug into the simulator.
#[derive(Debug, Clone)]
pub struct EmpiricalProcess {
    samples: Vec<f64>,
    mean: f64,
}

impl EmpiricalProcess {
    pub fn new(samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "empirical process needs samples");
        assert!(samples.iter().all(|&x| x >= 0.0 && x.is_finite()));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        EmpiricalProcess { samples, mean }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

impl SimProcess for EmpiricalProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        self.samples[rng.below(self.samples.len() as u64) as usize]
    }

    fn mean(&self) -> Option<f64> {
        Some(self.mean)
    }

    fn cdf(&self, x: f64) -> Option<f64> {
        let below = self.samples.iter().filter(|&&s| s <= x).count();
        Some(below as f64 / self.samples.len() as f64)
    }

    fn describe(&self) -> String {
        format!("Empirical(n={}, mean={:.6}s)", self.samples.len(), self.mean)
    }
}

/// Markov-modulated Poisson process (2-state on/off), for bursty arrivals —
/// explicitly beyond what the paper's Markovian analytical models handle.
///
/// NOTE: unlike the other processes, MMPP is *stateful* (it remembers its
/// current phase). Sharing one instance across simulator runs (e.g. by
/// cloning a `SimConfig`) carries the phase over; construct a fresh process
/// per run when bit-reproducibility across runs is required.
///
/// The process alternates between two exponential-rate phases; phase
/// residence times are exponential. `sample` returns the next inter-arrival
/// time accounting for phase changes between events. Interior mutability via
/// atomically-updated phase state is intentionally avoided: MMPP keeps its
/// phase in a `std::sync::Mutex` because `SimProcess` is `&self` (processes
/// are shared) — contention is nil in the single-threaded sim loop.
#[derive(Debug)]
pub struct MmppProcess {
    pub rate: [f64; 2],
    /// Phase switch rates: switch[i] = rate of leaving phase i.
    pub switch: [f64; 2],
    state: std::sync::Mutex<MmppState>,
}

#[derive(Debug, Clone, Copy)]
struct MmppState {
    phase: usize,
    /// Remaining time in the current phase.
    residual: f64,
}

impl MmppProcess {
    pub fn new(rate: [f64; 2], switch: [f64; 2]) -> Self {
        assert!(rate.iter().all(|&r| r > 0.0));
        assert!(switch.iter().all(|&r| r > 0.0));
        MmppProcess {
            rate,
            switch,
            state: std::sync::Mutex::new(MmppState { phase: 0, residual: 0.0 }),
        }
    }

    /// Long-run average arrival rate.
    pub fn average_rate(&self) -> f64 {
        // Stationary phase probabilities of a 2-state CTMC.
        let p0 = self.switch[1] / (self.switch[0] + self.switch[1]);
        p0 * self.rate[0] + (1.0 - p0) * self.rate[1]
    }
}

impl SimProcess for MmppProcess {
    fn sample(&self, rng: &mut Rng) -> f64 {
        let mut st = self.state.lock().unwrap();
        if st.residual <= 0.0 {
            st.residual = rng.exponential(self.switch[st.phase]);
        }
        let mut elapsed = 0.0;
        loop {
            let gap = rng.exponential(self.rate[st.phase]);
            if gap <= st.residual {
                st.residual -= gap;
                return elapsed + gap;
            }
            // Phase expires before the next arrival; advance to phase switch.
            elapsed += st.residual;
            st.phase = 1 - st.phase;
            st.residual = rng.exponential(self.switch[st.phase]);
        }
    }

    fn mean(&self) -> Option<f64> {
        Some(1.0 / self.average_rate())
    }

    fn describe(&self) -> String {
        format!(
            "MMPP(rates=[{:.4},{:.4}]/s, switch=[{:.4},{:.4}]/s, avg_rate={:.4}/s)",
            self.rate[0],
            self.rate[1],
            self.switch[0],
            self.switch[1],
            self.average_rate()
        )
    }
}

/// Monomorphic process dispatch for the simulator hot path.
///
/// The simulators draw inter-arrival and service times millions of times per
/// run; routing every draw through `Arc<dyn SimProcess>` costs an indirect
/// call the optimizer cannot inline (§Perf in DESIGN.md). `Process`
/// enumerates the built-in processes so the common draws compile to direct,
/// inlinable calls, while the `Custom` variant keeps the trait-object escape
/// hatch for user-defined processes (paper §3: "the user can pass a random
/// generator function with a custom distribution").
///
/// `Clone` is cheap for every variant except `Empirical` (which clones its
/// sample buffer — still negligible next to a simulation run). The stateful
/// `Mmpp` variant is shared behind an `Arc`; use [`Process::replica`] to get
/// an independent copy with fresh phase state for parallel replications.
#[derive(Clone)]
pub enum Process {
    /// Exponential(rate) — the paper's default for arrivals and service.
    Exp(ExpProcess),
    /// Deterministic fixed interval.
    Const(ConstProcess),
    /// Gaussian truncated at zero.
    Gaussian(GaussianProcess),
    /// Bootstrap resampling from a measured trace.
    Empirical(EmpiricalProcess),
    /// 2-state Markov-modulated Poisson process (stateful, shared).
    Mmpp(Arc<MmppProcess>),
    /// Any user-supplied [`SimProcess`] (virtual dispatch).
    Custom(Arc<dyn SimProcess>),
}

impl Process {
    /// Exponential process from a rate (events per second).
    pub fn exp_rate(rate: f64) -> Self {
        Process::Exp(ExpProcess::with_rate(rate))
    }

    /// Exponential process from a mean duration (seconds).
    pub fn exp_mean(mean: f64) -> Self {
        Process::Exp(ExpProcess::with_mean(mean))
    }

    /// Deterministic process.
    pub fn constant(value: f64) -> Self {
        Process::Const(ConstProcess::new(value))
    }

    /// Truncated Gaussian process.
    pub fn gaussian(mean: f64, std: f64) -> Self {
        Process::Gaussian(GaussianProcess::new(mean, std))
    }

    /// Empirical (bootstrap) process over measured samples.
    pub fn empirical(samples: Vec<f64>) -> Self {
        Process::Empirical(EmpiricalProcess::new(samples))
    }

    /// 2-state MMPP with fresh phase state.
    pub fn mmpp(rate: [f64; 2], switch: [f64; 2]) -> Self {
        Process::Mmpp(Arc::new(MmppProcess::new(rate, switch)))
    }

    /// Wrap any [`SimProcess`] (virtual-dispatch escape hatch).
    pub fn custom<P: SimProcess + 'static>(p: P) -> Self {
        Process::Custom(Arc::new(p))
    }

    /// Independent replica for parallel replications: stateful built-ins
    /// (MMPP) are re-created with fresh phase state so replications never
    /// share mutable state across threads; stateless variants are cloned.
    /// `Custom` processes are shared as-is — the trait exposes no way to
    /// re-create them, so determinism across thread counts for a stateful
    /// custom process is the caller's responsibility.
    pub fn replica(&self) -> Process {
        match self {
            Process::Mmpp(p) => Process::Mmpp(Arc::new(MmppProcess::new(p.rate, p.switch))),
            other => other.clone(),
        }
    }

    /// Draw the next duration. Built-in variants dispatch statically.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Process::Exp(p) => p.sample(rng),
            Process::Const(p) => p.sample(rng),
            Process::Gaussian(p) => p.sample(rng),
            Process::Empirical(p) => p.sample(rng),
            Process::Mmpp(p) => p.as_ref().sample(rng),
            Process::Custom(p) => p.sample(rng),
        }
    }

    /// Theoretical mean, if known in closed form.
    pub fn mean(&self) -> Option<f64> {
        match self {
            Process::Exp(p) => SimProcess::mean(p),
            Process::Const(p) => SimProcess::mean(p),
            Process::Gaussian(p) => SimProcess::mean(p),
            Process::Empirical(p) => SimProcess::mean(p),
            Process::Mmpp(p) => SimProcess::mean(p.as_ref()),
            Process::Custom(p) => p.mean(),
        }
    }

    /// Theoretical PDF at `x`, if known.
    pub fn pdf(&self, x: f64) -> Option<f64> {
        match self {
            Process::Exp(p) => p.pdf(x),
            Process::Const(p) => SimProcess::pdf(p, x),
            Process::Gaussian(p) => SimProcess::pdf(p, x),
            Process::Empirical(p) => SimProcess::pdf(p, x),
            Process::Mmpp(p) => SimProcess::pdf(p.as_ref(), x),
            Process::Custom(p) => p.pdf(x),
        }
    }

    /// Theoretical CDF at `x`, if known.
    pub fn cdf(&self, x: f64) -> Option<f64> {
        match self {
            Process::Exp(p) => p.cdf(x),
            Process::Const(p) => p.cdf(x),
            Process::Gaussian(p) => SimProcess::cdf(p, x),
            Process::Empirical(p) => p.cdf(x),
            Process::Mmpp(p) => SimProcess::cdf(p.as_ref(), x),
            Process::Custom(p) => p.cdf(x),
        }
    }

    /// Human-readable description.
    pub fn describe(&self) -> String {
        match self {
            Process::Exp(p) => p.describe(),
            Process::Const(p) => p.describe(),
            Process::Gaussian(p) => p.describe(),
            Process::Empirical(p) => p.describe(),
            Process::Mmpp(p) => p.describe(),
            Process::Custom(p) => p.describe(),
        }
    }
}

/// `Process` is itself a `SimProcess`, so it plugs into trait-based
/// consumers (e.g. `workload::from_process`) unchanged.
impl SimProcess for Process {
    fn sample(&self, rng: &mut Rng) -> f64 {
        Process::sample(self, rng)
    }

    fn mean(&self) -> Option<f64> {
        Process::mean(self)
    }

    fn pdf(&self, x: f64) -> Option<f64> {
        Process::pdf(self, x)
    }

    fn cdf(&self, x: f64) -> Option<f64> {
        Process::cdf(self, x)
    }

    fn describe(&self) -> String {
        Process::describe(self)
    }
}

impl From<ExpProcess> for Process {
    fn from(p: ExpProcess) -> Self {
        Process::Exp(p)
    }
}

impl From<ConstProcess> for Process {
    fn from(p: ConstProcess) -> Self {
        Process::Const(p)
    }
}

impl From<GaussianProcess> for Process {
    fn from(p: GaussianProcess) -> Self {
        Process::Gaussian(p)
    }
}

impl From<EmpiricalProcess> for Process {
    fn from(p: EmpiricalProcess) -> Self {
        Process::Empirical(p)
    }
}

impl From<MmppProcess> for Process {
    fn from(p: MmppProcess) -> Self {
        Process::Mmpp(Arc::new(p))
    }
}

impl From<LogNormalProcess> for Process {
    fn from(p: LogNormalProcess) -> Self {
        Process::custom(p)
    }
}

impl From<GammaProcess> for Process {
    fn from(p: GammaProcess) -> Self {
        Process::custom(p)
    }
}

impl From<WeibullProcess> for Process {
    fn from(p: WeibullProcess) -> Self {
        Process::custom(p)
    }
}

impl From<ParetoProcess> for Process {
    fn from(p: ParetoProcess) -> Self {
        Process::custom(p)
    }
}

impl From<Arc<dyn SimProcess>> for Process {
    fn from(p: Arc<dyn SimProcess>) -> Self {
        Process::Custom(p)
    }
}

/// Lanczos approximation of the Gamma function (for Weibull mean, CI widths).
pub fn gamma_fn(x: f64) -> f64 {
    // g = 7, n = 9 Lanczos coefficients.
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mean(p: &dyn SimProcess, n: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| p.sample(&mut rng)).sum::<f64>() / n as f64
    }

    #[test]
    fn exp_process_matches_theory() {
        let p = ExpProcess::with_rate(0.9);
        let m = sample_mean(&p, 200_000, 1);
        assert!((m - p.mean().unwrap()).abs() / p.mean().unwrap() < 0.01);
        assert!((p.cdf(0.0).unwrap() - 0.0).abs() < 1e-12);
        assert!((p.cdf(f64::INFINITY).unwrap() - 1.0).abs() < 1e-12);
        // PDF integrates to ~1 (trapezoid over [0, 20/rate])
        let mut acc = 0.0;
        let h = 0.001;
        let mut x = 0.0;
        while x < 20.0 / 0.9 {
            acc += h * (p.pdf(x).unwrap() + p.pdf(x + h).unwrap()) / 2.0;
            x += h;
        }
        assert!((acc - 1.0).abs() < 1e-3, "integral={acc}");
    }

    #[test]
    fn exp_from_mean() {
        let p = ExpProcess::with_mean(2.0);
        assert!((p.rate - 0.5).abs() < 1e-12);
    }

    #[test]
    fn const_process() {
        let p = ConstProcess::new(3.0);
        let mut rng = Rng::new(1);
        assert_eq!(p.sample(&mut rng), 3.0);
        assert_eq!(p.cdf(2.9), Some(0.0));
        assert_eq!(p.cdf(3.0), Some(1.0));
    }

    #[test]
    fn gaussian_truncates() {
        let p = GaussianProcess::new(0.1, 10.0);
        let mut rng = Rng::new(2);
        for _ in 0..10_000 {
            assert!(p.sample(&mut rng) >= 0.0);
        }
    }

    #[test]
    fn lognormal_from_mean_cv() {
        let p = LogNormalProcess::from_mean_cv(2.0, 0.5);
        assert!((p.mean().unwrap() - 2.0).abs() < 1e-9);
        let m = sample_mean(&p, 300_000, 3);
        assert!((m - 2.0).abs() < 0.02, "m={m}");
    }

    #[test]
    fn weibull_mean_closed_form() {
        let p = WeibullProcess::new(2.0, 1.0);
        // Gamma(1.5) = sqrt(pi)/2
        let expect = std::f64::consts::PI.sqrt() / 2.0;
        assert!((p.mean().unwrap() - expect).abs() < 1e-9);
    }

    #[test]
    fn pareto_infinite_mean_flagged() {
        assert!(ParetoProcess::new(1.0, 0.9).mean().is_none());
        let p = ParetoProcess::new(1.0, 3.0);
        assert!((p.mean().unwrap() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empirical_resamples_support() {
        let p = EmpiricalProcess::new(vec![1.0, 2.0, 3.0]);
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let s = p.sample(&mut rng);
            assert!(s == 1.0 || s == 2.0 || s == 3.0);
        }
        assert!((p.mean().unwrap() - 2.0).abs() < 1e-12);
        assert!((p.cdf(2.0).unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn mmpp_average_rate() {
        // Symmetric switch: phases equally likely; avg rate = (10+1)/2
        let p = MmppProcess::new([10.0, 1.0], [0.1, 0.1]);
        assert!((p.average_rate() - 5.5).abs() < 1e-12);
        // Long-run empirical rate matches.
        let mut rng = Rng::new(5);
        let n = 300_000;
        let total: f64 = (0..n).map(|_| p.sample(&mut rng)).sum();
        let rate = n as f64 / total;
        assert!((rate - 5.5).abs() / 5.5 < 0.05, "rate={rate}");
    }

    #[test]
    fn process_enum_bit_identical_to_trait_dispatch() {
        // The monomorphic fast path must draw the exact same stream as the
        // trait-object escape hatch: same samplers, same RNG consumption.
        let e = ExpProcess::with_rate(0.7);
        let enum_p = Process::Exp(e.clone());
        let custom_p = Process::custom(e);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        for _ in 0..10_000 {
            assert_eq!(
                enum_p.sample(&mut r1).to_bits(),
                custom_p.sample(&mut r2).to_bits()
            );
        }
        assert_eq!(enum_p.mean(), custom_p.mean());
        assert_eq!(enum_p.cdf(1.0), custom_p.cdf(1.0));
    }

    #[test]
    fn process_replica_resets_mmpp_state() {
        let p = Process::mmpp([10.0, 1.0], [0.1, 0.1]);
        // Advance the shared phase state so a plain clone would carry it.
        let mut r = Rng::new(3);
        for _ in 0..100 {
            p.sample(&mut r);
        }
        // Replicas start from fresh state: identical draws given equal RNGs.
        let a = p.replica();
        let b = p.replica();
        let mut ra = Rng::new(42);
        let mut rb = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.sample(&mut ra).to_bits(), b.sample(&mut rb).to_bits());
        }
    }

    #[test]
    fn process_from_impls_cover_builtins() {
        let ps: Vec<Process> = vec![
            ExpProcess::with_rate(1.0).into(),
            ConstProcess::new(1.0).into(),
            GaussianProcess::new(1.0, 0.1).into(),
            EmpiricalProcess::new(vec![1.0, 2.0]).into(),
            MmppProcess::new([1.0, 2.0], [0.1, 0.2]).into(),
            GammaProcess::new(2.0, 1.0).into(),
            LogNormalProcess::from_mean_cv(1.0, 0.5).into(),
            WeibullProcess::new(2.0, 1.0).into(),
            ParetoProcess::new(1.0, 2.0).into(),
        ];
        let mut rng = Rng::new(1);
        for p in &ps {
            let x = p.sample(&mut rng);
            assert!(x >= 0.0 && x.is_finite());
            assert!(!p.describe().is_empty());
        }
        // The enum is itself a SimProcess (trait consumers keep working).
        let as_trait: &dyn SimProcess = &ps[0];
        assert!(as_trait.mean().is_some());
    }

    #[test]
    fn gamma_fn_known_values() {
        assert!((gamma_fn(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(5.0) - 24.0).abs() < 1e-7);
        assert!((gamma_fn(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }
}
