//! Sparse continuous-time Markov chain (CTMC) solvers.
//!
//! The analytical performance models that preceded SimFaaS (Mahmoudi &
//! Khazaei 2020a/b) are CTMCs; this module provides the substrate they run
//! on: a sparse generator matrix, a steady-state solver (Gauss–Seidel on the
//! balance equations with normalization), and a transient solver
//! (uniformization / Jensen's method).

/// Sparse CTMC over states `0..n`.
///
/// Transitions are stored per source state as `(dest, rate)` lists. Diagonal
/// entries are implicit (negative row sums).
#[derive(Debug, Clone)]
pub struct Ctmc {
    /// Outgoing transitions per state.
    out: Vec<Vec<(usize, f64)>>,
}

impl Ctmc {
    pub fn new(n: usize) -> Self {
        Ctmc { out: vec![Vec::new(); n] }
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn is_empty(&self) -> bool {
        self.out.is_empty()
    }

    /// Add a transition `from -> to` with the given rate (accumulates if the
    /// pair already exists).
    pub fn add(&mut self, from: usize, to: usize, rate: f64) {
        assert!(rate >= 0.0, "negative rate");
        assert!(from < self.len() && to < self.len());
        if rate == 0.0 || from == to {
            return;
        }
        if let Some(e) = self.out[from].iter_mut().find(|(d, _)| *d == to) {
            e.1 += rate;
        } else {
            self.out[from].push((to, rate));
        }
    }

    /// Total exit rate of a state.
    pub fn exit_rate(&self, s: usize) -> f64 {
        self.out[s].iter().map(|(_, r)| r).sum()
    }

    pub fn transitions(&self, s: usize) -> &[(usize, f64)] {
        &self.out[s]
    }

    /// Steady-state distribution via Gauss–Seidel sweeps over the global
    /// balance equations `pi Q = 0`, `sum pi = 1`.
    ///
    /// Converges for the irreducible finite chains the serverless models
    /// produce. `tol` bounds the L1 change per sweep.
    pub fn steady_state(&self, tol: f64, max_sweeps: usize) -> Vec<f64> {
        let n = self.len();
        assert!(n > 0);
        // Incoming lists for Gauss-Seidel: pi[s] = (sum_in pi[j] q_ji) / exit(s)
        let mut incoming: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
        for (from, outs) in self.out.iter().enumerate() {
            for &(to, rate) in outs {
                incoming[to].push((from, rate));
            }
        }
        let exit: Vec<f64> = (0..n).map(|s| self.exit_rate(s)).collect();
        let mut pi = vec![1.0 / n as f64; n];
        for _sweep in 0..max_sweeps {
            let mut delta = 0.0;
            for s in 0..n {
                if exit[s] <= 0.0 {
                    continue; // absorbing state keeps its mass via normalization
                }
                let inflow: f64 = incoming[s].iter().map(|&(j, r)| pi[j] * r).sum();
                let new = inflow / exit[s];
                delta += (new - pi[s]).abs();
                pi[s] = new;
            }
            // Normalize.
            let total: f64 = pi.iter().sum();
            if total > 0.0 {
                for p in pi.iter_mut() {
                    *p /= total;
                }
            }
            if delta < tol {
                break;
            }
        }
        pi
    }

    /// Transient distribution at time `t` from `initial`, via uniformization:
    /// `pi(t) = sum_k PoissonPMF(k; q t) * initial P^k` where
    /// `P = I + Q/q` and `q >= max exit rate`.
    pub fn transient(&self, initial: &[f64], t: f64) -> Vec<f64> {
        let n = self.len();
        assert_eq!(initial.len(), n);
        if t <= 0.0 {
            return initial.to_vec();
        }
        let q = (0..n)
            .map(|s| self.exit_rate(s))
            .fold(0.0f64, f64::max)
            .max(1e-12)
            * 1.02; // slack keeps P strictly substochastic off-diagonal
        let qt = q * t;
        // Truncation point: mean + 8 sqrt(mean) + 10 covers > 1-1e-12 mass.
        let kmax = (qt + 8.0 * qt.sqrt() + 10.0).ceil() as usize;
        let mut v = initial.to_vec(); // initial P^k
        let mut acc = vec![0.0; n];
        // Poisson weights computed iteratively in log space to avoid
        // overflow for large qt.
        let mut log_w = -qt; // log PMF(0)
        let mut added_mass = 0.0;
        for k in 0..=kmax {
            let w = log_w.exp();
            if w > 0.0 {
                for (a, &x) in acc.iter_mut().zip(v.iter()) {
                    *a += w * x;
                }
                added_mass += w;
            }
            if added_mass > 1.0 - 1e-12 {
                break;
            }
            // v <- v P  (P = I + Q/q)
            let mut next = v.clone();
            for (from, outs) in self.out.iter().enumerate() {
                let exit = self.exit_rate(from);
                // diagonal of P: 1 - exit/q
                next[from] -= v[from] * (exit / q);
                for &(to, rate) in outs {
                    next[to] += v[from] * rate / q;
                }
            }
            v = next;
            log_w += (qt).ln() - ((k + 1) as f64).ln();
        }
        // Renormalize the truncated tail.
        let total: f64 = acc.iter().sum();
        if total > 0.0 {
            for a in acc.iter_mut() {
                *a /= total;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// M/M/1 with arrival l, service m: pi_k = (1-rho) rho^k.
    fn mm1(l: f64, m: f64, cap: usize) -> Ctmc {
        let mut c = Ctmc::new(cap + 1);
        for k in 0..cap {
            c.add(k, k + 1, l);
            c.add(k + 1, k, m);
        }
        c
    }

    #[test]
    fn mm1_steady_state_geometric() {
        let c = mm1(0.5, 1.0, 60);
        let pi = c.steady_state(1e-14, 20_000);
        let rho: f64 = 0.5;
        for k in 0..10 {
            let expect = (1.0 - rho) * rho.powi(k as i32);
            assert!(
                (pi[k] - expect).abs() < 1e-8,
                "pi[{k}]={} expect={expect}",
                pi[k]
            );
        }
    }

    #[test]
    fn mmck_erlang_b() {
        // M/M/c/c loss system: blocking probability = Erlang B.
        let l = 3.0;
        let m = 1.0;
        let c_servers = 5usize;
        let mut c = Ctmc::new(c_servers + 1);
        for k in 0..c_servers {
            c.add(k, k + 1, l);
            c.add(k + 1, k, (k + 1) as f64 * m);
        }
        let pi = c.steady_state(1e-14, 20_000);
        // Erlang B recursive: B(0)=1; B(k) = a B(k-1) / (k + a B(k-1))
        let a = l / m;
        let mut b = 1.0;
        for k in 1..=c_servers {
            b = a * b / (k as f64 + a * b);
        }
        assert!((pi[c_servers] - b).abs() < 1e-9, "pi_c={} erlangB={b}", pi[c_servers]);
    }

    #[test]
    fn transient_converges_to_steady_state() {
        let c = mm1(0.5, 1.0, 40);
        let mut init = vec![0.0; 41];
        init[0] = 1.0;
        let pt = c.transient(&init, 200.0);
        let pi = c.steady_state(1e-14, 20_000);
        let l1: f64 = pt.iter().zip(&pi).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "l1={l1}");
    }

    #[test]
    fn transient_short_horizon_keeps_mass_near_start() {
        let c = mm1(0.1, 1.0, 10);
        let mut init = vec![0.0; 11];
        init[0] = 1.0;
        let pt = c.transient(&init, 0.01);
        assert!(pt[0] > 0.99);
        let sum: f64 = pt.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transient_two_state_closed_form() {
        // 0 -> 1 rate a, 1 -> 0 rate b. P(in 1 at t | start 0)
        // = a/(a+b) (1 - exp(-(a+b) t)).
        let (a, b) = (2.0, 3.0);
        let mut c = Ctmc::new(2);
        c.add(0, 1, a);
        c.add(1, 0, b);
        let pt = c.transient(&[1.0, 0.0], 0.3);
        let expect = a / (a + b) * (1.0 - (-(a + b) * 0.3f64).exp());
        assert!((pt[1] - expect).abs() < 1e-9, "pt={} expect={expect}", pt[1]);
    }

    #[test]
    fn add_accumulates_parallel_edges() {
        let mut c = Ctmc::new(2);
        c.add(0, 1, 1.0);
        c.add(0, 1, 2.0);
        assert_eq!(c.transitions(0), &[(1, 3.0)]);
        assert_eq!(c.exit_rate(0), 3.0);
    }
}
