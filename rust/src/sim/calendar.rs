//! Calendar queue: an O(1)-amortized future event list.
//!
//! A classic Brown calendar queue [R. Brown, CACM 1988] adapted for
//! bit-reproducible discrete-event simulation. Time is divided into
//! fixed-width *days*; each day hashes onto one of `nbuckets` *bucket*
//! lists (`day % nbuckets`), so one "year" spans `nbuckets × width`
//! seconds. A cursor walks forward day by day; popping scans only the
//! current day's bucket for the earliest `(time, seq)` entry, which is
//! O(bucket occupancy) — O(1) when the queue is sized right — instead of
//! the `O(log n)` sift of a binary heap.
//!
//! Design points that keep it exactly equivalent to the heap queue:
//!
//! * **Total order.** Entries carry a monotone sequence number; pops are
//!   ordered by `(time, seq)`, the same deterministic tie-break as
//!   [`super::event::HeapEventQueue`]. Bucket-internal order (perturbed
//!   by `swap_remove`) is never observable.
//! * **Integer day indices.** Each entry precomputes its absolute day
//!   `abs = floor(time / width)` as a `u64` *once, at insertion*; the
//!   cursor compares days with integer equality, so there are no
//!   float-boundary disagreements between insert and pop.
//! * **Past-insert rewind.** Inserting before the cursor's day rewinds
//!   the cursor, so interleaved schedule/pop patterns (retries, prewarm
//!   leads) stay correct.
//! * **Sparse fallback.** If a full cycle of days turns up nothing (all
//!   entries live far in the future), a direct min-scan pops the global
//!   earliest entry and teleports the cursor to its day, bounding the
//!   worst case at O(n) instead of O(future gap / width).
//! * **Deterministic resize.** Bucket count doubles above 2× occupancy
//!   and halves below ¼ (hysteresis), and the day width is refit to the
//!   observed event spread. Resizing depends only on queue contents, so
//!   identical schedules resize identically.

use super::time::SimTime;

/// Smallest bucket count; also the floor the queue shrinks back to.
const MIN_BUCKETS: usize = 16;
/// Cap on the initial bucket allocation from [`CalendarQueue::with_capacity`].
const MAX_INITIAL_BUCKETS: usize = 1 << 18;
/// Clamp for `time / width` so the `as u64` conversion can never wrap:
/// beyond this the queue degrades to one shared day (still correct, the
/// direct-scan fallback finds the minimum).
const MAX_ABS: f64 = 9.0e18;
/// Floor on the day width so a pathological refit cannot divide by ~0.
const MIN_WIDTH: f64 = 1e-9;

#[derive(Debug, Clone)]
struct Entry<T> {
    at: SimTime,
    seq: u64,
    /// Absolute day index: `floor(at / width)` at insertion time.
    abs: u64,
    item: T,
}

/// A generic calendar queue over payload `T`, ordered by
/// `(time, insertion seq)`.
#[derive(Debug, Clone)]
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Entry<T>>>,
    /// Day width in seconds.
    width: f64,
    /// The cursor's absolute day; invariant: no entry has `abs < cur_abs`.
    cur_abs: u64,
    len: usize,
    seq: u64,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CalendarQueue<T> {
    /// Empty queue with the minimum bucket count.
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Empty queue sized for roughly `cap` concurrently pending entries
    /// (about one entry per bucket at that occupancy).
    pub fn with_capacity(cap: usize) -> Self {
        let nbuckets = cap.clamp(MIN_BUCKETS, MAX_INITIAL_BUCKETS);
        CalendarQueue {
            buckets: (0..nbuckets).map(|_| Vec::new()).collect(),
            width: 1.0,
            cur_abs: 0,
            len: 0,
            seq: 0,
        }
    }

    #[inline]
    fn abs_of(&self, at: SimTime) -> u64 {
        let x = at.as_secs() / self.width;
        if x >= MAX_ABS {
            MAX_ABS as u64
        } else if x > 0.0 {
            x as u64
        } else {
            0
        }
    }

    /// Insert `item` at absolute time `at`; returns the sequence number
    /// assigned (monotone per queue, the `(time, seq)` tie-break).
    #[inline]
    pub fn push(&mut self, at: SimTime, item: T) -> u64 {
        debug_assert!(at.is_finite(), "cannot schedule at infinity");
        let seq = self.seq;
        self.seq += 1;
        let abs = self.abs_of(at);
        self.insert_entry(Entry { at, seq, abs, item });
        if self.len > 2 * self.buckets.len() {
            self.resize(self.buckets.len() * 2);
        }
        seq
    }

    #[inline]
    fn insert_entry(&mut self, e: Entry<T>) {
        if self.len == 0 || e.abs < self.cur_abs {
            self.cur_abs = e.abs;
        }
        let n = self.buckets.len() as u64;
        self.buckets[(e.abs % n) as usize].push(e);
        self.len += 1;
    }

    /// Pop the earliest entry as `(time, seq, item)`.
    pub fn pop(&mut self) -> Option<(SimTime, u64, T)> {
        if self.len == 0 {
            return None;
        }
        let n = self.buckets.len() as u64;
        let mut misses = 0usize;
        loop {
            let idx = (self.cur_abs % n) as usize;
            let bucket = &self.buckets[idx];
            let mut best: Option<usize> = None;
            for (i, e) in bucket.iter().enumerate() {
                // Same hash slot, later year: not due in this day.
                if e.abs > self.cur_abs {
                    continue;
                }
                best = Some(match best {
                    None => i,
                    Some(b) => {
                        let cur = &bucket[b];
                        if e.at < cur.at || (e.at == cur.at && e.seq < cur.seq) {
                            i
                        } else {
                            b
                        }
                    }
                });
            }
            if let Some(i) = best {
                let e = self.buckets[idx].swap_remove(i);
                self.len -= 1;
                self.maybe_shrink();
                return Some((e.at, e.seq, e.item));
            }
            self.cur_abs += 1;
            misses += 1;
            if misses >= self.buckets.len() {
                return Some(self.pop_direct());
            }
        }
    }

    /// O(n) fallback for sparse queues: pop the global `(time, seq)`
    /// minimum and jump the cursor to its day.
    fn pop_direct(&mut self) -> (SimTime, u64, T) {
        debug_assert!(self.len > 0, "pop_direct on an empty queue");
        let mut best: Option<(SimTime, u64, usize, usize)> = None;
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (ei, e) in bucket.iter().enumerate() {
                let better = match &best {
                    None => true,
                    Some((bat, bseq, _, _)) => {
                        e.at < *bat || (e.at == *bat && e.seq < *bseq)
                    }
                };
                if better {
                    best = Some((e.at, e.seq, bi, ei));
                }
            }
        }
        let (_, _, bi, ei) = best.expect("len > 0 but no entry found");
        let e = self.buckets[bi].swap_remove(ei);
        // Entries left behind all order after `e`, and day indices are
        // monotone in time, so `e.abs` is a valid new cursor lower bound.
        self.cur_abs = e.abs;
        self.len -= 1;
        self.maybe_shrink();
        (e.at, e.seq, e.item)
    }

    #[inline]
    fn maybe_shrink(&mut self) {
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 4 {
            self.resize(self.buckets.len() / 2);
        }
    }

    /// Re-bucket everything into `new_n` buckets, refitting the day width
    /// to the observed spread (~one entry per day at current occupancy).
    fn resize(&mut self, new_n: usize) {
        let new_n = new_n.max(MIN_BUCKETS);
        let mut entries: Vec<Entry<T>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            entries.append(b);
        }
        let mut tmin = f64::INFINITY;
        let mut tmax = f64::NEG_INFINITY;
        for e in &entries {
            let t = e.at.as_secs();
            tmin = tmin.min(t);
            tmax = tmax.max(t);
        }
        if entries.len() >= 2 && tmax > tmin {
            let w = (tmax - tmin) / entries.len() as f64;
            if w.is_finite() {
                self.width = w.max(MIN_WIDTH);
            }
        }
        if self.buckets.len() != new_n {
            self.buckets = (0..new_n).map(|_| Vec::new()).collect();
        }
        self.len = 0;
        for e in entries {
            let abs = self.abs_of(e.at);
            self.insert_entry(Entry { abs, ..e });
        }
    }

    /// Time of the earliest entry without popping (O(n) scan; diagnostic
    /// use, not the hot path).
    pub fn peek_time(&self) -> Option<SimTime> {
        let mut best: Option<(SimTime, u64)> = None;
        for bucket in &self.buckets {
            for e in bucket {
                let better = match &best {
                    None => true,
                    Some((bat, bseq)) => {
                        e.at < *bat || (e.at == *bat && e.seq < *bseq)
                    }
                };
                if better {
                    best = Some((e.at, e.seq));
                }
            }
        }
        best.map(|(at, _)| at)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all pending entries. The sequence counter is preserved
    /// (matching the heap queue's `clear`), so tie-break order across a
    /// clear stays monotone.
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.len = 0;
        self.cur_abs = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = CalendarQueue::new();
        q.push(SimTime::from_secs(3.0), 'c');
        q.push(SimTime::from_secs(1.0), 'a');
        q.push(SimTime::from_secs(2.0), 'b');
        let order: Vec<char> = std::iter::from_fn(|| q.pop().map(|(_, _, c)| c)).collect();
        assert_eq!(order, vec!['a', 'b', 'c']);
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = CalendarQueue::new();
        let t = SimTime::from_secs(5.0);
        for i in 0..100u32 {
            q.push(t, i);
        }
        for i in 0..100u32 {
            let (_, _, v) = q.pop().unwrap();
            assert_eq!(v, i);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn insert_before_cursor_rewinds() {
        let mut q = CalendarQueue::new();
        q.push(SimTime::from_secs(100.0), 1u32);
        // Advance the cursor far forward.
        let (t, _, _) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 100.0);
        // Now insert in the "past" relative to the cursor.
        q.push(SimTime::from_secs(3.0), 2u32);
        q.push(SimTime::from_secs(200.0), 3u32);
        let (t, _, v) = q.pop().unwrap();
        assert_eq!((t.as_secs(), v), (3.0, 2));
        let (t, _, v) = q.pop().unwrap();
        assert_eq!((t.as_secs(), v), (200.0, 3));
    }

    #[test]
    fn grows_and_shrinks_through_resize() {
        let mut q = CalendarQueue::with_capacity(16);
        for i in 0..5000u32 {
            q.push(SimTime::from_secs(i as f64 * 0.13), i);
        }
        assert!(q.buckets.len() > MIN_BUCKETS, "expected growth");
        let mut prev = f64::NEG_INFINITY;
        for i in 0..5000u32 {
            let (t, _, v) = q.pop().unwrap();
            assert!(t.as_secs() >= prev);
            prev = t.as_secs();
            assert_eq!(v, i, "FIFO within the sorted insert order");
        }
        assert!(q.is_empty());
        assert_eq!(q.buckets.len(), MIN_BUCKETS, "expected shrink to floor");
    }

    #[test]
    fn sparse_far_future_uses_direct_fallback() {
        let mut q = CalendarQueue::new();
        // One entry ~10^9 days past the cursor at the default width.
        q.push(SimTime::from_secs(0.5), 'x');
        let (_, _, v) = q.pop().unwrap();
        assert_eq!(v, 'x');
        q.push(SimTime::from_secs(1.0e9), 'y');
        let (t, _, v) = q.pop().unwrap();
        assert_eq!((t.as_secs(), v), (1.0e9, 'y'));
    }

    #[test]
    fn clear_preserves_seq_monotonicity() {
        let mut q = CalendarQueue::new();
        let s0 = q.push(SimTime::from_secs(1.0), 0u8);
        q.clear();
        assert!(q.is_empty());
        let s1 = q.push(SimTime::from_secs(1.0), 1u8);
        assert!(s1 > s0);
        let (_, _, v) = q.pop().unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = CalendarQueue::new();
        for &t in &[9.0, 4.0, 6.5, 4.0] {
            q.push(SimTime::from_secs(t), ());
        }
        while let Some(peek) = q.peek_time() {
            let (t, _, _) = q.pop().unwrap();
            assert_eq!(peek, t);
        }
    }
}
