//! `ServerlessSimulator` — the paper's core contribution: a discrete-event
//! simulator of scale-per-request serverless platforms (AWS Lambda, Google
//! Cloud Functions, IBM Cloud Functions, Apache OpenWhisk, Azure Functions).
//!
//! Model (paper §2):
//! * **Scale-per-request**: an arrival is served by an idle instance (warm
//!   start) if one exists, otherwise a new instance is spun up for it (cold
//!   start). No queuing.
//! * **Newest-first routing**: among idle instances the most recently
//!   created one is chosen, maximizing older instances' chance to expire.
//! * **Expiration**: an idle instance that receives no request for
//!   `expiration_threshold` seconds is terminated (deterministic on AWS et
//!   al.; a stochastic threshold process is supported too).
//! * **Maximum concurrency level**: when `max_concurrency` instances exist
//!   and none is idle, arrivals are rejected with an error status.
//! * A cold request's busy period is one draw of the *cold service process*
//!   (provisioning + service, the paper's "cold response time"); a warm
//!   request's busy period is a draw of the *warm service process*.

use super::event::{Event, EventQueue};
use super::hist::CountDistribution;
use super::instance::{FunctionInstance, InstanceId, InstanceState};
use super::metrics::{OnlineStats, P2Quantile, TimeWeighted};
use super::process::Process;
use super::results::SimResults;
use super::rng::Rng;
use super::time::SimTime;

/// Outcome of a single request, for the optional per-request trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    Cold,
    Warm,
    Rejected,
}

/// One per-request trace record (only collected when
/// [`SimConfig::capture_request_log`] is set).
#[derive(Debug, Clone)]
pub struct RequestLogEntry {
    pub arrived_at: f64,
    pub outcome: RequestOutcome,
    /// Response time (provisioning+service for cold); 0 for rejected.
    pub response_time: f64,
    /// Serving instance (None for rejected).
    pub instance: Option<InstanceId>,
}

/// Simulation input parameters (the paper's Table 1 input rows).
///
/// Processes are held as the monomorphic [`Process`] enum so the hot-path
/// draws dispatch statically; any [`super::process::SimProcess`] still plugs
/// in via [`Process::custom`] / `.into()`.
#[derive(Clone)]
pub struct SimConfig {
    /// Inter-arrival time process.
    pub arrival: Process,
    /// Optional batch-size process: each arrival epoch brings
    /// `max(1, round(sample))` simultaneous requests (paper §4.2/§6 calls
    /// out batch arrivals as beyond the Markovian models' reach). `None`
    /// means single arrivals.
    pub batch_size: Option<Process>,
    /// Warm-start busy-period process (service time).
    pub warm_service: Process,
    /// Cold-start busy-period process (provisioning + service).
    pub cold_service: Process,
    /// Idle expiration threshold in seconds (AWS Lambda: 600 s).
    /// A stochastic threshold can be supplied via `expiration_process`.
    pub expiration_threshold: f64,
    /// Optional stochastic expiration threshold, overriding the constant.
    pub expiration_process: Option<Process>,
    /// Maximum concurrency level (AWS Lambda default: 1000).
    pub max_concurrency: usize,
    /// Simulation horizon in seconds.
    pub horizon: f64,
    /// Warm-up window to exclude from all statistics.
    pub skip_initial: f64,
    /// RNG seed; equal seeds give bit-identical runs.
    pub seed: u64,
    /// Collect the per-request log (costs memory on long runs).
    pub capture_request_log: bool,
    /// Sample the cumulative-average instance count every this many seconds
    /// (for Fig. 4 style transient plots). 0 disables sampling.
    pub sample_interval: f64,
}

impl SimConfig {
    /// The paper's Table 1 configuration: Poisson(0.9/s) arrivals,
    /// exp(1.991 s) warm, exp(2.244 s) cold, 10 min threshold, 1e6 s
    /// horizon, 100 s warm-up skip.
    pub fn table1() -> Self {
        SimConfig {
            arrival: Process::exp_rate(0.9),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 1e6,
            skip_initial: 100.0,
            seed: 0x5EED,
            capture_request_log: false,
            sample_interval: 0.0,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_horizon(mut self, horizon: f64) -> Self {
        self.horizon = horizon;
        self
    }

    pub fn with_arrival_rate(mut self, rate: f64) -> Self {
        self.arrival = Process::exp_rate(rate);
        self
    }

    pub fn with_expiration_threshold(mut self, secs: f64) -> Self {
        self.expiration_threshold = secs;
        self
    }

    /// Clone this configuration for an independent replication: stateful
    /// processes get fresh state (see [`Process::replica`]) and the RNG is
    /// re-seeded. The ensemble and temporal engines use this so parallel
    /// replications never share mutable process state across threads —
    /// the precondition for bit-identical results at any thread count.
    pub fn replica_with_seed(&self, seed: u64) -> SimConfig {
        let mut cfg = self.clone();
        cfg.arrival = cfg.arrival.replica();
        cfg.batch_size = cfg.batch_size.as_ref().map(Process::replica);
        cfg.warm_service = cfg.warm_service.replica();
        cfg.cold_service = cfg.cold_service.replica();
        cfg.expiration_process = cfg.expiration_process.as_ref().map(Process::replica);
        cfg.seed = seed;
        cfg
    }
}

/// A sampled point of the transient instance-count estimate.
#[derive(Debug, Clone, Copy)]
pub struct CountSample {
    pub t: f64,
    /// Instantaneous total instance count at t.
    pub count: f64,
    /// Cumulative time-average of the count over [skip, t].
    pub cumulative_avg: f64,
}

/// The scale-per-request serverless platform simulator.
pub struct ServerlessSimulator {
    cfg: SimConfig,
    rng: Rng,
    events: EventQueue,
    now: SimTime,

    /// All instances ever created, indexed by `InstanceId.0`.
    instances: Vec<FunctionInstance>,
    /// Idle pool, kept sorted ascending by id; the newest idle instance
    /// (max id) sits at the end, so newest-first routing is an O(1) pop.
    /// Pools are small (tens) and churn is dominated by reuse of the
    /// newest instance, so a sorted Vec beats a BTreeSet by a wide margin
    /// (§Perf: +20% end-to-end on the Table 1 workload).
    idle_pool: Vec<InstanceId>,
    /// Live (non-terminated) instance count.
    live_count: usize,
    busy_count: usize,

    // -------- statistics (all reset at the end of the warm-up skip) -------
    stats_started: bool,
    stats_start: SimTime,
    total_requests: u64,
    cold_requests: u64,
    warm_requests: u64,
    rejected_requests: u64,
    instances_created: u64,
    instances_expired: u64,
    server_count_tw: TimeWeighted,
    // The idle level is total - busy at every instant, so its time-weighted
    // average is derived exactly at finish() instead of paying a third
    // accumulator update on every level change (§Perf).
    running_tw: TimeWeighted,
    count_dist: CountDistribution,
    lifespan_stats: OnlineStats,
    response_stats: OnlineStats,
    warm_response_stats: OnlineStats,
    cold_response_stats: OnlineStats,
    response_p50: P2Quantile,
    response_p95: P2Quantile,
    response_p99: P2Quantile,
    billed_seconds: f64,
    request_log: Vec<RequestLogEntry>,
    samples: Vec<CountSample>,
    next_sample_at: SimTime,
}

impl ServerlessSimulator {
    pub fn new(cfg: SimConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        let start = SimTime::ZERO;
        // Pre-reserve hot storage: a Table-1-scale run allocates thousands
        // of instances and keeps a few thousand events in flight; growing
        // these Vecs inside the event loop shows up in profiles (§Perf).
        ServerlessSimulator {
            rng,
            events: EventQueue::with_capacity(4096),
            now: start,
            instances: Vec::with_capacity(1024),
            idle_pool: Vec::with_capacity(64),
            live_count: 0,
            busy_count: 0,
            stats_started: cfg.skip_initial <= 0.0,
            stats_start: SimTime::from_secs(cfg.skip_initial.max(0.0)),
            total_requests: 0,
            cold_requests: 0,
            warm_requests: 0,
            rejected_requests: 0,
            instances_created: 0,
            instances_expired: 0,
            server_count_tw: TimeWeighted::new(start, 0.0),
            running_tw: TimeWeighted::new(start, 0.0),
            count_dist: CountDistribution::new(start, 0),
            lifespan_stats: OnlineStats::new(),
            response_stats: OnlineStats::new(),
            warm_response_stats: OnlineStats::new(),
            cold_response_stats: OnlineStats::new(),
            response_p50: P2Quantile::new(0.5),
            response_p95: P2Quantile::new(0.95),
            response_p99: P2Quantile::new(0.99),
            billed_seconds: 0.0,
            request_log: Vec::new(),
            samples: Vec::new(),
            next_sample_at: SimTime::from_secs(cfg.skip_initial.max(0.0)),
            cfg,
        }
    }

    /// Seed the simulator with a custom initial state: `idle` instances idle
    /// for `idle_ages[i]` seconds already, and `running` instances that have
    /// `running_remaining[i]` seconds of service left. Used by the temporal
    /// simulator (paper's `ServerlessTemporalSimulator`).
    pub fn set_initial_state(&mut self, idle_ages: &[f64], running_remaining: &[f64]) {
        assert_eq!(self.now, SimTime::ZERO, "initial state must be set before run()");
        for &age in idle_ages {
            let id = self.alloc_instance();
            let inst = &mut self.instances[id.0 as usize];
            inst.state = InstanceState::Idle;
            // Created in the past; approximate lifespan bookkeeping.
            inst.created_at = SimTime::ZERO;
            inst.idle_since = SimTime::ZERO;
            let gen = inst.generation;
            let threshold = self.sample_expiration();
            let remaining = (threshold - age).max(0.0);
            debug_assert!(self.idle_pool.last().map(|&l| l < id).unwrap_or(true));
            self.idle_pool.push(id);
            self.live_count += 1;
            self.events.schedule(SimTime::from_secs(remaining), Event::Expiration { id, gen });
        }
        for &rem in running_remaining {
            let id = self.alloc_instance();
            let inst = &mut self.instances[id.0 as usize];
            inst.state = InstanceState::Running;
            self.live_count += 1;
            self.busy_count += 1;
            self.events
                .schedule(SimTime::from_secs(rem.max(0.0)), Event::Departure(id));
        }
        self.sync_levels();
    }

    fn alloc_instance(&mut self) -> InstanceId {
        let id = InstanceId(self.instances.len() as u64);
        self.instances.push(FunctionInstance::cold_start(id, self.now));
        id
    }

    fn sample_expiration(&mut self) -> f64 {
        match &self.cfg.expiration_process {
            Some(p) => p.sample(&mut self.rng),
            None => self.cfg.expiration_threshold,
        }
    }

    /// Push the current levels into the time-weighted accumulators.
    fn sync_levels(&mut self) {
        let total = self.live_count as f64;
        let busy = self.busy_count as f64;
        self.server_count_tw.update(self.now, total);
        self.running_tw.update(self.now, busy);
        self.count_dist.update(self.now, self.live_count);
    }

    /// Emit Fig.4-style samples up to the current time.
    fn emit_samples(&mut self) {
        if self.cfg.sample_interval <= 0.0 || !self.stats_started {
            return;
        }
        while self.next_sample_at <= self.now {
            // Cumulative average over [stats_start, next_sample_at]: the
            // accumulators are synced at every level change, so the
            // remainder since the last sync is at the current level.
            let t = self.next_sample_at;
            let elapsed = t.since(self.stats_start);
            let cum = if elapsed > 0.0 {
                let tw = &self.server_count_tw;
                let gap = t.since(tw.last_time()).max(0.0);
                (tw.integral() + tw.current() * gap) / elapsed
            } else {
                self.live_count as f64
            };
            self.samples.push(CountSample {
                t: t.as_secs(),
                count: self.live_count as f64,
                cumulative_avg: cum,
            });
            self.next_sample_at = t.after(self.cfg.sample_interval);
        }
    }

    fn maybe_start_stats(&mut self, event_time: SimTime) {
        if self.stats_started || event_time < self.stats_start {
            return;
        }
        // Advance level accumulators to the skip boundary, then reset them.
        let boundary = self.stats_start;
        self.server_count_tw.advance(boundary);
        self.running_tw.advance(boundary);
        self.count_dist.finish(boundary);
        self.server_count_tw.reset_at(boundary);
        self.running_tw.reset_at(boundary);
        self.count_dist.reset_at(boundary);
        self.stats_started = true;
    }

    fn record_response(&mut self, rt: f64, cold: bool) {
        if !self.stats_started {
            return;
        }
        self.response_stats.push(rt);
        if cold {
            self.cold_response_stats.push(rt);
        } else {
            self.warm_response_stats.push(rt);
        }
        self.response_p50.push(rt);
        self.response_p95.push(rt);
        self.response_p99.push(rt);
    }

    fn handle_arrival(&mut self) {
        // Batch epochs bring several simultaneous requests.
        let batch = match &self.cfg.batch_size {
            None => 1,
            Some(p) => {
                let k = p.sample(&mut self.rng).round();
                if k < 1.0 {
                    1
                } else {
                    k as u64
                }
            }
        };
        let (live0, busy0) = (self.live_count, self.busy_count);
        for _ in 0..batch {
            self.route_one_request();
        }
        // Lazy sync: a fully-rejected epoch changes no level, so skip the
        // accumulator updates entirely (they stay correct because the level
        // is unchanged since the last sync).
        if self.live_count != live0 || self.busy_count != busy0 {
            self.sync_levels();
        }
        // Schedule the next arrival epoch.
        let gap = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(self.now.after(gap), Event::Arrival);
    }

    /// Route a single request at the current instant (scale-per-request).
    fn route_one_request(&mut self) {
        if self.stats_started {
            self.total_requests += 1;
        }
        // Newest-first routing: take the youngest idle instance.
        if let Some(id) = self.idle_pool.pop() {
            let inst = &mut self.instances[id.0 as usize];
            inst.start_warm(self.now);
            self.busy_count += 1;
            let service = self.cfg.warm_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.warm_requests += 1;
                self.record_response(service, false);
                if self.cfg.capture_request_log {
                    self.request_log.push(RequestLogEntry {
                        arrived_at: self.now.as_secs(),
                        outcome: RequestOutcome::Warm,
                        response_time: service,
                        instance: Some(id),
                    });
                }
            }
        } else if self.live_count < self.cfg.max_concurrency {
            // Cold start: spin up a new instance; its busy period is one
            // draw of the cold service process (provisioning + service).
            let id = self.alloc_instance();
            self.live_count += 1;
            self.busy_count += 1;
            if self.stats_started {
                self.instances_created += 1;
            }
            let service = self.cfg.cold_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.cold_requests += 1;
                self.record_response(service, true);
                if self.cfg.capture_request_log {
                    self.request_log.push(RequestLogEntry {
                        arrived_at: self.now.as_secs(),
                        outcome: RequestOutcome::Cold,
                        response_time: service,
                        instance: Some(id),
                    });
                }
            }
        } else {
            // Maximum concurrency reached and nothing idle: reject.
            if self.stats_started {
                self.rejected_requests += 1;
                if self.cfg.capture_request_log {
                    self.request_log.push(RequestLogEntry {
                        arrived_at: self.now.as_secs(),
                        outcome: RequestOutcome::Rejected,
                        response_time: 0.0,
                        instance: None,
                    });
                }
            }
        }
    }

    fn handle_departure(&mut self, id: InstanceId) {
        let gen;
        {
            let inst = &mut self.instances[id.0 as usize];
            // The whole busy period is billed (the paper notes app init —
            // included in the cold busy period here — is billed; the
            // platform-init part is a sub-second refinement configurable
            // via the cost module's billed-fraction knob).
            let busy = self.now.since(inst.busy_since).max(0.0);
            gen = inst.finish_request(self.now, busy);
            if self.stats_started {
                self.billed_seconds += busy;
            }
        }
        self.busy_count -= 1;
        match self.idle_pool.binary_search(&id) {
            Err(pos) => self.idle_pool.insert(pos, id),
            Ok(_) => unreachable!("instance already idle"),
        }
        let threshold = self.sample_expiration();
        self.events
            .schedule(self.now.after(threshold), Event::Expiration { id, gen });
        self.sync_levels();
    }

    fn handle_expiration(&mut self, id: InstanceId, gen: u64) {
        let inst = &mut self.instances[id.0 as usize];
        // Stale event: the instance was reused (generation advanced) or is
        // no longer idle.
        if inst.generation != gen || inst.state != InstanceState::Idle {
            return;
        }
        inst.terminate(self.now);
        let lifespan = inst.lifespan(self.now);
        if let Ok(pos) = self.idle_pool.binary_search(&id) {
            self.idle_pool.remove(pos);
        }
        self.live_count -= 1;
        if self.stats_started {
            self.instances_expired += 1;
            self.lifespan_stats.push(lifespan);
        }
        self.sync_levels();
    }

    /// Run to the horizon and produce results.
    pub fn run(&mut self) -> SimResults {
        let horizon = SimTime::from_secs(self.cfg.horizon);
        // First arrival.
        let first = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(SimTime::from_secs(first), Event::Arrival);
        self.events.schedule(horizon, Event::Horizon);

        while let Some((t, ev)) = self.events.pop() {
            self.maybe_start_stats(t);
            self.now = t;
            self.emit_samples();
            match ev {
                Event::Arrival => self.handle_arrival(),
                Event::Departure(id) => self.handle_departure(id),
                Event::Expiration { id, gen } => self.handle_expiration(id, gen),
                Event::ProvisioningDone(_) => unreachable!("not used by this simulator"),
                Event::Horizon => break,
            }
        }
        self.finish(horizon)
    }

    fn finish(&mut self, horizon: SimTime) -> SimResults {
        self.now = horizon;
        self.server_count_tw.advance(horizon);
        self.running_tw.advance(horizon);
        self.count_dist.finish(horizon);
        self.emit_samples();

        let measured = horizon.since(self.stats_start).max(0.0);
        let served = self.cold_requests + self.warm_requests;
        let avg_server = self.server_count_tw.average();
        let avg_running = self.running_tw.average();
        // idle(t) = total(t) - busy(t) at every instant, so the averages
        // decompose exactly (no third accumulator needed on the hot path).
        let avg_idle = avg_server - avg_running;
        SimResults {
            measured_time: measured,
            total_requests: self.total_requests,
            cold_requests: self.cold_requests,
            warm_requests: self.warm_requests,
            rejected_requests: self.rejected_requests,
            cold_start_prob: if served > 0 {
                self.cold_requests as f64 / served as f64
            } else {
                0.0
            },
            rejection_prob: if self.total_requests > 0 {
                self.rejected_requests as f64 / self.total_requests as f64
            } else {
                0.0
            },
            avg_lifespan: self.lifespan_stats.mean(),
            instances_created: self.instances_created,
            instances_expired: self.instances_expired,
            avg_server_count: avg_server,
            avg_running_count: avg_running,
            avg_idle_count: avg_idle,
            max_server_count: self.server_count_tw.max_level(),
            wasted_capacity: if avg_server > 0.0 { avg_idle / avg_server } else { 0.0 },
            avg_response_time: self.response_stats.mean(),
            avg_warm_response_time: self.warm_response_stats.mean(),
            avg_cold_response_time: self.cold_response_stats.mean(),
            response_p50: self.response_p50.quantile(),
            response_p95: self.response_p95.quantile(),
            response_p99: self.response_p99.quantile(),
            billed_instance_seconds: self.billed_seconds,
            observed_arrival_rate: if measured > 0.0 {
                self.total_requests as f64 / measured
            } else {
                0.0
            },
            instance_count_pmf: self.count_dist.pmf(),
        }
    }

    /// The per-request log (empty unless `capture_request_log`).
    pub fn request_log(&self) -> &[RequestLogEntry] {
        &self.request_log
    }

    /// Fig.4-style transient samples (empty unless `sample_interval > 0`).
    pub fn samples(&self) -> &[CountSample] {
        &self.samples
    }

    /// All instances ever created (for lifecycle analysis tooling).
    pub fn instances(&self) -> &[FunctionInstance] {
        &self.instances
    }

    /// Current live/busy/idle counts — exposed for invariant tests.
    pub fn live_counts(&self) -> (usize, usize, usize) {
        (self.live_count, self.busy_count, self.idle_pool.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg(rate: f64, horizon: f64, seed: u64) -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(rate),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 100.0,
            seed,
            capture_request_log: false,
            sample_interval: 0.0,
        }
    }

    #[test]
    fn littles_law_running_servers() {
        // Little's law: E[running] = lambda * E[S] (rejections are nil here).
        let mut sim = ServerlessSimulator::new(quick_cfg(0.9, 200_000.0, 1));
        let r = sim.run();
        let expected = 0.9 * 1.991; // cold fraction negligible
        assert!(
            (r.avg_running_count - expected).abs() / expected < 0.03,
            "running={} expected~{}",
            r.avg_running_count,
            expected
        );
    }

    #[test]
    fn reproducible_given_seed() {
        let a = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 42)).run();
        let b = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 42)).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert!((a.avg_server_count - b.avg_server_count).abs() < 1e-12);
    }

    #[test]
    fn enum_and_custom_dispatch_runs_bit_identical() {
        // The monomorphic hot path must reproduce the trait-object ("seed
        // behavior") path exactly: same draws, same events, same stats.
        use crate::sim::process::ExpProcess;
        let base = quick_cfg(0.9, 50_000.0, 77);
        let mut custom = base.clone();
        custom.arrival = Process::custom(ExpProcess::with_rate(0.9));
        custom.warm_service = Process::custom(ExpProcess::with_mean(1.991));
        custom.cold_service = Process::custom(ExpProcess::with_mean(2.244));
        let a = ServerlessSimulator::new(base).run();
        let b = ServerlessSimulator::new(custom).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.instances_expired, b.instances_expired);
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
        assert_eq!(
            a.billed_instance_seconds.to_bits(),
            b.billed_instance_seconds.to_bits()
        );
        assert_eq!(a.response_p99.to_bits(), b.response_p99.to_bits());
    }

    #[test]
    fn different_seeds_differ() {
        let a = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 1)).run();
        let b = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 2)).run();
        assert_ne!(a.total_requests, b.total_requests);
    }

    #[test]
    fn counts_are_consistent() {
        let mut sim = ServerlessSimulator::new(quick_cfg(1.5, 100_000.0, 3));
        let r = sim.run();
        assert_eq!(r.total_requests, r.cold_requests + r.warm_requests + r.rejected_requests);
        assert!(r.cold_start_prob > 0.0 && r.cold_start_prob < 0.05);
        assert_eq!(r.rejected_requests, 0);
        // total = running + idle (time-weighted means add up)
        assert!((r.avg_server_count - r.avg_running_count - r.avg_idle_count).abs() < 1e-9);
    }

    #[test]
    fn max_concurrency_causes_rejections() {
        let mut cfg = quick_cfg(10.0, 20_000.0, 4);
        cfg.max_concurrency = 5; // way below lambda * E[S] ~ 20
        let mut sim = ServerlessSimulator::new(cfg);
        let r = sim.run();
        assert!(r.rejected_requests > 0);
        assert!(r.rejection_prob > 0.3, "p_reject={}", r.rejection_prob);
        assert!(r.max_server_count <= 5.0);
    }

    #[test]
    fn deterministic_processes_no_cold_after_first() {
        // Arrivals every 5 s, service 1 s, threshold 600 s: after the first
        // cold start the single instance is always reused.
        let cfg = SimConfig {
            arrival: Process::constant(5.0),
            batch_size: None,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 10_000.0,
            skip_initial: 0.0,
            seed: 5,
            capture_request_log: false,
            sample_interval: 0.0,
        };
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.cold_requests, 1);
        assert_eq!(r.rejected_requests, 0);
        assert!((r.max_server_count - 1.0).abs() < 1e-12);
    }

    #[test]
    fn instances_expire_when_idle_long_enough() {
        // Arrivals every 700 s > threshold 600 s: every request is cold.
        let cfg = SimConfig {
            arrival: Process::constant(700.0),
            batch_size: None,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 100_000.0,
            skip_initial: 0.0,
            seed: 6,
            capture_request_log: false,
            sample_interval: 0.0,
        };
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.warm_requests, 0);
        assert!(r.cold_requests > 100);
        assert!(r.instances_expired >= r.cold_requests - 1);
        // Lifespan = busy (2 s) + idle threshold (600 s)
        assert!((r.avg_lifespan - 602.0).abs() < 1e-6, "lifespan={}", r.avg_lifespan);
    }

    #[test]
    fn request_log_captured_when_enabled() {
        let mut cfg = quick_cfg(0.9, 5_000.0, 7);
        cfg.capture_request_log = true;
        let mut sim = ServerlessSimulator::new(cfg);
        let r = sim.run();
        let log = sim.request_log();
        assert_eq!(log.len() as u64, r.total_requests);
        assert!(log.windows(2).all(|w| w[0].arrived_at <= w[1].arrived_at));
        let cold = log.iter().filter(|e| e.outcome == RequestOutcome::Cold).count() as u64;
        assert_eq!(cold, r.cold_requests);
    }

    #[test]
    fn newest_first_routing_lets_oldest_expire() {
        // Two instances get created by a burst, then load drops to one
        // request at a time: the newest instance should absorb all traffic
        // and the oldest should expire.
        let mut cfg = quick_cfg(0.9, 50_000.0, 8);
        cfg.capture_request_log = true;
        let mut sim = ServerlessSimulator::new(cfg);
        let _ = sim.run();
        // Find any instance that was reused while an older one expired -
        // structural check: among terminated instances, termination is
        // dominated by low request counts (they were starved by routing).
        let insts = sim.instances();
        let terminated: Vec<_> = insts
            .iter()
            .filter(|i| i.state == InstanceState::Terminated)
            .collect();
        assert!(!terminated.is_empty());
    }

    #[test]
    fn initial_state_seeding() {
        let mut cfg = quick_cfg(0.9, 1000.0, 9);
        cfg.skip_initial = 0.0;
        let mut sim = ServerlessSimulator::new(cfg);
        sim.set_initial_state(&[0.0, 100.0, 599.0], &[5.0, 1.0]);
        let (live, busy, idle) = sim.live_counts();
        assert_eq!((live, busy, idle), (5, 2, 3));
        let r = sim.run();
        // The instance idle for 599 s expires almost immediately unless a
        // request reaches it first; either way the run completes sanely.
        assert!(r.avg_server_count > 0.0);
    }

    #[test]
    fn samples_emitted_at_interval() {
        let mut cfg = quick_cfg(0.9, 10_000.0, 10);
        cfg.sample_interval = 100.0;
        let mut sim = ServerlessSimulator::new(cfg);
        let _ = sim.run();
        let samples = sim.samples();
        assert!(samples.len() >= 95, "samples={}", samples.len());
        assert!(samples.windows(2).all(|w| w[1].t > w[0].t));
    }
}
