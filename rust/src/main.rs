//! `simfaas` — the SimFaaS command-line interface.
//!
//! Every simulation-side subcommand (`steady`, `temporal`, `ensemble`,
//! `fleet`, `sweep`, `compare`, `cost`) is a thin translator from flags to
//! a [`simfaas::scenario::ScenarioSpec`], executed by the one
//! [`simfaas::scenario::run_scenario`] entry point — `simfaas run
//! <scenario.json>` executes the same specs from files (bundled examples
//! under `examples/scenarios/`). The emulator-side commands (`emulate`,
//! `validate`, `probe`), trace identification (`identify`) and the paper
//! figure regenerator (`figures`) drive their subsystems directly.
//!
//! The command table below ([`COMMANDS`]) is the single source of truth:
//! dispatch, `simfaas help` and the unknown-command message all derive
//! from it, so the three can never disagree (pinned by `tests/cli_smoke`).

use anyhow::{bail, Context, Result};
use simfaas::cli::Args;
use simfaas::cluster::{ClusterConfig, SchedulerSpec};
use simfaas::control::ControllerSpec;
use simfaas::cost::Provider;
use simfaas::emulator::{EmulatorConfig, Platform};
use simfaas::figures;
use simfaas::fleet::PolicyKind;
use simfaas::output::{ascii_histogram, ascii_lines, Series, Table};
use simfaas::scenario::{
    run_scenario_to_string, CostSpec, ExperimentSpec, FleetScenario, KeepAliveSpec,
    ObservabilitySpec, OutputFormat, ProcessSpec, ReliabilitySpec, ScenarioSpec, SourceSpec,
};
use simfaas::sim::SimConfig;
use simfaas::workload;
use std::sync::Arc;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// One CLI subcommand: dispatch target plus its help text.
struct Cmd {
    name: &'static str,
    summary: &'static str,
    /// Flag reference lines listed under the summary in `simfaas help`.
    flags: &'static str,
    /// Maximum positional operands after the subcommand; extras fail fast
    /// before the command runs (a typo'd flag value must not trigger a
    /// full simulation with default parameters).
    operands: usize,
    run: fn(&Args) -> Result<()>,
}

/// The command registry — help, dispatch and the unknown-command message
/// all derive from this table.
const COMMANDS: &[Cmd] = &[
    Cmd {
        name: "run",
        summary: "execute a declarative scenario file (examples/scenarios/)",
        flags: "simfaas run <scenario.json> [--json] [--print-spec]",
        operands: 1,
        run: cmd_run,
    },
    Cmd {
        name: "steady",
        summary: "steady-state simulation (Table 1)",
        flags: "--rate --warm --cold --threshold --max-concurrency\n--horizon --skip --seed --json\n--failure-rate P --coldstart-failure-rate P --timeout S [--timeout-kills]\n--retry none|fixed:D[,N]|exponential:BASE,CAP[,N]\n--record-trace out.jsonl (also writes .perfetto.json/.metrics.csv)\n--metrics-interval S (state samples every S sim-seconds)",
        operands: 0,
        run: cmd_steady,
    },
    Cmd {
        name: "temporal",
        summary: "transient analysis with CI (Fig. 4)",
        flags: "--replications --horizon --interval --warm-pool --seed",
        operands: 0,
        run: cmd_temporal,
    },
    Cmd {
        name: "ensemble",
        summary: "multi-threaded replication ensemble: mean ± 95% CI per metric",
        flags: "--replications --threads (0 = all cores) --rate --warm --cold\n--threshold --horizon --skip --seed\n[--thresholds a,b,c  parallel expiration-threshold grid]",
        operands: 0,
        run: cmd_ensemble,
    },
    Cmd {
        name: "fleet",
        summary: "multi-function fleet simulation (synthetic mix or real Azure trace)",
        flags: "--functions N --horizon --skip --seed --threads\n--policy fixed|adaptive --threshold (fixed)\n--range --bin (adaptive) --fleet-cap (0 = none)\n--capacity-domains K (shard the capped/clustered paths; 1 = off)\n--controller target:U|pid:KP,KI,KD|step:LO,HI (autoscale the cap/hosts;\n  options ;tick=S;min=N;max=N;delay=S — needs --fleet-cap or --hosts)\n--hosts N (0 = no cluster) --host-memory MB --host-cpus C\n--scheduler first-fit|least-loaded|round-robin|packing\n--prewarm-lead S (adaptive head-arm prewarm; 0 = off)\n--trace-dir DIR (Azure Functions 2019 dataset CSVs)\n--trace-top-k K --trace-scale X (with --trace-dir)\n--provider --memory --top K --json\n[--compare-thresholds a,b,c  fixed grid vs adaptive sweep]\n--failure-rate P --coldstart-failure-rate P --timeout S [--timeout-kills]\n--retry none|fixed:D[,N]|exponential:BASE,CAP[,N]\n--record-trace out.jsonl (also writes .perfetto.json/.metrics.csv)\n--metrics-interval S (state samples every S sim-seconds)",
        operands: 0,
        run: cmd_fleet,
    },
    Cmd {
        name: "sweep",
        summary: "what-if sweep (Fig. 5)",
        flags: "--rates a,b,c --thresholds x,y --horizon --seed",
        operands: 0,
        run: cmd_sweep,
    },
    Cmd {
        name: "emulate",
        summary: "run the platform emulator",
        flags: "--rate --horizon --scale --payload none|small|medium|large\n--threshold --csv out.csv",
        operands: 0,
        run: cmd_emulate,
    },
    Cmd {
        name: "validate",
        summary: "simulator vs emulator (Figs. 6-8)",
        flags: "--rates a,b,c --emu-horizon --scale --sim-horizon --seed",
        operands: 0,
        run: cmd_validate,
    },
    Cmd {
        name: "compare",
        summary: "simulator vs Markovian analytical model",
        flags: "--rate --service --threshold --horizon --markovian-expiration",
        operands: 0,
        run: cmd_compare,
    },
    Cmd {
        name: "cost",
        summary: "cost estimation (paper §4.4)",
        flags: "--rate --memory --provider --horizon",
        operands: 0,
        run: cmd_cost,
    },
    Cmd {
        name: "identify",
        summary: "parameters from a trace CSV",
        flags: "--trace file.csv",
        operands: 0,
        run: cmd_identify,
    },
    Cmd {
        name: "inspect",
        summary: "recompute warm-pool/cold-start estimates from a recorded span trace",
        flags: "simfaas inspect <trace.jsonl> [--window S] [--skip S] [--json]",
        operands: 1,
        run: cmd_inspect,
    },
    Cmd {
        name: "probe",
        summary: "expiration-threshold probe against the emulator",
        flags: "--threshold --scale --step --max-gap",
        operands: 0,
        run: cmd_probe,
    },
    Cmd {
        name: "figures",
        summary: "regenerate paper tables/figures",
        flags: "--all | --fig 1|3|4|5|6 (6 covers 6-8) [--out-dir results/]\n[--quick]",
        operands: 0,
        run: cmd_figures,
    },
];

fn command_names() -> Vec<&'static str> {
    COMMANDS.iter().map(|c| c.name).collect()
}

fn help_text() -> String {
    let mut s = String::from(
        "simfaas — performance simulator for serverless platforms\n\n\
         usage: simfaas <command> [flags]\n\ncommands:\n",
    );
    for c in COMMANDS {
        s.push_str(&format!("  {:<11}{}\n", c.name, c.summary));
        for line in c.flags.lines() {
            s.push_str(&format!("             {line}\n"));
        }
    }
    s.push_str("  help       show this message\n");
    s
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_deref() {
        Some("help") | None => print!("{}", help_text()),
        Some(name) => match COMMANDS.iter().find(|c| c.name == name) {
            Some(cmd) => {
                if args.positional_count() > cmd.operands {
                    bail!(
                        "unexpected positional argument {:?}",
                        args.positional(cmd.operands).unwrap()
                    );
                }
                (cmd.run)(&args)?
            }
            None => bail!(
                "unknown command {name:?}; expected one of: {}, help",
                command_names().join(", ")
            ),
        },
    }
    args.check_unknown()
}

/// Run a scenario and print its report (the single exit every
/// simulation-side subcommand funnels through). By this point the
/// translator has consumed every flag it understands, so unknown-flag
/// detection runs *before* the simulation — a typo'd flag must not burn a
/// paper-scale run on default parameters first.
fn execute(args: &Args, spec: &ScenarioSpec) -> Result<()> {
    args.check_unknown()?;
    print!("{}", run_scenario_to_string(spec)?);
    Ok(())
}

/// Flags → the shared workload/platform/run axes, with the historical
/// `sim_cfg_from_args` defaults (the paper's Table 1 configuration).
fn core_spec(args: &Args, name: &str) -> Result<ScenarioSpec> {
    Ok(ScenarioSpec::new(name)
        .with_arrival(ProcessSpec::ExpRate(args.get_f64("rate", 0.9)?))
        .with_services(
            ProcessSpec::ExpMean(args.get_f64("warm", figures::WARM_MEAN)?),
            ProcessSpec::ExpMean(args.get_f64("cold", figures::COLD_MEAN)?),
        )
        .with_expiration_threshold(args.get_f64("threshold", 600.0)?)
        .with_max_concurrency(args.get_usize("max-concurrency", 1000)?)
        .with_horizon(args.get_f64("horizon", 1e6)?)
        .with_skip_initial(args.get_f64("skip", 100.0)?)
        .with_seed(args.get_u64("seed", 0x5EED)?))
}

/// Flags → the optional reliability axis (fault injection + retries),
/// shared by `steady` and `fleet`. Returns `None` when no fault flag is
/// given, keeping the spec — and therefore the run — bit-identical to the
/// pre-fault CLI.
fn reliability_from_args(args: &Args) -> Result<Option<ReliabilitySpec>> {
    use simfaas::sim::{FaultProfile, RetryPolicy, TimeoutAction};
    let failure = args.get_f64("failure-rate", 0.0)?;
    let cs_failure = args.get_f64("coldstart-failure-rate", 0.0)?;
    let timeout = args.get_f64("timeout", 0.0)?;
    let timeout_kills = args.get_bool("timeout-kills");
    let retry_spec = args.get("retry").map(str::to_string);
    if failure == 0.0
        && cs_failure == 0.0
        && timeout == 0.0
        && !timeout_kills
        && retry_spec.is_none()
    {
        return Ok(None);
    }
    let mut fault = FaultProfile::disabled()
        .with_failure_prob(failure)
        .with_coldstart_failure_prob(cs_failure);
    if timeout > 0.0 {
        fault = fault.with_timeout(timeout);
    }
    if timeout_kills {
        fault = fault.with_timeout_action(TimeoutAction::KillInstance);
    }
    let retry = match retry_spec {
        None => RetryPolicy::none(),
        Some(s) => RetryPolicy::parse(&s).context("--retry")?,
    };
    Ok(Some(ReliabilitySpec::new(fault, retry)))
}

/// Flags → the optional observability axis (span capture + state
/// sampling), shared by `steady` and `fleet`. Returns `None` when neither
/// flag is given, keeping the spec — and the run — bit-identical to the
/// pre-telemetry CLI.
fn observability_from_args(args: &Args) -> Result<Option<ObservabilitySpec>> {
    let record_trace = args.get("record-trace").map(str::to_string);
    let metrics_interval = args.get_f64("metrics-interval", 0.0)?;
    if record_trace.is_none() && metrics_interval == 0.0 {
        return Ok(None);
    }
    Ok(Some(ObservabilitySpec::new(record_trace, metrics_interval)))
}

fn cmd_run(args: &Args) -> Result<()> {
    let path = args
        .positional(0)
        .context("usage: simfaas run <scenario.json> [--json] [--print-spec]")?
        .to_string();
    let text =
        std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let mut spec =
        ScenarioSpec::from_json_str(&text).with_context(|| format!("parsing {path}"))?;
    if args.get_bool("json") {
        spec = spec.with_output(OutputFormat::Json);
    }
    if args.get_bool("print-spec") {
        // Echo the canonical (defaults-resolved) form without running —
        // before any path rewriting, so the printed spec matches the file.
        println!("{}", spec.to_json_string());
        return Ok(());
    }
    // Relative dataset directories in a scenario file resolve against the
    // file's own location, so bundled specs run from any working dir.
    if let Some(base) = std::path::Path::new(&path).parent() {
        spec.resolve_source_paths(base);
    }
    execute(args, &spec)
}

fn cmd_steady(args: &Args) -> Result<()> {
    let mut spec = core_spec(args, "steady")?;
    if let Some(rel) = reliability_from_args(args)? {
        spec = spec.with_reliability(rel);
    }
    if let Some(obs) = observability_from_args(args)? {
        spec = spec.with_observability(obs);
    }
    if args.get_bool("json") {
        spec = spec.with_output(OutputFormat::Json);
    }
    execute(args, &spec)
}

fn cmd_temporal(args: &Args) -> Result<()> {
    // The transient default horizon is shorter than the steady-state one.
    let horizon = args.get_f64("horizon", 10_000.0)?;
    let spec = core_spec(args, "temporal")?
        .with_horizon(horizon)
        .with_experiment(ExperimentSpec::Temporal {
            replications: args.get_usize("replications", 10)?,
            sample_interval: Some(args.get_f64("interval", horizon / 100.0)?),
            warm_pool: args.get_usize("warm-pool", 0)?,
        });
    execute(args, &spec)
}

fn cmd_ensemble(args: &Args) -> Result<()> {
    let spec = core_spec(args, "ensemble")?.with_experiment(ExperimentSpec::Ensemble {
        replications: args.get_usize("replications", 10)?,
        threads: args.get_usize("threads", 0)?,
        thresholds: args.get_f64_list("thresholds", &[])?,
    });
    execute(args, &spec)
}

fn cmd_fleet(args: &Args) -> Result<()> {
    let mut fleet = FleetScenario::new(args.get_usize("functions", 50)?);
    fleet.threads = args.get_usize("threads", 0)?;
    // Consume both policy parameter sets up front so e.g. `--threshold`
    // with `--policy adaptive` is ignored rather than an unknown flag.
    let threshold = args.get_f64("threshold", 600.0)?;
    let range = args.get_f64("range", 3_600.0)?;
    let bin = args.get_f64("bin", 60.0)?;
    let adaptive = KeepAliveSpec::hybrid_histogram(range, bin);
    fleet.policy = match args.get_str("policy", "fixed").parse::<PolicyKind>()? {
        PolicyKind::Fixed => KeepAliveSpec::fixed(threshold),
        PolicyKind::Adaptive => adaptive.clone(),
    };
    let cap = args.get_usize("fleet-cap", 0)?;
    fleet.fleet_cap = if cap > 0 { Some(cap) } else { None };
    // Cluster axis: --hosts switches the capacity model from the flat
    // --fleet-cap counter to finite-resource hosts with a scheduler.
    let hosts = args.get_usize("hosts", 0)?;
    let host_memory = args.get_f64("host-memory", 2_048.0)?;
    let host_cpus = args.get_f64("host-cpus", 32.0)?;
    let scheduler_str = args.get_str("scheduler", "first-fit");
    if hosts == 0
        && (args.get("host-memory").is_some()
            || args.get("host-cpus").is_some()
            || args.get("scheduler").is_some())
    {
        bail!("--host-memory/--host-cpus/--scheduler require --hosts");
    }
    if hosts > 0 {
        let scheduler = SchedulerSpec::parse(scheduler_str).with_context(|| {
            format!(
                "--scheduler: unknown scheduler {scheduler_str:?} \
                 (expected first-fit|least-loaded|round-robin|packing)"
            )
        })?;
        fleet.cluster = Some(
            ClusterConfig::new(hosts, host_memory, host_cpus).with_scheduler(scheduler),
        );
    }
    // Capacity-domain sharding of the capped/clustered paths (validated
    // against the cap / host count by ScenarioSpec::validate below).
    fleet.capacity_domains = args.get_usize("capacity-domains", 1)?;
    // Autoscaling controller moving the fleet cap / host set at simulated
    // time (requires a capacity model; ScenarioSpec::validate checks).
    if let Some(ctl) = args.get("controller") {
        fleet.controller = Some(ControllerSpec::parse(ctl).with_context(|| {
            format!(
                "--controller: unparseable controller {ctl:?} \
                 (expected target:UTIL[,COOLDOWN,STEP] | pid:KP,KI,KD[,TARGET] | \
                 step:LOW,HIGH[,STEP], with optional ;tick=SECS;min=N;max=N;delay=SECS \
                 options)"
            )
        })?);
    }
    fleet.prewarm_lead = args.get_f64("prewarm-lead", 0.0)?;
    fleet.memory_mb = args.get_f64("memory", 128.0)?;
    fleet.top_k = args.get_usize("top", 5)?;
    fleet.compare_thresholds = args.get_f64_list("compare-thresholds", &[])?;
    let comparison = !fleet.compare_thresholds.is_empty();
    if comparison {
        fleet.compare_extra = vec![adaptive];
    }
    let provider: Provider = args.get_str("provider", "aws").parse()?;
    let memory_mb = fleet.memory_mb;
    // Real-trace ingestion (the workload.source axis): --trace-dir swaps
    // the synthetic mix for the Azure Functions 2019 dataset in DIR.
    let trace_dir = args.get("trace-dir").map(str::to_string);
    let trace_top_k = args.get_usize("trace-top-k", 0)?;
    let trace_scale = args.get_f64("trace-scale", 1.0)?;
    if trace_dir.is_none() && (trace_top_k > 0 || trace_scale != 1.0) {
        bail!("--trace-top-k/--trace-scale require --trace-dir");
    }
    if trace_dir.is_some() && (args.get("functions").is_some() || args.get("memory").is_some()) {
        // Fail fast instead of silently ignoring axes the dataset decides.
        bail!(
            "--functions/--memory apply to the synthetic mix; with --trace-dir the \
             dataset sets the function count and per-app memory (narrow the mix \
             with --trace-top-k instead)"
        );
    }
    // Consume --json up front: it is a no-op in the comparison branch
    // (which always rendered as a table) but must not read as unknown.
    let json_out = args.get_bool("json");

    let mut spec = ScenarioSpec::new("fleet")
        .with_horizon(args.get_f64("horizon", 86_400.0)?)
        .with_skip_initial(args.get_f64("skip", 0.0)?)
        .with_seed(args.get_u64("seed", 0x5EED)?)
        .with_experiment(ExperimentSpec::Fleet(fleet))
        .with_cost(CostSpec { provider, memory_mb, ..CostSpec::default() });
    if let Some(dir) = trace_dir {
        spec = spec.with_source(SourceSpec::AzureDataset {
            dir,
            top_k: if trace_top_k > 0 { Some(trace_top_k) } else { None },
            slice: None,
            scale_rate: trace_scale,
        });
    }
    if let Some(rel) = reliability_from_args(args)? {
        spec = spec.with_reliability(rel);
    }
    if let Some(obs) = observability_from_args(args)? {
        if comparison {
            bail!(
                "--record-trace/--metrics-interval apply to a single fleet run, \
                 not a policy comparison"
            );
        }
        spec = spec.with_observability(obs);
    }
    if json_out && !comparison {
        spec = spec.with_output(OutputFormat::Json);
    }
    execute(args, &spec)
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let spec = ScenarioSpec::new("sweep")
        .with_horizon(args.get_f64("horizon", 200_000.0)?)
        .with_seed(args.get_u64("seed", 0x5EED)?)
        .with_experiment(ExperimentSpec::Sweep {
            rates: args.get_f64_list("rates", &[0.1, 0.3, 0.5, 0.9, 1.5, 2.5])?,
            thresholds: args.get_f64_list("thresholds", &[120.0, 300.0, 600.0, 1200.0])?,
        });
    execute(args, &spec)
}

fn cmd_compare(args: &Args) -> Result<()> {
    let spec = core_spec(args, "compare")?.with_experiment(ExperimentSpec::Compare {
        service_mean: args.get_f64("service", figures::WARM_MEAN)?,
        markovian_expiration: args.get_bool("markovian-expiration"),
    });
    execute(args, &spec)
}

fn cmd_cost(args: &Args) -> Result<()> {
    let provider: Provider = args.get_str("provider", "aws").parse()?;
    let spec = core_spec(args, "cost")?
        .with_cost(CostSpec::monthly(provider, args.get_f64("memory", 128.0)?));
    execute(args, &spec)
}

fn emulator_cfg_from_args(
    args: &Args,
) -> Result<(EmulatorConfig, Option<Arc<simfaas::runtime::ComputePool>>)> {
    use simfaas::runtime::{ComputePool, PayloadKind};
    use simfaas::sim::ExpProcess;
    let scale = args.get_f64("scale", 2_000.0)?;
    let mut cfg = EmulatorConfig::lambda_like(scale);
    cfg.expiration_threshold = args.get_f64("threshold", 600.0)?;
    cfg.synthetic_service = Some(Arc::new(ExpProcess::with_mean(
        args.get_f64("warm", figures::WARM_MEAN)?,
    )));
    cfg.provisioning_delay =
        args.get_f64("provisioning", figures::COLD_MEAN - figures::WARM_MEAN)?;
    let payload = args.get_str("payload", "none");
    let pool = match payload.as_str() {
        "none" => None,
        name => {
            cfg.payload = Some(name.parse::<PayloadKind>()?);
            cfg.payload_reps = args.get_u64("payload-reps", 1)? as u32;
            cfg.app_init_reps = args.get_u64("app-init-reps", 2)? as u32;
            let workers = args.get_usize("pool-workers", 4)?;
            Some(Arc::new(ComputePool::new(
                simfaas::runtime::default_artifacts_dir(),
                workers,
            )?))
        }
    };
    Ok((cfg, pool))
}

fn cmd_emulate(args: &Args) -> Result<()> {
    let (cfg, pool) = emulator_cfg_from_args(args)?;
    let rate = args.get_f64("rate", 0.9)?;
    let horizon = args.get_f64("horizon", 10_000.0)?;
    let seed = args.get_u64("seed", 7)?;
    let skip = args.get_f64("skip", 300.0)?;
    // All flags consumed — surface typos before the (real-time) emulation.
    let csv_path = args.get("csv").map(str::to_string);
    args.check_unknown()?;
    let mut rng = simfaas::sim::Rng::new(seed);
    let w = workload::poisson(rate, horizon, &mut rng);
    println!(
        "emulating {} requests over {horizon} virtual s (scale {}x)...",
        w.len(),
        cfg.time_scale
    );
    let platform = Platform::new(cfg, pool);
    let t0 = std::time::Instant::now();
    let res = platform.run(&w)?;
    let wall = t0.elapsed().as_secs_f64();
    let m = res.metrics(skip);
    let mut t = Table::new(vec!["metric", "value"]);
    t.row(vec!["cold start prob".to_string(), format!("{:.4} %", m.cold_start_prob * 100.0)]);
    t.row(vec!["rejection prob".to_string(), format!("{:.4} %", m.rejection_prob * 100.0)]);
    t.row(vec!["avg server count".to_string(), format!("{:.4}", m.avg_server_count)]);
    t.row(vec!["avg running".to_string(), format!("{:.4}", m.avg_running_count)]);
    t.row(vec!["avg idle".to_string(), format!("{:.4}", m.avg_idle_count)]);
    t.row(vec!["wasted capacity".to_string(), format!("{:.4} %", m.wasted_capacity * 100.0)]);
    t.row(vec!["avg warm response".to_string(), format!("{:.4} s", m.avg_warm_response)]);
    t.row(vec!["avg cold response".to_string(), format!("{:.4} s", m.avg_cold_response)]);
    t.row(vec!["instances".to_string(), format!("{}", res.instances.len())]);
    t.row(vec!["wall time".to_string(), format!("{wall:.2} s")]);
    print!("{t}");
    if let Some(path) = csv_path {
        let f = std::fs::File::create(&path).with_context(|| format!("creating {path}"))?;
        simfaas::trace::write_csv(std::io::BufWriter::new(f), &res.records)?;
        println!("trace written to {path}");
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let rates = args.get_f64_list("rates", &[0.5, 1.0, 2.0])?;
    let opts = figures::ValidationOpts {
        emu_horizon: args.get_f64("emu-horizon", 40_000.0)?,
        time_scale: args.get_f64("scale", 4_000.0)?,
        sim_horizon: args.get_f64("sim-horizon", 400_000.0)?,
        skip: args.get_f64("skip", 600.0)?,
        seed: args.get_u64("seed", 0xF16)?,
    };
    args.check_unknown()?;
    let rows = figures::validation_rows(&rates, &opts);
    print_validation(&rows);
    Ok(())
}

fn print_validation(rows: &[figures::ValidationRow]) {
    let mut t = Table::new(vec![
        "rate",
        "sim p_cold%",
        "emu p_cold%",
        "sim servers",
        "emu servers",
        "sim waste%",
        "emu waste%",
    ]);
    for r in rows {
        t.row_f64(
            &[
                r.rate,
                r.sim.cold_start_prob * 100.0,
                r.emu.cold_start_prob * 100.0,
                r.sim.avg_server_count,
                r.emu.avg_server_count,
                r.sim.wasted_capacity * 100.0,
                r.emu.wasted_capacity * 100.0,
            ],
            3,
        );
    }
    print!("{t}");
    let (e6, e7, e8) = figures::validation_errors(rows);
    println!(
        "Fig6 avg %err (p_cold): {e6:.2}%   Fig7 MAPE (servers): {e7:.2}%   Fig8 MAPE (waste): {e8:.2}%"
    );
    println!("(paper: 12.75%, 3.43%, 0.17%)");
}

fn cmd_identify(args: &Args) -> Result<()> {
    let path = args.get("trace").context("--trace <file.csv> is required")?.to_string();
    let f = std::fs::File::open(&path).with_context(|| format!("opening {path}"))?;
    let records = simfaas::trace::read_csv(std::io::BufReader::new(f))?;
    let p = simfaas::trace::identify(&records);
    let pool = simfaas::trace::mean_warm_pool(&records, 600.0, 600.0);
    let mut t = Table::new(vec!["parameter", "estimate"]);
    t.row(vec!["arrival rate".to_string(), format!("{:.4} req/s", p.arrival_rate)]);
    t.row(vec!["warm mean".to_string(), format!("{:.4} s (std {:.4})", p.warm_mean, p.warm_std)]);
    t.row(vec!["cold mean".to_string(), format!("{:.4} s (std {:.4})", p.cold_mean, p.cold_std)]);
    t.row(vec!["cold start prob".to_string(), format!("{:.4} %", p.cold_start_prob * 100.0)]);
    t.row(vec!["rejection prob".to_string(), format!("{:.4} %", p.rejection_prob * 100.0)]);
    t.row(vec!["warm pool (10 min window)".to_string(), format!("{pool:.3}")]);
    print!("{t}");
    Ok(())
}

/// `simfaas inspect <trace.jsonl>` — close the loop between the telemetry
/// layer and the paper's §5.2/§5.3 identification: map recorded spans back
/// into the shared trace schema, then run the same estimators `identify`
/// applies to emulator/AWS logs (arrival rate, service moments, cold-start
/// probability, sliding-window warm-pool size).
fn cmd_inspect(args: &Args) -> Result<()> {
    use simfaas::telemetry::{SpanOutcome, SpanVerdict};
    use simfaas::trace::{identify, mean_warm_pool, Outcome, RequestRecord};
    let path = args
        .positional(0)
        .context("usage: simfaas inspect <trace.jsonl> [--window S] [--skip S] [--json]")?
        .to_string();
    let window = args.get_f64("window", 600.0)?;
    let skip = args.get_f64("skip", 0.0)?;
    let json_out = args.get_bool("json");
    args.check_unknown()?;
    let f = std::fs::File::open(&path).with_context(|| format!("opening {path}"))?;
    let spans = simfaas::telemetry::read_spans_jsonl(std::io::BufReader::new(f))?;
    if spans.is_empty() {
        bail!("{path}: no spans recorded");
    }
    let mut records: Vec<RequestRecord> = spans
        .iter()
        .map(|s| RequestRecord {
            arrived_at: s.queued_at,
            outcome: match (s.outcome, s.verdict) {
                (SpanOutcome::Rejected, _) => Outcome::Rejected,
                (SpanOutcome::ColdStartFailed, _) => Outcome::Failed,
                (_, SpanVerdict::Timeout) => Outcome::Timeout,
                (_, SpanVerdict::Failed) => Outcome::Failed,
                (o, SpanVerdict::Ok) if s.attempt > 1 => {
                    debug_assert!(matches!(o, SpanOutcome::Cold | SpanOutcome::Warm));
                    Outcome::Retried
                }
                (SpanOutcome::Cold, SpanVerdict::Ok) => Outcome::Cold,
                (SpanOutcome::Warm, SpanVerdict::Ok) => Outcome::Warm,
            },
            response_time: s.response_time,
            // Instance ids are per-function in a fleet trace; qualify them
            // so the warm-pool window never conflates two functions.
            instance_id: s
                .instance
                .map(|i| format!("f{}-i{}", s.function, i))
                .unwrap_or_default(),
        })
        .collect();
    // Fleet traces concatenate per-function span streams; the estimators
    // expect one time-ordered trace.
    records.sort_by(|a, b| a.arrived_at.total_cmp(&b.arrived_at));
    let p = identify(&records);
    let pool = mean_warm_pool(&records, window, skip);
    if json_out {
        use simfaas::output::json::JsonValue;
        let mut o = JsonValue::object();
        o.set("spans", records.len())
            .set("arrival_rate", p.arrival_rate)
            .set("warm_mean", p.warm_mean)
            .set("warm_std", p.warm_std)
            .set("cold_mean", p.cold_mean)
            .set("cold_std", p.cold_std)
            .set("cold_start_prob", p.cold_start_prob)
            .set("rejection_prob", p.rejection_prob)
            .set("mean_warm_pool", pool)
            .set("window", window);
        println!("{o}");
        return Ok(());
    }
    let mut t = Table::new(vec!["parameter", "estimate"]);
    t.row(vec!["spans".to_string(), format!("{}", records.len())]);
    t.row(vec!["arrival rate".to_string(), format!("{:.4} req/s", p.arrival_rate)]);
    t.row(vec!["warm mean".to_string(), format!("{:.4} s (std {:.4})", p.warm_mean, p.warm_std)]);
    t.row(vec!["cold mean".to_string(), format!("{:.4} s (std {:.4})", p.cold_mean, p.cold_std)]);
    t.row(vec!["cold start prob".to_string(), format!("{:.4} %", p.cold_start_prob * 100.0)]);
    t.row(vec!["rejection prob".to_string(), format!("{:.4} %", p.rejection_prob * 100.0)]);
    t.row(vec![format!("warm pool ({window:.0} s window)"), format!("{pool:.3}")]);
    print!("{t}");
    Ok(())
}

fn cmd_probe(args: &Args) -> Result<()> {
    use simfaas::emulator::EmulatorProbe;
    use simfaas::trace::probe_expiration_threshold;
    let mut cfg = EmulatorConfig::lambda_like(args.get_f64("scale", 10_000.0)?);
    cfg.expiration_threshold = args.get_f64("threshold", 600.0)?;
    cfg.synthetic_service = Some(Arc::new(simfaas::sim::ConstProcess::new(1.0)));
    cfg.provisioning_delay = 0.25;
    cfg.tick = 1.0;
    let step = args.get_f64("step", 60.0)?;
    let max_gap = args.get_f64("max-gap", 1_500.0)?;
    args.check_unknown()?;
    println!(
        "probing emulator (true threshold {} s) with step {} s...",
        cfg.expiration_threshold, step
    );
    let mut probe = EmulatorProbe::new(cfg);
    let (lo, hi) = probe_expiration_threshold(&mut probe, step, step, max_gap);
    println!("expiration threshold bracketed in ({lo:.1} s, {hi:.1} s]");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let all = args.get_bool("all");
    let which = args.get_u64("fig", 0)?;
    let out_dir = args.get_str("out-dir", "results");
    std::fs::create_dir_all(&out_dir)?;
    let seed = args.get_u64("seed", 0x5EED)?;
    let quick = args.get_bool("quick");
    args.check_unknown()?;
    let horizon = if quick { 100_000.0 } else { 1e6 };

    if all || which == 0 {
        println!("=== Table 1: steady-state example ===");
        let r = figures::table1(horizon, seed);
        print!("{r}");
        simfaas::output::write_csv_rows(
            format!("{out_dir}/table1.csv"),
            &[
                "cold_start_prob",
                "rejection_prob",
                "avg_lifespan",
                "avg_server",
                "avg_running",
                "avg_idle",
            ],
            &[vec![
                r.cold_start_prob,
                r.rejection_prob,
                r.avg_lifespan,
                r.avg_server_count,
                r.avg_running_count,
                r.avg_idle_count,
            ]],
        )?;
    }
    if all || which == 1 {
        println!("\n=== Fig 1: concurrency value (c=1 vs c=3) ===");
        use simfaas::sim::ParServerlessSimulator;
        let cfg = SimConfig::table1().with_arrival_rate(3.0).with_horizon(horizon.min(2e5));
        let r1 = ParServerlessSimulator::new(cfg.clone(), 1).run();
        let r3 = ParServerlessSimulator::new(cfg, 3).run();
        let mut t = Table::new(vec!["concurrency value", "avg servers", "p_cold %"]);
        t.row_f64(&[1.0, r1.avg_server_count, r1.cold_start_prob * 100.0], 4);
        t.row_f64(&[3.0, r3.avg_server_count, r3.cold_start_prob * 100.0], 4);
        print!("{t}");
    }
    if all || which == 3 {
        println!("\n=== Fig 3: instance count distribution ===");
        let pmf = figures::fig3_distribution(horizon, seed);
        let labels: Vec<String> = (0..pmf.len()).map(|i| i.to_string()).collect();
        print!("{}", ascii_histogram(&labels, &pmf, 48));
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig3.csv"),
            &["count", "p"],
            &pmf.iter().enumerate().map(|(i, &p)| vec![i as f64, p]).collect::<Vec<_>>(),
        )?;
    }
    if all || which == 4 {
        println!("\n=== Fig 4: avg instance count over time (10 runs, 95% CI) ===");
        let band = figures::fig4_band(if quick { 20_000.0 } else { 100_000.0 }, 200.0, 10, seed);
        let series = vec![
            Series::new("mean", band.iter().map(|&(t, m, _)| (t, m)).collect()),
            Series::new("mean+ci", band.iter().map(|&(t, m, h)| (t, m + h)).collect()),
            Series::new("mean-ci", band.iter().map(|&(t, m, h)| (t, m - h)).collect()),
        ];
        print!("{}", ascii_lines(&series, 72, 16));
        let last = band.last().unwrap();
        println!(
            "final: {:.4} ± {:.4} ({:.2}% of mean)",
            last.1,
            last.2,
            100.0 * last.2 / last.1
        );
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig4.csv"),
            &["t", "mean", "ci95_half_width"],
            &band.iter().map(|&(t, m, h)| vec![t, m, h]).collect::<Vec<_>>(),
        )?;
    }
    if all || which == 5 {
        println!("\n=== Fig 5: p_cold vs rate x threshold ===");
        let rates = [0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.2, 1.5, 2.0, 2.5, 3.0];
        let thresholds = [120.0, 300.0, 600.0, 1200.0];
        let out = figures::fig5_sweep(&rates, &thresholds, horizon.min(3e5), seed);
        let series: Vec<Series> = out
            .iter()
            .map(|(th, s)| {
                Series::new(format!("{th} s"), s.iter().map(|&(r, p)| (r, p * 100.0)).collect())
            })
            .collect();
        print!("{}", ascii_lines(&series, 72, 18));
        let rows: Vec<Vec<f64>> = rates
            .iter()
            .enumerate()
            .map(|(i, &r)| std::iter::once(r).chain(out.iter().map(|(_, s)| s[i].1)).collect())
            .collect();
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig5.csv"),
            &["rate", "p_cold_120s", "p_cold_300s", "p_cold_600s", "p_cold_1200s"],
            &rows,
        )?;
    }
    if all || which == 6 {
        println!("\n=== Figs 6-8: validation (simulator vs emulator) ===");
        let rates = if quick {
            vec![0.5, 1.0, 2.0]
        } else {
            vec![0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0]
        };
        let opts = figures::ValidationOpts {
            emu_horizon: if quick { 10_000.0 } else { 40_000.0 },
            ..Default::default()
        };
        let rows = figures::validation_rows(&rates, &opts);
        print_validation(&rows);
        simfaas::output::write_csv_rows(
            format!("{out_dir}/fig6_7_8.csv"),
            &[
                "rate",
                "sim_p_cold",
                "emu_p_cold",
                "sim_servers",
                "emu_servers",
                "sim_waste",
                "emu_waste",
            ],
            &rows
                .iter()
                .map(|r| {
                    vec![
                        r.rate,
                        r.sim.cold_start_prob,
                        r.emu.cold_start_prob,
                        r.sim.avg_server_count,
                        r.emu.avg_server_count,
                        r.sim.wasted_capacity,
                        r.emu.wasted_capacity,
                    ]
                })
                .collect::<Vec<_>>(),
        )?;
    }
    println!("\nCSV outputs in {out_dir}/");
    Ok(())
}
