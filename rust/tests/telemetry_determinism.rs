//! Telemetry determinism contract: recorded bytes are a pure function of
//! the run. Sharded fleets buffer per function and merge in function
//! order, so the JSONL span stream, the time-series CSV and the Chrome
//! trace-event JSON must come out byte-identical at any thread count —
//! and identical again on a re-run.

use simfaas::fleet::{FleetConfig, FleetResults, PolicySpec};
use simfaas::sim::Rng;
use simfaas::telemetry::{chrome_trace, write_samples_csv, write_spans_jsonl};
use simfaas::workload::SyntheticTrace;

/// Serialize every exporter's output for a fleet run into one byte blob.
fn export_bytes(res: &FleetResults) -> Vec<u8> {
    let recorders = res.telemetry.as_ref().expect("telemetry enabled");
    let mut bytes = Vec::new();
    for rec in recorders {
        write_spans_jsonl(&mut bytes, &rec.spans).unwrap();
    }
    let samples: Vec<_> =
        recorders.iter().flat_map(|r| r.samples.iter().cloned()).collect();
    write_samples_csv(&mut bytes, &samples).unwrap();
    bytes.extend(chrome_trace(recorders, &res.names).to_string().into_bytes());
    bytes
}

#[test]
fn sharded_fleet_exports_identical_bytes_at_any_thread_count() {
    let mut rng = Rng::new(21);
    let trace = SyntheticTrace::generate(8, &mut rng);
    let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 0x7E1E, PolicySpec::fixed(300.0))
        .with_telemetry(60.0);
    let reference = base.clone().with_threads(1).run();
    let ref_bytes = export_bytes(&reference);
    assert!(reference.aggregate.total_requests > 0);
    assert!(!ref_bytes.is_empty());
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(export_bytes(&res), ref_bytes, "threads={threads}");
    }
    // Re-running the same config replays the identical byte stream.
    let again = base.clone().run();
    assert_eq!(export_bytes(&again), ref_bytes);
}

/// Recorder invariants the exporters rely on: spans arrive per function in
/// nondecreasing start order, samples in nondecreasing tick order, every
/// span carries the owning function index, and the span count equals the
/// measured request count.
#[test]
fn recorded_streams_are_ordered_and_complete() {
    let mut rng = Rng::new(4);
    let trace = SyntheticTrace::generate(5, &mut rng);
    let res = FleetConfig::from_trace(&trace, 2_000.0, 0.0, 9, PolicySpec::fixed(300.0))
        .with_telemetry(50.0)
        .run();
    let recorders = res.telemetry.as_ref().unwrap();
    assert_eq!(recorders.len(), res.per_function.len());
    let mut span_total = 0u64;
    for (i, rec) in recorders.iter().enumerate() {
        for pair in rec.spans.windows(2) {
            assert!(pair[0].started_at <= pair[1].started_at, "function {i}");
        }
        for pair in rec.samples.windows(2) {
            assert!(pair[0].t < pair[1].t, "function {i}");
        }
        for s in &rec.spans {
            assert_eq!(s.function, i as u32);
        }
        for s in &rec.samples {
            assert_eq!(s.function, i as u32);
            // Sharded fleets run uncapped: no headroom column.
            assert!(s.cap_headroom.is_none());
        }
        span_total += rec.spans.len() as u64;
    }
    assert_eq!(span_total, res.aggregate.total_requests);
}

/// The coupled (capped) path records too, stamping the shared-gate
/// headroom on every sample; with a never-binding cap its spans match the
/// sharded run's bytes.
#[test]
fn capped_fleet_records_headroom_and_matches_sharded_spans() {
    let mut rng = Rng::new(13);
    let trace = SyntheticTrace::generate(4, &mut rng);
    let base = FleetConfig::from_trace(&trace, 2_000.0, 0.0, 0xCAB, PolicySpec::fixed(300.0))
        .with_telemetry(100.0);
    let sharded = base.clone().run();
    let capped = base.clone().with_fleet_cap(1_000_000).run();
    let (srec, crec) =
        (sharded.telemetry.as_ref().unwrap(), capped.telemetry.as_ref().unwrap());
    let mut sharded_spans = Vec::new();
    let mut capped_spans = Vec::new();
    for rec in srec {
        write_spans_jsonl(&mut sharded_spans, &rec.spans).unwrap();
    }
    for rec in crec {
        write_spans_jsonl(&mut capped_spans, &rec.spans).unwrap();
    }
    assert_eq!(sharded_spans, capped_spans);
    let mut saw_sample = false;
    for rec in crec {
        for s in &rec.samples {
            saw_sample = true;
            assert!(s.cap_headroom.is_some());
            assert!(s.cap_headroom.unwrap() <= 1_000_000);
        }
    }
    assert!(saw_sample);
}
