//! Struct-of-arrays instance storage for the lifecycle core.
//!
//! The pre-arena [`super::core::EngineCore`] kept a
//! `Vec<FunctionInstance>` that only ever grew: every cold start pushed a
//! new struct and terminated instances stayed behind as tombstones, so a
//! multi-day fleet run accumulated millions of dead 100-byte rows and the
//! hot handlers (arrival/departure/expiration) chased pointers through a
//! cold, ever-growing allocation. [`InstanceArena`] replaces it with:
//!
//! * **Struct-of-arrays columns** — each lifecycle field lives in its own
//!   dense `Vec`, so a handler touches only the cache lines of the two or
//!   three fields it actually reads (`in_flight`, `busy_since`,
//!   `generation`), not a whole row.
//! * **Free-list slot reuse** — when `retain` is off (the fleet's
//!   per-function engines), a terminated instance's *slot* is recycled for
//!   the next cold start, bounding resident memory by the engine's peak
//!   live count instead of its total churn.
//! * **Stable ordinal ids with generation indices** — [`InstanceId`]s stay
//!   the monotone creation ordinals the routers and telemetry rely on
//!   (newest = highest id, ids never reused). `slot_of` maps ordinal →
//!   current slot and tombstones freed ordinals, which doubles as the
//!   staleness guard: a late [`super::event::Event::Expiration`] aimed at
//!   a freed ordinal resolves to no slot and is dropped, exactly like the
//!   old terminated-state check. Per-slot `generation` counters guard
//!   lazy-cancelled expirations on *live* instances, unchanged.
//!
//! With `retain` on (the single-function simulators, whose
//! `instances()` accessor and tests inspect the full history) nothing is
//! ever freed, so slot == ordinal and the arena is a column-major view of
//! the old vector — bit-identical results either way, since id
//! assignment, state transitions and assertion semantics are exactly
//! [`FunctionInstance`]'s.

use super::instance::{FunctionInstance, InstanceId, InstanceState};
use super::time::SimTime;

/// Tombstone in `slot_of`: this ordinal's instance was terminated and its
/// slot recycled.
const FREED: u32 = u32::MAX;

/// Struct-of-arrays instance pool with free-list reuse. See the module
/// docs for the design; the mutation methods mirror
/// [`FunctionInstance`]'s transitions one-for-one (including the
/// debug assertions), which is what keeps the arena engines bit-identical
/// to the historical `Vec<FunctionInstance>` engines.
#[derive(Debug)]
pub struct InstanceArena {
    state: Vec<InstanceState>,
    created_at: Vec<SimTime>,
    idle_since: Vec<SimTime>,
    busy_since: Vec<SimTime>,
    terminated_at: Vec<SimTime>,
    generation: Vec<u64>,
    busy_time: Vec<f64>,
    requests_served: Vec<u64>,
    cold_only: Vec<bool>,
    in_flight: Vec<u32>,
    prewarmed: Vec<bool>,
    /// slot → the ordinal id currently occupying it.
    id_of: Vec<u64>,
    /// ordinal id → slot ([`FREED`] once recycled).
    slot_of: Vec<u32>,
    /// Recycled slots (LIFO — the hottest cache lines are reused first).
    free: Vec<u32>,
    /// When true, terminated instances keep their slots forever (the
    /// single-function simulators expose the full history).
    retain: bool,
}

impl InstanceArena {
    /// Empty arena with `cap` pre-reserved slots. `retain` keeps
    /// terminated instances resident (see the module docs).
    pub fn with_capacity(cap: usize, retain: bool) -> InstanceArena {
        InstanceArena {
            state: Vec::with_capacity(cap),
            created_at: Vec::with_capacity(cap),
            idle_since: Vec::with_capacity(cap),
            busy_since: Vec::with_capacity(cap),
            terminated_at: Vec::with_capacity(cap),
            generation: Vec::with_capacity(cap),
            busy_time: Vec::with_capacity(cap),
            requests_served: Vec::with_capacity(cap),
            cold_only: Vec::with_capacity(cap),
            in_flight: Vec::with_capacity(cap),
            prewarmed: Vec::with_capacity(cap),
            id_of: Vec::with_capacity(cap),
            slot_of: Vec::with_capacity(cap),
            free: Vec::new(),
            retain,
        }
    }

    /// Total instances ever created (the next ordinal id).
    #[inline]
    pub fn created(&self) -> usize {
        self.slot_of.len()
    }

    /// Resolve an ordinal id to its slot; `None` once the slot was
    /// recycled (the instance is long terminated).
    #[inline]
    fn slot(&self, id: InstanceId) -> Option<usize> {
        let s = self.slot_of[id.0 as usize];
        (s != FREED).then_some(s as usize)
    }

    /// Whether `id` still occupies a slot (not yet recycled).
    #[inline]
    pub fn is_resident(&self, id: InstanceId) -> bool {
        self.slot_of[id.0 as usize] != FREED
    }

    /// Allocate a cold-starting instance at `now`
    /// ([`FunctionInstance::cold_start`] semantics): state Initializing,
    /// all timestamps `now`, generation 0. Returns the new monotone
    /// ordinal id — identical to the id sequence of the historical
    /// grow-only vector.
    pub fn alloc(&mut self, now: SimTime, prewarmed: bool) -> InstanceId {
        let id = InstanceId(self.slot_of.len() as u64);
        match self.free.pop() {
            Some(slot) => {
                let s = slot as usize;
                self.state[s] = InstanceState::Initializing;
                self.created_at[s] = now;
                self.idle_since[s] = now;
                self.busy_since[s] = now;
                self.terminated_at[s] = now;
                self.generation[s] = 0;
                self.busy_time[s] = 0.0;
                self.requests_served[s] = 0;
                self.cold_only[s] = true;
                self.in_flight[s] = 0;
                self.prewarmed[s] = prewarmed;
                self.id_of[s] = id.0;
                self.slot_of.push(slot);
            }
            None => {
                debug_assert!(self.state.len() < FREED as usize, "slot index overflow");
                self.state.push(InstanceState::Initializing);
                self.created_at.push(now);
                self.idle_since.push(now);
                self.busy_since.push(now);
                self.terminated_at.push(now);
                self.generation.push(0);
                self.busy_time.push(0.0);
                self.requests_served.push(0);
                self.cold_only.push(true);
                self.in_flight.push(0);
                self.prewarmed.push(prewarmed);
                self.id_of.push(id.0);
                self.slot_of.push((self.state.len() - 1) as u32);
            }
        }
        id
    }

    /// Recycle a terminated instance's slot. No-op in retain mode. Must
    /// only be called after the instance was terminated and removed from
    /// the router — its ordinal becomes a tombstone, which is what drops
    /// any still-pending expiration events aimed at it.
    #[inline]
    pub fn release_slot(&mut self, id: InstanceId) {
        if self.retain {
            return;
        }
        let slot = self.slot_of[id.0 as usize];
        debug_assert_ne!(slot, FREED, "double release of {id}");
        debug_assert_eq!(self.state[slot as usize], InstanceState::Terminated);
        self.slot_of[id.0 as usize] = FREED;
        self.free.push(slot);
    }

    // ------------------------------------------------- lifecycle mutations

    /// [`FunctionInstance::finish_request`]: the busy period ends, the
    /// instance goes idle; returns the bumped generation.
    #[inline]
    pub fn finish_request(&mut self, id: InstanceId, now: SimTime, busy: f64) -> u64 {
        let s = self.slot_of[id.0 as usize] as usize;
        debug_assert!(matches!(
            self.state[s],
            InstanceState::Initializing | InstanceState::Running
        ));
        self.state[s] = InstanceState::Idle;
        self.idle_since[s] = now;
        self.busy_time[s] += busy;
        self.requests_served[s] += 1;
        self.generation[s] += 1;
        self.generation[s]
    }

    /// [`FunctionInstance::start_warm`]: an idle instance absorbs a
    /// request.
    #[inline]
    pub fn start_warm(&mut self, id: InstanceId, now: SimTime) {
        let s = self.slot_of[id.0 as usize] as usize;
        debug_assert_eq!(self.state[s], InstanceState::Idle);
        debug_assert!(now >= self.idle_since[s]);
        self.state[s] = InstanceState::Running;
        self.cold_only[s] = false;
        self.busy_since[s] = now;
        self.generation[s] += 1;
    }

    /// [`FunctionInstance::terminate`]: an idle instance expires.
    #[inline]
    pub fn terminate(&mut self, id: InstanceId, now: SimTime) {
        let s = self.slot_of[id.0 as usize] as usize;
        debug_assert_eq!(self.state[s], InstanceState::Idle);
        self.state[s] = InstanceState::Terminated;
        self.terminated_at[s] = now;
    }

    /// [`FunctionInstance::lifespan`] at `now`.
    #[inline]
    pub fn lifespan(&self, id: InstanceId, now: SimTime) -> f64 {
        let s = self.slot_of[id.0 as usize] as usize;
        if self.state[s] == InstanceState::Terminated {
            self.terminated_at[s].since(self.created_at[s])
        } else {
            now.since(self.created_at[s])
        }
    }

    // ------------------------------------------------------ field access

    /// Current lifecycle state of `id`.
    #[inline]
    pub fn state(&self, id: InstanceId) -> InstanceState {
        self.state[self.slot_of[id.0 as usize] as usize]
    }

    /// Requests in flight on `id`.
    #[inline]
    pub fn in_flight(&self, id: InstanceId) -> u32 {
        self.in_flight[self.slot_of[id.0 as usize] as usize]
    }

    /// Overwrite the in-flight count of `id`.
    #[inline]
    pub fn set_in_flight(&mut self, id: InstanceId, v: u32) {
        self.in_flight[self.slot_of[id.0 as usize] as usize] = v;
    }

    /// Busy-period start of `id`.
    #[inline]
    pub fn busy_since(&self, id: InstanceId) -> SimTime {
        self.busy_since[self.slot_of[id.0 as usize] as usize]
    }

    /// Generation counter of `id` (lazy-cancellation guard).
    #[inline]
    pub fn generation(&self, id: InstanceId) -> u64 {
        self.generation[self.slot_of[id.0 as usize] as usize]
    }

    /// Whether `id` was created by the prewarm path.
    #[inline]
    pub fn prewarmed(&self, id: InstanceId) -> bool {
        self.prewarmed[self.slot_of[id.0 as usize] as usize]
    }

    /// Requests served by `id` so far.
    #[inline]
    pub fn requests_served(&self, id: InstanceId) -> u64 {
        self.requests_served[self.slot_of[id.0 as usize] as usize]
    }

    /// Seed-state setup (the temporal simulator's warm pools): force `id`
    /// idle as of `at` with its creation time rewritten.
    #[inline]
    pub fn seed_idle(&mut self, id: InstanceId, at: SimTime) {
        let s = self.slot_of[id.0 as usize] as usize;
        self.state[s] = InstanceState::Idle;
        self.created_at[s] = at;
        self.idle_since[s] = at;
    }

    /// Seed-state setup: force `id` running with one request in flight.
    #[inline]
    pub fn seed_running(&mut self, id: InstanceId) {
        let s = self.slot_of[id.0 as usize] as usize;
        self.state[s] = InstanceState::Running;
        self.in_flight[s] = 1;
    }

    /// Prewarm completion ([`super::core::EngineCore`]'s ProvisioningDone):
    /// Initializing → Idle with a generation bump; returns the new
    /// generation.
    #[inline]
    pub fn provisioning_done(&mut self, id: InstanceId, now: SimTime) -> u64 {
        let s = self.slot_of[id.0 as usize] as usize;
        debug_assert_eq!(self.state[s], InstanceState::Initializing);
        debug_assert_eq!(self.in_flight[s], 0);
        self.state[s] = InstanceState::Idle;
        self.idle_since[s] = now;
        self.generation[s] += 1;
        self.generation[s]
    }

    /// Materialize the resident instances as [`FunctionInstance`] rows in
    /// ordinal order (diagnostic / test surface, not the hot path). With
    /// `retain` on this is the complete creation history, exactly the old
    /// grow-only vector.
    pub fn materialize(&self) -> Vec<FunctionInstance> {
        let mut out = Vec::with_capacity(self.slot_of.len() - self.free.len());
        for (ord, &slot) in self.slot_of.iter().enumerate() {
            if slot == FREED {
                continue;
            }
            let s = slot as usize;
            out.push(FunctionInstance {
                id: InstanceId(ord as u64),
                state: self.state[s],
                created_at: self.created_at[s],
                idle_since: self.idle_since[s],
                busy_since: self.busy_since[s],
                terminated_at: self.terminated_at[s],
                generation: self.generation[s],
                busy_time: self.busy_time[s],
                requests_served: self.requests_served[s],
                cold_only: self.cold_only[s],
                in_flight: self.in_flight[s],
                prewarmed: self.prewarmed[s],
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retain_mode_keeps_full_history_with_ordinal_slots() {
        let mut a = InstanceArena::with_capacity(4, true);
        let t0 = SimTime::from_secs(1.0);
        let i0 = a.alloc(t0, false);
        let i1 = a.alloc(t0, true);
        assert_eq!((i0, i1), (InstanceId(0), InstanceId(1)));
        a.finish_request(i0, SimTime::from_secs(3.0), 2.0);
        a.terminate(i0, SimTime::from_secs(9.0));
        a.release_slot(i0); // no-op in retain mode
        assert!(a.is_resident(i0));
        let rows = a.materialize();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].state, InstanceState::Terminated);
        assert_eq!(rows[0].requests_served, 1);
        assert!((rows[0].busy_time - 2.0).abs() < 1e-12);
        assert_eq!(rows[1].state, InstanceState::Initializing);
        assert!(rows[1].prewarmed);
        // Lifespan matches FunctionInstance: terminated_at - created_at.
        assert!((a.lifespan(i0, SimTime::from_secs(99.0)) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn free_list_recycles_slots_but_never_ids() {
        let mut a = InstanceArena::with_capacity(2, false);
        let t = SimTime::from_secs(0.0);
        let i0 = a.alloc(t, false);
        a.finish_request(i0, SimTime::from_secs(1.0), 1.0);
        a.terminate(i0, SimTime::from_secs(2.0));
        a.release_slot(i0);
        assert!(!a.is_resident(i0), "freed ordinal is a tombstone");
        // The next allocation reuses slot 0 under a brand-new ordinal,
        // with all columns reset to cold-start values.
        let i1 = a.alloc(SimTime::from_secs(5.0), false);
        assert_eq!(i1, InstanceId(1), "ids stay monotone across reuse");
        assert_eq!(a.state(i1), InstanceState::Initializing);
        assert_eq!(a.generation(i1), 0);
        assert_eq!(a.requests_served(i1), 0);
        assert_eq!(a.created(), 2);
        // Materialize skips the tombstoned ordinal.
        let rows = a.materialize();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].id, InstanceId(1));
    }

    #[test]
    fn transition_sequence_matches_function_instance() {
        // Drive the same lifecycle through FunctionInstance and the arena
        // and compare every observable.
        let mut inst = FunctionInstance::cold_start(InstanceId(0), SimTime::from_secs(5.0));
        let mut a = InstanceArena::with_capacity(1, true);
        let id = a.alloc(SimTime::from_secs(5.0), false);

        let g1 = inst.finish_request(SimTime::from_secs(7.0), 2.0);
        let g2 = a.finish_request(id, SimTime::from_secs(7.0), 2.0);
        assert_eq!(g1, g2);

        inst.start_warm(SimTime::from_secs(8.0));
        a.start_warm(id, SimTime::from_secs(8.0));
        assert_eq!(a.generation(id), inst.generation);

        let g1 = inst.finish_request(SimTime::from_secs(9.5), 1.5);
        let g2 = a.finish_request(id, SimTime::from_secs(9.5), 1.5);
        assert_eq!(g1, g2);

        inst.terminate(SimTime::from_secs(20.0));
        a.terminate(id, SimTime::from_secs(20.0));
        let row = &a.materialize()[0];
        assert_eq!(row.state, inst.state);
        assert_eq!(row.generation, inst.generation);
        assert_eq!(row.requests_served, inst.requests_served);
        assert!((row.busy_time - inst.busy_time).abs() < 1e-12);
        assert_eq!(
            a.lifespan(id, SimTime::from_secs(30.0)),
            inst.lifespan(SimTime::from_secs(30.0))
        );
        assert_eq!(row.cold_only, inst.cold_only);
    }
}
