"""AOT lowering: JAX entry points -> HLO text artifacts for the Rust side.

HLO *text* (not a serialized ``HloModuleProto``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids, which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
Produces one ``<name>.hlo.txt`` per entry point plus ``manifest.txt``
describing the input shapes the Rust runtime must feed.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args) -> str:
    """Lower a jittable function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the payloads bake their weights as constants;
    # the default printer elides them as `constant({...})`, which does not
    # parse back. Full literals make the text artifact self-contained.
    return comp.as_hlo_text(print_large_constants=True)


def describe(example_args) -> str:
    parts = []
    for a in example_args:
        dims = "x".join(str(d) for d in a.shape) if a.shape else "scalar"
        parts.append(f"{a.dtype}[{dims}]")
    return " ".join(parts)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="lower a single entry point by name"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, (fn, example_args) in model.ENTRY_POINTS.items():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, example_args)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name} {describe(example_args)}")
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest_path, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
