//! Telemetry record types: per-request spans and periodic internal-state
//! samples. Both are plain data — capture happens in `sim::core`, export in
//! [`super::export`].

/// How a dispatched request was (or was not) served — the routing outcome
/// of one attempt, including the reliability layer's cold-start failures
/// (which `sim::RequestOutcome` cannot express: no instance ever served
/// the request, but it was not a concurrency rejection either).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Served by a freshly cold-started instance.
    Cold,
    /// Served by a warm (idle or spare-slot) instance.
    Warm,
    /// Rejected at the concurrency limit (or the fleet gate).
    Rejected,
    /// The cold-start provisioning itself failed (reliability layer);
    /// no instance materialized.
    ColdStartFailed,
}

impl SpanOutcome {
    /// Stable wire name (JSONL / Chrome-trace event name).
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanOutcome::Cold => "cold",
            SpanOutcome::Warm => "warm",
            SpanOutcome::Rejected => "rejected",
            SpanOutcome::ColdStartFailed => "coldstart_failed",
        }
    }

    /// Inverse of [`as_str`](Self::as_str).
    pub fn parse(s: &str) -> Option<SpanOutcome> {
        match s {
            "cold" => Some(SpanOutcome::Cold),
            "warm" => Some(SpanOutcome::Warm),
            "rejected" => Some(SpanOutcome::Rejected),
            "coldstart_failed" => Some(SpanOutcome::ColdStartFailed),
            _ => None,
        }
    }
}

/// Execution verdict of a served request (reliability layer; always
/// [`SpanVerdict::Ok`] with faults disabled).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanVerdict {
    /// The execution completed successfully.
    Ok,
    /// The execution completed but returned a transient failure (or the
    /// cold-start provisioning failed).
    Failed,
    /// The execution exceeded the fault profile's timeout.
    Timeout,
}

impl SpanVerdict {
    /// Stable wire name (JSONL `verdict` field).
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanVerdict::Ok => "ok",
            SpanVerdict::Failed => "failed",
            SpanVerdict::Timeout => "timeout",
        }
    }

    /// Inverse of [`as_str`](Self::as_str).
    pub fn parse(s: &str) -> Option<SpanVerdict> {
        match s {
            "ok" => Some(SpanVerdict::Ok),
            "failed" => Some(SpanVerdict::Failed),
            "timeout" => Some(SpanVerdict::Timeout),
            _ => None,
        }
    }
}

/// One request-dispatch span: everything the engine knew about a single
/// routing attempt at the instant it resolved. Retried requests produce
/// one span per attempt, linked by increasing `attempt` numbers.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Fleet function index (0 for single-function engines).
    pub function: u32,
    /// When this attempt entered the arrival stream: the arrival epoch for
    /// first attempts, the previous failure instant for retries
    /// (`started_at - backoff delay`), so `started_at - queued_at` is the
    /// backoff the request waited.
    pub queued_at: f64,
    /// Dispatch instant (simulation seconds).
    pub started_at: f64,
    /// Busy period observed by the client: service (plus provisioning for
    /// cold starts), truncated at the timeout; 0 for rejected requests and
    /// cold-start failures.
    pub response_time: f64,
    /// Routing outcome of this attempt.
    pub outcome: SpanOutcome,
    /// Execution verdict of this attempt.
    pub verdict: SpanVerdict,
    /// Serving instance id (`None` for rejected / cold-start-failed).
    pub instance: Option<u64>,
    /// Dispatch attempt number (1 = fresh arrival, >1 = retry).
    pub attempt: u32,
}

/// One periodic snapshot of an engine's internal state — the platform
/// quantities the paper calls "otherwise hard (mostly impossible) to
/// extract from real platforms", as a time-series.
#[derive(Debug, Clone, PartialEq)]
pub struct StateSample {
    /// Fleet function index (0 for single-function engines).
    pub function: u32,
    /// Sample instant (simulation seconds; multiples of the sampling
    /// interval from the end of the warm-up skip).
    pub t: f64,
    /// Live instances (idle + busy + provisioning).
    pub live_instances: usize,
    /// Instances with at least one request in flight.
    pub busy_instances: usize,
    /// Live instances with nothing in flight (includes provisioning).
    pub idle_instances: usize,
    /// Requests currently in flight across all instances.
    pub in_flight: u64,
    /// Cumulative requests since the measured window started.
    pub total_requests: u64,
    /// Cumulative cold starts since the measured window started.
    pub cold_requests: u64,
    /// Cumulative warm starts since the measured window started.
    pub warm_requests: u64,
    /// Number of currently active degradation windows.
    pub degradation_active: u32,
    /// Remaining fleet-cap headroom at the shared gate (`None` when the
    /// engine runs uncapped).
    pub cap_headroom: Option<u64>,
}

impl StateSample {
    /// Cumulative cold-start rate at this sample: cold / (cold + warm),
    /// 0 before any request was served.
    pub fn cold_start_rate(&self) -> f64 {
        let served = self.cold_requests + self.warm_requests;
        if served > 0 {
            self.cold_requests as f64 / served as f64
        } else {
            0.0
        }
    }
}
