//! Integration test: the AOT contract between `python/compile/aot.py` and
//! the Rust PJRT runtime — every artifact loads, compiles and executes with
//! correct shapes; the histogram kernel agrees bit-for-bit with the
//! pure-Rust reference; payloads are deterministic and variant-distinct.
//!
//! Requires `make artifacts` (the Makefile test target orders this) and a
//! build with the `pjrt` feature; the default (offline) build compiles
//! this file to nothing.
#![cfg(feature = "pjrt")]

use simfaas::runtime::{ComputePool, Engine, PayloadKind, HIST_NBINS};
use simfaas::sim::{Histogram, Rng};

fn engine() -> Engine {
    Engine::load_dir(simfaas::runtime::default_artifacts_dir())
        .expect("artifacts missing: run `make artifacts`")
}

#[test]
fn all_payload_variants_execute_with_correct_shapes() {
    let e = engine();
    for kind in PayloadKind::ALL {
        let x: Vec<f32> = (0..kind.input_len()).map(|i| (i as f32 * 0.001).sin()).collect();
        let out = e.run_payload(kind, &x).unwrap();
        assert_eq!(out.len(), kind.output_len(), "{kind:?}");
        assert!(out.iter().all(|v| v.is_finite()), "{kind:?} produced non-finite output");
    }
}

#[test]
fn payload_variants_have_distinct_weights() {
    // Same input prefix, different baked weights -> different outputs.
    let e = engine();
    let x_small = vec![0.3f32; PayloadKind::Small.input_len()];
    let a = e.run_payload(PayloadKind::Small, &x_small).unwrap();
    let b = e.run_payload(PayloadKind::Small, &x_small).unwrap();
    assert_eq!(a, b, "payload must be deterministic");
    let x_medium = vec![0.3f32; PayloadKind::Medium.input_len()];
    let c = e.run_payload(PayloadKind::Medium, &x_medium).unwrap();
    assert_ne!(a[..8], c[..8], "variants should differ");
}

#[test]
fn payload_is_input_sensitive() {
    let e = engine();
    let k = PayloadKind::Small;
    let zeros = vec![0.0f32; k.input_len()];
    let ones = vec![1.0f32; k.input_len()];
    let a = e.run_payload(k, &zeros).unwrap();
    let b = e.run_payload(k, &ones).unwrap();
    assert_ne!(a, b);
    // relu(0 @ w1 + b1) @ w2 + b2 is a constant row repeated per batch row.
    let (batch, _, d_out) = k.shape();
    for row in 1..batch {
        for j in 0..d_out {
            assert!((a[row * d_out + j] - a[j]).abs() < 1e-5);
        }
    }
}

#[test]
fn histogram_kernel_exactly_matches_rust_reference() {
    let e = engine();
    let mut rng = Rng::new(0xCAFE);
    for (n, lo, hi) in [(1000usize, 0.0f32, 1.0f32), (200_000, 0.0, 8.0), (131_072, -2.0, 2.0)] {
        let samples: Vec<f32> = (0..n)
            .map(|_| (rng.normal(1.0, 1.5)) as f32)
            .collect();
        let counts = e.run_histogram(&samples, lo, hi).unwrap();
        let mut h = Histogram::new(lo as f64, hi as f64, HIST_NBINS);
        for &s in &samples {
            h.push(s as f64);
        }
        let expect: Vec<f64> = h.counts().iter().map(|&c| c as f64).collect();
        assert_eq!(counts, expect, "n={n} lo={lo} hi={hi}");
    }
}

#[test]
fn compute_pool_parallel_consistency() {
    // The pool must give the same answers as a direct engine, from any
    // number of client threads.
    let e = engine();
    let pool = std::sync::Arc::new(
        ComputePool::new(simfaas::runtime::default_artifacts_dir(), 2).unwrap(),
    );
    let k = PayloadKind::Small;
    let x = vec![0.7f32; k.input_len()];
    let direct = e.run_payload(k, &x).unwrap();
    let mut handles = Vec::new();
    for _ in 0..6 {
        let pool = std::sync::Arc::clone(&pool);
        let x = x.clone();
        handles.push(std::thread::spawn(move || pool.run_payload(k, x).unwrap()));
    }
    for h in handles {
        assert_eq!(h.join().unwrap(), direct);
    }
}
