//! `TraceSource` — the one typed seam every workload enters the simulator
//! through.
//!
//! Engines consume per-function [`FunctionSpec`]s; this module defines
//! where those specs come from: a [`SyntheticTrace`] (the generated
//! Azure-style mix), a real ingested [`AzureDataset`], explicit
//! caller-built specs, or a single recorded [`Workload`]. Every variant
//! yields **streaming** arrival sources (see [`super::stream`]) — no
//! arrival vector is materialized up front — plus provenance for reports
//! and rate/popularity statistics for validating the synthetic generator
//! against real data.
//!
//! `fleet::FleetConfig::from_source` builds a fleet from any variant;
//! `scenario::WorkloadSpec`'s `source` axis and the CLI's
//! `fleet --trace-dir` select one declaratively.

use super::azure::SyntheticTrace;
use super::azure_dataset::AzureDataset;
use super::generator::Workload;
use super::stream::{ArrivalSource, StreamSpec};
use crate::sim::ensemble::derive_seeds;
use crate::sim::process::Process;
use crate::sim::simulator::SimConfig;
use std::sync::Arc;

/// One function's arrival source specification (the cloneable half of
/// [`ArrivalSource`]).
#[derive(Clone)]
pub enum ArrivalMode {
    /// Inter-arrival process (the core simulator's model), drawn from the
    /// engine's RNG stream.
    Process(Process),
    /// Replay of pre-materialized, sorted absolute arrival times. `Arc`
    /// keeps [`FunctionSpec`] clones cheap for what-if sweeps.
    Trace(Arc<Vec<f64>>),
    /// Streaming thinning generator with its own seeded RNG stream —
    /// identical arrivals to materializing the generator eagerly, at O(1)
    /// resident memory per function.
    Streaming(StreamSpec),
}

impl ArrivalMode {
    /// Build the runtime [`ArrivalSource`] for one run over
    /// `[0, horizon)`. Stateful processes get fresh replica state so
    /// parallel shards never share mutable state (the fleet determinism
    /// contract); streaming sources reseed from their spec, so repeated
    /// runs replay identical arrivals.
    pub fn runtime(&self, horizon: f64) -> ArrivalSource {
        match self {
            ArrivalMode::Process(p) => ArrivalSource::process(p.replica()),
            // Trace modes are built from ingestion paths that sort (or
            // validate) timestamps up front, so an unsorted vector here is
            // construction-order corruption, not user input.
            ArrivalMode::Trace(t) => ArrivalSource::replay(Arc::clone(t))
                .expect("ArrivalMode::Trace timestamps must be sorted non-decreasing"),
            ArrivalMode::Streaming(s) => ArrivalSource::Stream(s.build(horizon)),
        }
    }
}

/// Per-function simulation parameters within a fleet.
#[derive(Clone)]
pub struct FunctionSpec {
    /// Display name (reports, top-K tables).
    pub name: String,
    /// Arrival source specification.
    pub arrival: ArrivalMode,
    /// Optional batch-size process (see [`SimConfig::batch_size`]).
    pub batch_size: Option<Process>,
    /// Warm-start busy-period process.
    pub warm_service: Process,
    /// Cold-start busy-period process.
    pub cold_service: Process,
    /// Per-function maximum concurrency (AWS Lambda default: 1000).
    pub max_concurrency: usize,
    /// Allocated memory in MB, for the fleet cost report.
    pub memory_mb: f64,
    /// RNG seed for this function's service (and process-arrival) draws.
    pub seed: u64,
}

impl FunctionSpec {
    /// Lift a core [`SimConfig`] into a fleet member. The config's own
    /// expiration fields are superseded by the fleet's policy, and the
    /// diagnostic-only knobs (`capture_request_log`, `sample_interval`)
    /// are not carried over — the fleet engine keeps per-function
    /// results but no per-request log or transient samples. The seed is
    /// kept so a 1-function fleet under a fixed policy reproduces
    /// `ServerlessSimulator::new(cfg).run()` bit-for-bit.
    pub fn from_sim_config(name: impl Into<String>, cfg: &SimConfig) -> Self {
        FunctionSpec {
            name: name.into(),
            arrival: ArrivalMode::Process(cfg.arrival.replica()),
            batch_size: cfg.batch_size.as_ref().map(Process::replica),
            warm_service: cfg.warm_service.replica(),
            cold_service: cfg.cold_service.replica(),
            max_concurrency: cfg.max_concurrency,
            memory_mb: 128.0,
            seed: cfg.seed,
        }
    }
}

/// Where a workload comes from: the typed source behind every trace-driven
/// experiment.
#[derive(Clone)]
pub enum TraceSource {
    /// Synthetic Azure-style tenant mix (Shahrad et al. characteristics).
    Synthetic(SyntheticTrace),
    /// Real ingested Azure Functions 2019 dataset.
    AzureDataset(AzureDataset),
    /// Explicit caller-built function specs.
    Explicit(Vec<FunctionSpec>),
    /// One recorded workload replayed as a single function (Table-1
    /// exponential services).
    Recorded(Workload),
}

impl TraceSource {
    /// Number of functions this source yields.
    pub fn len(&self) -> usize {
        match self {
            TraceSource::Synthetic(t) => t.functions.len(),
            TraceSource::AzureDataset(d) => d.functions.len(),
            TraceSource::Explicit(specs) => specs.len(),
            TraceSource::Recorded(_) => 1,
        }
    }

    /// Whether the source yields no functions.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Yield the per-function specs. Synthetic and ingested sources derive
    /// two SplitMix64 streams per function from `root_seed` (arrival
    /// generation and service draws) — the same derivation the historical
    /// eager `FleetConfig::from_trace` used, so synthetic fleets stay
    /// bit-identical through this seam.
    pub fn function_specs(&self, root_seed: u64) -> Vec<FunctionSpec> {
        match self {
            TraceSource::Synthetic(trace) => {
                let n = trace.functions.len();
                let seeds = derive_seeds(root_seed, 2 * n);
                trace
                    .functions
                    .iter()
                    .enumerate()
                    .map(|(i, f)| FunctionSpec {
                        name: f.name.clone(),
                        arrival: ArrivalMode::Streaming(StreamSpec::sinusoid(
                            f.mean_rate,
                            f.diurnal_depth,
                            f.peak_offset,
                            seeds[2 * i],
                        )),
                        batch_size: None,
                        warm_service: Process::exp_mean(f.warm_service_mean),
                        cold_service: Process::exp_mean(f.cold_service_mean),
                        max_concurrency: 1000,
                        memory_mb: 128.0,
                        seed: seeds[2 * i + 1],
                    })
                    .collect()
            }
            TraceSource::AzureDataset(ds) => {
                let n = ds.functions.len();
                let seeds = derive_seeds(root_seed, 2 * n);
                ds.functions
                    .iter()
                    .enumerate()
                    .map(|(i, f)| FunctionSpec {
                        name: f.name.clone(),
                        arrival: ArrivalMode::Streaming(StreamSpec::piecewise_daily(
                            Arc::clone(&f.minute_rates),
                            60.0,
                            seeds[2 * i],
                        )),
                        batch_size: None,
                        warm_service: Process::exp_mean(f.warm_service_mean),
                        cold_service: Process::exp_mean(f.cold_service_mean),
                        max_concurrency: 1000,
                        memory_mb: f.memory_mb,
                        seed: seeds[2 * i + 1],
                    })
                    .collect()
            }
            TraceSource::Explicit(specs) => specs.clone(),
            TraceSource::Recorded(w) => {
                let seeds = derive_seeds(root_seed, 2);
                vec![FunctionSpec {
                    name: "recorded".into(),
                    arrival: ArrivalMode::Trace(Arc::new(w.arrivals.clone())),
                    batch_size: None,
                    warm_service: Process::exp_mean(crate::figures::WARM_MEAN),
                    cold_service: Process::exp_mean(crate::figures::COLD_MEAN),
                    max_concurrency: 1000,
                    memory_mb: 128.0,
                    seed: seeds[1],
                }]
            }
        }
    }

    /// Provenance record for table and JSON reports.
    pub fn provenance(&self) -> TraceProvenance {
        match self {
            TraceSource::Synthetic(t) => TraceProvenance {
                kind: "synthetic".into(),
                detail: "Azure-style synthetic mix (Shahrad et al. characteristics)".into(),
                functions: t.functions.len(),
            },
            TraceSource::AzureDataset(d) => TraceProvenance {
                kind: "azure_dataset".into(),
                detail: d.describe(),
                functions: d.functions.len(),
            },
            TraceSource::Explicit(specs) => TraceProvenance {
                kind: "explicit".into(),
                detail: "caller-supplied function specs".into(),
                functions: specs.len(),
            },
            TraceSource::Recorded(w) => TraceProvenance {
                kind: "recorded".into(),
                detail: format!("{} recorded arrivals", w.len()),
                functions: 1,
            },
        }
    }

    /// Per-function mean-rate statistics, when the source carries rate
    /// profiles (synthetic and ingested traces; `None` for explicit and
    /// recorded sources). The validation seam: compare an ingested trace
    /// against the synthetic generator with [`TraceStats::comparison_table`].
    pub fn rate_stats(&self) -> Option<TraceStats> {
        let rates: Vec<f64> = match self {
            TraceSource::Synthetic(t) => t.functions.iter().map(|f| f.mean_rate).collect(),
            TraceSource::AzureDataset(d) => {
                d.functions.iter().map(|f| f.mean_rate()).collect()
            }
            _ => return None,
        };
        Some(TraceStats::from_rates(&rates))
    }
}

/// Where a report's workload came from: source kind, human detail, size.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceProvenance {
    /// Source kind tag: `synthetic` | `azure_dataset` | `explicit` |
    /// `recorded`.
    pub kind: String,
    /// Human-readable detail (directory, transforms, …).
    pub detail: String,
    /// Number of functions the source yielded.
    pub functions: usize,
}

impl TraceProvenance {
    /// One-line rendering for table reports.
    pub fn describe(&self) -> String {
        format!("{} — {} functions, {}", self.kind, self.functions, self.detail)
    }
}

/// Rate/popularity statistics of a multi-function trace — the common
/// yardstick for comparing the synthetic generator against ingested data.
#[derive(Debug, Clone)]
pub struct TraceStats {
    /// Number of functions.
    pub functions: usize,
    /// Sum of per-function mean rates (req/s).
    pub total_rate: f64,
    /// Mean of the per-function mean rates.
    pub mean_rate: f64,
    /// Hottest function's mean rate.
    pub max_rate: f64,
    /// Share of the total rate held by the busiest 10% of functions
    /// (popularity skew; heavy-tailed mixes approach 1).
    pub top_decile_share: f64,
    /// Coefficient of variation of the per-function rates.
    pub rate_cv: f64,
}

impl TraceStats {
    /// Compute from per-function mean rates.
    pub fn from_rates(rates: &[f64]) -> TraceStats {
        let n = rates.len();
        let total: f64 = rates.iter().sum();
        let mean = if n > 0 { total / n as f64 } else { 0.0 };
        let var = if n > 0 {
            rates.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n as f64
        } else {
            0.0
        };
        let mut sorted = rates.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let top = (n.div_ceil(10)).min(n);
        let top_sum: f64 = sorted.iter().take(top).sum();
        TraceStats {
            functions: n,
            total_rate: total,
            mean_rate: mean,
            max_rate: sorted.first().copied().unwrap_or(0.0),
            top_decile_share: if total > 0.0 { top_sum / total } else { 0.0 },
            rate_cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        }
    }

    /// Side-by-side comparison table of two labeled stat sets — the
    /// DESIGN.md §3 validation report (ingested vs synthetic).
    pub fn comparison_table(&self, label: &str, other: &TraceStats, other_label: &str) -> String {
        let rows: [(&str, f64, f64); 6] = [
            ("functions", self.functions as f64, other.functions as f64),
            ("total rate (req/s)", self.total_rate, other.total_rate),
            ("mean rate (req/s)", self.mean_rate, other.mean_rate),
            ("max rate (req/s)", self.max_rate, other.max_rate),
            ("top-decile share", self.top_decile_share, other.top_decile_share),
            ("rate CV", self.rate_cv, other.rate_cv),
        ];
        let mut s = format!("{:<20}  {:>14}  {:>14}\n", "statistic", label, other_label);
        for (name, a, b) in rows {
            s.push_str(&format!("{name:<20}  {a:>14.4}  {b:>14.4}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn synthetic_specs_mirror_the_trace_profiles() {
        let mut rng = Rng::new(11);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let src = TraceSource::Synthetic(trace.clone());
        assert_eq!(src.len(), 8);
        assert!(!src.is_empty());
        let specs = src.function_specs(0xBEEF);
        assert_eq!(specs.len(), 8);
        let seeds = derive_seeds(0xBEEF, 16);
        for (i, (spec, f)) in specs.iter().zip(&trace.functions).enumerate() {
            assert_eq!(spec.name, f.name);
            assert_eq!(spec.seed, seeds[2 * i + 1]);
            match &spec.arrival {
                ArrivalMode::Streaming(s) => {
                    assert_eq!(s.seed, seeds[2 * i]);
                    assert!((s.rate_max - f.mean_rate * (1.0 + f.diurnal_depth)).abs() < 1e-12);
                }
                _ => panic!("synthetic specs must stream"),
            }
        }
        // Derivation is deterministic.
        let again = src.function_specs(0xBEEF);
        assert_eq!(again[3].seed, specs[3].seed);
    }

    #[test]
    fn recorded_source_replays_the_workload() {
        let w = Workload { arrivals: vec![1.0, 2.0, 3.0] };
        let src = TraceSource::Recorded(w);
        assert_eq!(src.len(), 1);
        let specs = src.function_specs(1);
        match &specs[0].arrival {
            ArrivalMode::Trace(t) => assert_eq!(t.as_slice(), &[1.0, 2.0, 3.0]),
            _ => panic!("recorded specs must replay"),
        }
        assert_eq!(src.provenance().kind, "recorded");
        assert!(src.rate_stats().is_none());
    }

    #[test]
    fn rate_stats_capture_popularity_skew() {
        // 9 cold functions + 1 hot one: the top decile holds ~92% of the
        // rate.
        let rates: Vec<f64> = (0..9).map(|_| 0.1).chain([10.0]).collect();
        let stats = TraceStats::from_rates(&rates);
        assert_eq!(stats.functions, 10);
        assert!((stats.total_rate - 10.9).abs() < 1e-12);
        assert_eq!(stats.max_rate, 10.0);
        assert!((stats.top_decile_share - 10.0 / 10.9).abs() < 1e-12);
        assert!(stats.rate_cv > 2.0);
        let table = stats.comparison_table("a", &stats, "b");
        assert!(table.contains("top-decile share"));
        assert!(table.contains("rate CV"));
    }
}
