//! Trace-driven simulation on a synthetic Azure-style workload (Shahrad et
//! al. 2020 characteristics; DESIGN.md §3 substitutions): per-function
//! diurnal arrivals, heavy-tailed popularity, CPU/IO service mix — the
//! batch/"any distribution" regime the paper says Markovian models cannot
//! handle.
//!
//! Run with: `cargo run --release --example trace_driven`

use simfaas::output::Table;
use simfaas::sim::{Process, ServerlessSimulator, SimConfig};
use simfaas::workload::SyntheticTrace;

fn main() {
    let mut rng = simfaas::sim::Rng::new(2024);
    let trace = SyntheticTrace::generate(200, &mut rng);
    println!(
        "generated {} functions, total mean rate {:.2} req/s",
        trace.functions.len(),
        trace.total_mean_rate()
    );

    // Pick the three most popular functions and simulate each from its own
    // materialized arrival trace (EmpiricalProcess over the observed gaps).
    let mut by_rate: Vec<usize> = (0..trace.functions.len()).collect();
    by_rate.sort_by(|&a, &b| {
        trace.functions[b].mean_rate.partial_cmp(&trace.functions[a].mean_rate).unwrap()
    });

    let mut t = Table::new(vec![
        "function",
        "rate req/s",
        "warm s",
        "p_cold %",
        "avg servers",
        "waste %",
    ]);
    let horizon = 2.0 * 86_400.0;
    for &idx in by_rate.iter().take(3) {
        let f = &trace.functions[idx];
        let w = trace.arrivals_for(idx, horizon, &mut rng).expect("index from the trace");
        let gaps = w.gaps();
        if gaps.len() < 100 {
            continue;
        }
        let mut cfg = SimConfig::table1();
        cfg.arrival = Process::empirical(gaps);
        cfg.warm_service = simfaas::sim::GammaProcess::new(
            4.0,
            f.warm_service_mean / 4.0, // CV=0.5: realistic, non-Markovian
        )
        .into();
        cfg.cold_service = Process::gaussian(f.cold_service_mean, f.cold_service_mean * 0.15);
        cfg.horizon = horizon;
        let r = ServerlessSimulator::new(cfg).run();
        t.row(vec![
            f.name.clone(),
            format!("{:.3}", f.mean_rate),
            format!("{:.2}", f.warm_service_mean),
            format!("{:.3}", r.cold_start_prob * 100.0),
            format!("{:.2}", r.avg_server_count),
            format!("{:.1}", r.wasted_capacity * 100.0),
        ]);
    }
    print!("{t}");
    println!("\n(diurnal arrivals + gamma/gaussian service: all beyond Markovian models)");
}
