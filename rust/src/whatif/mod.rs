//! What-if analysis engine (paper §4.3): sweep platform/workload
//! configurations through the simulator, in parallel across OS threads, and
//! find best-performing settings — e.g. the expiration-threshold trade-off
//! of Fig. 5, or a cost/QoS-optimal threshold per workload.

pub mod sweep;

pub use sweep::{sweep, sweep_grid, GridPoint, SweepOutcome};

use crate::cluster::SchedulerSpec;
use crate::control::ControllerSpec;
use crate::cost::PricingTable;
use crate::fleet::{fleet_cost, FleetConfig, FleetCostReport, FleetResults, PolicySpec};
use crate::sim::ensemble::{derive_seeds, run_indexed, EnsembleOpts, EnsembleResults};
use crate::sim::fault::FaultProfile;
use crate::sim::retry::RetryPolicy;
use crate::sim::{ServerlessSimulator, SimConfig, SimResults};

/// Optimize the expiration threshold for a workload: minimize
/// `cost_weight * avg_server_count + coldstart_weight * cold_start_prob`
/// over a threshold grid (both terms normalized by their grid maxima so the
/// weights express relative importance). Returns the best threshold and the
/// per-point outcomes.
///
/// This is the provider-side knob the paper highlights: "provide users with
/// fine-grain control over the cost-performance trade-off by modifying the
/// platform parameters (e.g., expiration threshold)".
pub fn optimize_expiration_threshold(
    base: &SimConfig,
    thresholds: &[f64],
    cost_weight: f64,
    coldstart_weight: f64,
) -> (f64, Vec<(f64, SimResults)>) {
    assert!(!thresholds.is_empty());
    let outcomes: Vec<(f64, SimResults)> = sweep(thresholds, |&th| {
        let cfg = base.clone().with_expiration_threshold(th);
        ServerlessSimulator::new(cfg).run()
    })
    .into_iter()
    .map(|(th, r)| (*th, r))
    .collect();

    let max_servers = outcomes
        .iter()
        .map(|(_, r)| r.avg_server_count)
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let max_cold = outcomes
        .iter()
        .map(|(_, r)| r.cold_start_prob)
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    let best = outcomes
        .iter()
        .min_by(|a, b| {
            let score = |r: &SimResults| {
                cost_weight * r.avg_server_count / max_servers
                    + coldstart_weight * r.cold_start_prob / max_cold
            };
            score(&a.1).partial_cmp(&score(&b.1)).unwrap()
        })
        .map(|(th, _)| *th)
        .unwrap();
    (best, outcomes)
}

/// Ensemble what-if over the expiration-threshold grid (Fig. 5 with error
/// bars): every `(threshold, replication)` pair is one job on a single
/// shared thread pool, so the grid and the replications parallelize
/// together instead of nesting pools. Per-threshold results aggregate into
/// an [`EnsembleResults`] (mean ± 95% CI via
/// [`EnsembleResults::summary`]). Deterministic for a fixed
/// `opts.root_seed` regardless of `opts.threads`.
pub fn expiration_threshold_ensemble(
    base: &SimConfig,
    thresholds: &[f64],
    opts: &EnsembleOpts,
) -> Vec<(f64, EnsembleResults)> {
    assert!(!thresholds.is_empty());
    assert!(opts.replications >= 1);
    let seeds = derive_seeds(opts.root_seed, opts.replications);
    let n = thresholds.len() * opts.replications;
    let runs = run_indexed(n, opts.threads, |j| {
        let th = thresholds[j / opts.replications];
        let seed = seeds[j % opts.replications];
        let cfg = base.replica_with_seed(seed).with_expiration_threshold(th);
        ServerlessSimulator::new(cfg).run()
    });
    let mut out = Vec::with_capacity(thresholds.len());
    let mut it = runs.into_iter();
    for &th in thresholds {
        let chunk: Vec<SimResults> = it.by_ref().take(opts.replications).collect();
        out.push((th, EnsembleResults { seeds: seeds.clone(), runs: chunk }));
    }
    out
}

/// Outcome of running one keep-alive policy over a fleet: the fleet
/// results plus the priced cost rollup.
pub struct PolicyOutcome {
    pub label: String,
    pub results: FleetResults,
    pub cost: FleetCostReport,
}

/// Fleet-scale what-if: the same tenant mix (same traces, same seeds) under
/// a grid of fixed keep-alive thresholds plus any number of additional
/// policies (typically the adaptive hybrid-histogram policy). This is the
/// provider-side question the fleet subsystem exists to answer: what does
/// switching the platform's keep-alive policy do to cold starts, idle
/// waste, and cost across the whole mix?
///
/// Policies run sequentially; each fleet run parallelizes internally
/// (sharded across `base.threads` workers), so the grid inherits the
/// fleet's any-thread-count determinism. The base config's
/// `prewarm_lead` rides along unchanged, so a prewarm-enabled mix
/// compares its policies *with* the provisioning-lead arm active (only
/// policies with a prediction arm — the hybrid histogram — actually
/// prewarm).
pub fn keepalive_policy_comparison(
    base: &FleetConfig,
    fixed_thresholds: &[f64],
    extra_policies: &[PolicySpec],
    pricing: &PricingTable,
) -> Vec<PolicyOutcome> {
    let specs: Vec<PolicySpec> = fixed_thresholds
        .iter()
        .map(|&th| PolicySpec::fixed(th))
        .chain(extra_policies.iter().cloned())
        .collect();
    assert!(!specs.is_empty(), "no policies to compare");
    specs
        .into_iter()
        .map(|policy| {
            let cfg = base.clone().with_policy(policy);
            let results = cfg.run();
            let cost = fleet_cost(&cfg, &results, pricing);
            PolicyOutcome { label: cfg.policy.describe(), results, cost }
        })
        .collect()
}

/// Reliability what-if: the same tenant mix under the same fault profile,
/// swept across a grid of retry policies. Answers the developer-side
/// question the fault layer exists for: given the platform's failure
/// behaviour, how much goodput does each retry strategy recover, and what
/// does the extra (wasted) work cost?
///
/// Each run shares the base config's keep-alive policy, threads and
/// prewarm settings; only the retry policy varies. The fault RNG lane is
/// seeded per function (not per policy), so every policy faces the same
/// fault draws at the same dispatch points until retries perturb the
/// schedule.
pub fn retry_policy_comparison(
    base: &FleetConfig,
    fault: &FaultProfile,
    policies: &[RetryPolicy],
    pricing: &PricingTable,
) -> Vec<PolicyOutcome> {
    assert!(!policies.is_empty(), "no retry policies to compare");
    policies
        .iter()
        .map(|retry| {
            let cfg = base.clone().with_fault(fault.clone()).with_retry(retry.clone());
            let results = cfg.run();
            let cost = fleet_cost(&cfg, &results, pricing);
            PolicyOutcome { label: retry.describe(), results, cost }
        })
        .collect()
}

/// Provider-side placement what-if: the same tenant mix on the same
/// cluster hardware, swept across invoker-selection schedulers. Requires
/// `base.cluster` to be set — the sweep varies only the scheduler, so
/// every difference in cold starts, rejections, evictions, and per-host
/// utilization is attributable to the placement strategy alone. This is
/// the question the host layer exists to answer: what does changing the
/// placement algorithm do on fixed hardware?
pub fn scheduler_comparison(
    base: &FleetConfig,
    schedulers: &[SchedulerSpec],
    pricing: &PricingTable,
) -> Vec<PolicyOutcome> {
    let cluster = base
        .cluster
        .clone()
        .expect("scheduler_comparison requires a cluster-configured fleet");
    assert!(!schedulers.is_empty(), "no schedulers to compare");
    schedulers
        .iter()
        .map(|&scheduler| {
            let mut cl = cluster.clone();
            cl.scheduler = scheduler;
            let cfg = base.clone().with_cluster(cl);
            let results = cfg.run();
            let cost = fleet_cost(&cfg, &results, pricing);
            PolicyOutcome { label: scheduler.as_str().to_string(), results, cost }
        })
        .collect()
}

/// Autoscaling what-if: the same tenant mix under static capacity versus a
/// grid of feedback controllers ([`crate::control`]). The first outcome is
/// the uncontrolled baseline (labelled `static`); each controller then runs
/// the identical trace with the fleet cap or cluster host set moved at
/// simulated time. Comparing cost against rejections / cold starts across
/// the outcomes traces the cost-vs-SLO frontier the control subsystem
/// exists to expose: how much capacity (and therefore money) does each
/// policy spend to hold service quality?
///
/// Requires `base` to have a scalable backend — a `fleet_max_concurrency`
/// cap or a `cluster` — since a controller has nothing to actuate
/// otherwise.
pub fn controller_comparison(
    base: &FleetConfig,
    controllers: &[ControllerSpec],
    pricing: &PricingTable,
) -> Vec<PolicyOutcome> {
    assert!(
        base.fleet_max_concurrency.is_some() || base.cluster.is_some(),
        "controller_comparison requires a capped or clustered fleet"
    );
    assert!(!controllers.is_empty(), "no controllers to compare");
    let mut out = Vec::with_capacity(1 + controllers.len());
    let static_cfg = {
        let mut c = base.clone();
        c.controller = None;
        c
    };
    let results = static_cfg.run();
    let cost = fleet_cost(&static_cfg, &results, pricing);
    out.push(PolicyOutcome { label: "static".to_string(), results, cost });
    for spec in controllers {
        let cfg = base.clone().with_controller(*spec);
        let results = cfg.run();
        let cost = fleet_cost(&cfg, &results, pricing);
        out.push(PolicyOutcome { label: spec.as_str(), results, cost });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_ensemble_deterministic_and_monotone() {
        let mut base = SimConfig::table1();
        base.horizon = 8_000.0;
        let thresholds = [60.0, 1200.0];
        let opts = EnsembleOpts::new(4, 0x5EED);
        let a = expiration_threshold_ensemble(&base, &thresholds, &opts.with_threads(1));
        let b = expiration_threshold_ensemble(&base, &thresholds, &opts.with_threads(4));
        assert_eq!(a.len(), 2);
        for ((tha, ra), (thb, rb)) in a.iter().zip(&b) {
            assert_eq!(tha, thb);
            for (x, y) in ra.runs.iter().zip(&rb.runs) {
                assert_eq!(x.total_requests, y.total_requests);
                assert_eq!(x.cold_requests, y.cold_requests);
                assert_eq!(x.avg_server_count.to_bits(), y.avg_server_count.to_bits());
            }
        }
        // Longer threshold -> fewer cold starts (Fig. 5 shape), now with CI.
        let p_short = a[0].1.ci_of(|r| r.cold_start_prob);
        let p_long = a[1].1.ci_of(|r| r.cold_start_prob);
        assert!(p_long.mean < p_short.mean, "short={p_short:?} long={p_long:?}");
    }

    #[test]
    fn optimizer_prefers_long_threshold_when_cold_starts_dominate() {
        let mut base = SimConfig::table1();
        base.horizon = 60_000.0;
        let thresholds = [60.0, 600.0, 1800.0];
        let (best, outcomes) = optimize_expiration_threshold(&base, &thresholds, 0.0, 1.0);
        let probs: Vec<(f64, f64)> =
            outcomes.iter().map(|(t, r)| (*t, r.cold_start_prob)).collect();
        assert_eq!(best, 1800.0, "outcomes: {probs:?}");
    }

    #[test]
    fn optimizer_prefers_short_threshold_when_cost_dominates() {
        let mut base = SimConfig::table1();
        base.horizon = 60_000.0;
        let thresholds = [60.0, 600.0, 1800.0];
        let (best, _) = optimize_expiration_threshold(&base, &thresholds, 1.0, 0.0);
        assert_eq!(best, 60.0);
    }

    #[test]
    fn policy_comparison_covers_grid_and_adaptive_on_same_trace() {
        use crate::sim::Rng;
        use crate::workload::SyntheticTrace;
        let mut rng = Rng::new(31);
        let trace = SyntheticTrace::generate(10, &mut rng);
        let base =
            FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xCAFE, PolicySpec::fixed(600.0));
        let out = keepalive_policy_comparison(
            &base,
            &[60.0, 1200.0],
            &[PolicySpec::hybrid_histogram(3_600.0, 60.0)],
            &PricingTable::aws_lambda(),
        );
        assert_eq!(out.len(), 3);
        assert!(out[0].label.contains("fixed(60s)"));
        assert!(out[2].label.contains("hybrid-histogram"));
        // Same trace everywhere: total arrivals are policy-invariant.
        let totals: Vec<u64> =
            out.iter().map(|o| o.results.aggregate.total_requests).collect();
        assert_eq!(totals[0], totals[1]);
        assert_eq!(totals[0], totals[2]);
        // Fig. 5 shape at fleet scale: longer threshold, fewer cold starts,
        // more idle servers.
        let (short, long) = (&out[0].results.aggregate, &out[1].results.aggregate);
        assert!(long.cold_start_prob < short.cold_start_prob);
        assert!(long.avg_server_count > short.avg_server_count);
        // Cost report rides along for every policy.
        assert!(out.iter().all(|o| o.cost.total.requests > 0.0));
    }

    #[test]
    fn scheduler_comparison_diverges_on_azure_sample() {
        use crate::cluster::ClusterConfig;
        use crate::workload::{AzureDataset, TraceSource};
        use std::path::PathBuf;
        let dir =
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/azure_sample");
        let ds = AzureDataset::load(&dir).expect("bundled sample trace parses");
        let src = TraceSource::AzureDataset(ds);
        // A deliberately tight cluster: 2 hosts x 640 MB x 4 cores for a
        // 20-function mix with 128-512 MB footprints, so the placement
        // strategy is the binding constraint.
        let base = FleetConfig::from_source(&src, 7_200.0, 0.0, 0xC1A5, PolicySpec::fixed(600.0))
            .with_cluster(ClusterConfig::new(2, 640.0, 4.0));
        let out = scheduler_comparison(&base, &SchedulerSpec::all(), &PricingTable::aws_lambda());
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].label, "first-fit");
        // Same trace everywhere: total arrivals are scheduler-invariant.
        let totals: Vec<u64> =
            out.iter().map(|o| o.results.aggregate.total_requests).collect();
        assert!(totals.iter().all(|&t| t == totals[0] && t > 0), "{totals:?}");
        // Every run reports the cluster's shape, and the tight hardware
        // pushes back somewhere under every scheduler.
        for o in &out {
            let a = &o.results.aggregate;
            assert_eq!(a.host_utilization.len(), 2, "{}", o.label);
            assert!(
                a.placement_failures > 0 || a.evictions > 0 || a.rejected_requests > 0,
                "{}: the tight cluster should bind",
                o.label
            );
        }
        // The acceptance criterion: cold-start / rejection / utilization
        // outcomes actually diverge across >= 3 schedulers.
        let digests: std::collections::BTreeSet<Vec<u64>> = out
            .iter()
            .map(|o| {
                let a = &o.results.aggregate;
                let mut d = vec![a.cold_requests, a.rejected_requests, a.evictions];
                d.extend(a.host_utilization.iter().map(|u| u.to_bits()));
                d
            })
            .collect();
        assert!(digests.len() >= 3, "schedulers too similar: {} distinct", digests.len());
        // Cost reports ride along.
        assert!(out.iter().all(|o| o.cost.total.requests > 0.0));
    }

    #[test]
    fn controller_comparison_diverges_on_azure_sample() {
        use crate::workload::{AzureDataset, TraceSource};
        use std::path::PathBuf;
        let dir =
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/azure_sample");
        let ds = AzureDataset::load(&dir).expect("bundled sample trace parses");
        let src = TraceSource::AzureDataset(ds.top_k(10));
        // A deliberately tight fleet cap so static capacity rejects work and
        // every controller has something to fix.
        let base = FleetConfig::from_source(&src, 7_200.0, 0.0, 0xC1A5, PolicySpec::fixed(600.0))
            .with_fleet_cap(4);
        let controllers = [
            ControllerSpec::target_tracking(0.7).with_tick(30.0).with_bounds(2, 40),
            ControllerSpec::pid(0.8, 0.1, 0.05).with_tick(30.0).with_bounds(2, 40),
            ControllerSpec::step(0.3, 0.9).with_tick(30.0).with_bounds(2, 40),
        ];
        let out = controller_comparison(&base, &controllers, &PricingTable::aws_lambda());
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].label, "static");
        assert!(out[0].results.control.is_none());
        // Same trace everywhere: total arrivals are controller-invariant.
        let totals: Vec<u64> =
            out.iter().map(|o| o.results.aggregate.total_requests).collect();
        assert!(totals.iter().all(|&t| t == totals[0] && t > 0), "{totals:?}");
        // Every controlled run carries its control report and actually ticked.
        for o in &out[1..] {
            let report = o.results.control.as_ref().unwrap_or_else(|| {
                panic!("{}: controlled run must carry a control report", o.label)
            });
            assert!(report.ticks > 0, "{}", o.label);
            assert_eq!(o.label, report.spec);
        }
        // The acceptance criterion: >= 3 controllers land at distinct points
        // on the cost-vs-SLO frontier (capacity spent vs service quality).
        let digests: std::collections::BTreeSet<Vec<u64>> = out[1..]
            .iter()
            .map(|o| {
                let a = &o.results.aggregate;
                vec![
                    a.cold_requests,
                    a.rejected_requests,
                    a.billed_instance_seconds.to_bits(),
                    o.cost.total.developer_total().to_bits(),
                ]
            })
            .collect();
        assert!(digests.len() >= 3, "controllers too similar: {} distinct", digests.len());
        // The controllers buy service quality the static cap cannot:
        // scaling out strictly reduces rejections on this trace.
        let static_rej = out[0].results.aggregate.rejected_requests;
        assert!(
            out[1..].iter().any(|o| o.results.aggregate.rejected_requests < static_rej),
            "no controller beat the static cap ({static_rej} rejections)"
        );
    }

    #[test]
    fn retry_comparison_runs_same_mix_under_each_policy() {
        use crate::sim::Rng;
        use crate::workload::SyntheticTrace;
        let mut rng = Rng::new(17);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let base =
            FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xFA11, PolicySpec::fixed(600.0));
        let fault = FaultProfile::disabled().with_failure_prob(0.15);
        let out = retry_policy_comparison(
            &base,
            &fault,
            &[
                RetryPolicy::none(),
                RetryPolicy::fixed(0.5, 3),
                RetryPolicy::exponential(0.1, 5.0, 4),
            ],
            &PricingTable::aws_lambda(),
        );
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|o| !o.label.is_empty()));
        // Same mix and fault lane: transient failures occur under every
        // policy, but only retrying policies record attempts.
        assert!(out.iter().all(|o| o.results.aggregate.failed_requests > 0));
        assert_eq!(out[0].results.aggregate.retry_attempts, 0);
        assert!(out[1].results.aggregate.retry_attempts > 0);
        assert!(out[2].results.aggregate.retry_attempts > 0);
        // Retried work re-enters the stream: more served requests than the
        // no-retry baseline.
        assert!(
            out[1].results.aggregate.total_requests
                > out[0].results.aggregate.total_requests
        );
        // Cost reflects each policy's own run.
        assert!(out.iter().all(|o| o.cost.total.requests > 0.0));
    }
}
