//! Payload registry: the AOT-compiled entry points and their geometries.
//!
//! Must stay in sync with `python/compile/model.py` (`PAYLOAD_SHAPES`,
//! `HIST_N`, `HIST_NBINS`); `artifacts/manifest.txt` is the build-time
//! contract and `Engine::load_dir` cross-checks it at load time.

/// The serverless-function compute payloads (three emulated memory
/// configurations; larger = more FLOPs per request = longer service time).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PayloadKind {
    Small,
    Medium,
    Large,
}

impl PayloadKind {
    pub const ALL: [PayloadKind; 3] = [PayloadKind::Small, PayloadKind::Medium, PayloadKind::Large];

    /// Artifact base name (matches `model.ENTRY_POINTS`).
    pub fn artifact_name(&self) -> &'static str {
        match self {
            PayloadKind::Small => "payload_small",
            PayloadKind::Medium => "payload_medium",
            PayloadKind::Large => "payload_large",
        }
    }

    /// (batch, d_in, d_out) — mirrors `model.PAYLOAD_SHAPES` (d_hidden is
    /// internal to the artifact).
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            PayloadKind::Small => (128, 128, 128),
            PayloadKind::Medium => (128, 256, 128),
            PayloadKind::Large => (128, 512, 128),
        }
    }

    pub fn input_len(&self) -> usize {
        let (b, d_in, _) = self.shape();
        b * d_in
    }

    pub fn output_len(&self) -> usize {
        let (b, _, d_out) = self.shape();
        b * d_out
    }

    /// The emulated memory configuration this payload stands in for (MB).
    pub fn memory_mb(&self) -> f64 {
        match self {
            PayloadKind::Small => 128.0,
            PayloadKind::Medium => 256.0,
            PayloadKind::Large => 512.0,
        }
    }
}

/// Shared string→payload parsing for the CLI (`--payload`); the sentinel
/// "none" (no payload) is the caller's concern, not a `PayloadKind`.
impl std::str::FromStr for PayloadKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "small" => PayloadKind::Small,
            "medium" => PayloadKind::Medium,
            "large" => PayloadKind::Large,
            other => anyhow::bail!("unknown payload {other:?} (expected small|medium|large)"),
        })
    }
}

/// Histogram analysis graph geometry (mirrors `model.HIST_N/HIST_NBINS`).
pub const HIST_N: usize = 131_072;
pub const HIST_NBINS: usize = 64;
pub const HIST_ARTIFACT: &str = "trace_histogram";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_consistent() {
        for k in PayloadKind::ALL {
            let (b, d_in, d_out) = k.shape();
            assert_eq!(k.input_len(), b * d_in);
            assert_eq!(k.output_len(), b * d_out);
            assert!(k.memory_mb() >= 128.0);
        }
        assert!(PayloadKind::Small.input_len() < PayloadKind::Large.input_len());
    }
}
