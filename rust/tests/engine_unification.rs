//! Golden regression fixtures for the engine unification (`sim::core`).
//!
//! The refactor folded three hand-synchronized lifecycle implementations
//! (`ServerlessSimulator`, `ParServerlessSimulator`, `fleet::FunctionEngine`)
//! into one core. These tests pin the five pre-refactor configurations —
//! steady, concurrency-value, temporal, 1-function fleet, capped fleet —
//! two ways:
//!
//! * **Deterministic goldens**: constant-process workloads whose every
//!   output row is derivable by hand from the paper's model definition, so
//!   the expected values below are exactly what the pre-refactor engines
//!   provably produced (no recorded snapshots needed, and any lifecycle
//!   regression shows up as a concrete wrong number).
//! * **Cross-engine digests**: configurations where two engines are
//!   specified to be the same stochastic system must agree bit-for-bit —
//!   same RNG draw sequence, same event order, same accumulator updates.
//!
//! Plus the prewarm property: a provisioning lead of 0 (or a positive lead
//! under a policy with no prediction arm) reproduces the no-prewarm engine
//! bit-for-bit.

use simfaas::fleet::{FleetConfig, PolicySpec};
use simfaas::sim::{
    FaultProfile, InitialState, ParServerlessSimulator, Process, RetryPolicy, Rng,
    ServerlessSimulator, ServerlessTemporalSimulator, SimConfig, SimResults,
};
use simfaas::workload::SyntheticTrace;

/// Every scalar output of a run, exact-comparable (floats by bit pattern).
fn digest(r: &SimResults) -> Vec<u64> {
    vec![
        r.total_requests,
        r.cold_requests,
        r.warm_requests,
        r.rejected_requests,
        r.instances_created,
        r.instances_expired,
        r.prewarm_starts,
        r.cold_start_prob.to_bits(),
        r.rejection_prob.to_bits(),
        r.avg_lifespan.to_bits(),
        r.avg_server_count.to_bits(),
        r.avg_running_count.to_bits(),
        r.avg_idle_count.to_bits(),
        r.max_server_count.to_bits(),
        r.wasted_capacity.to_bits(),
        r.avg_response_time.to_bits(),
        r.response_p50.to_bits(),
        r.response_p95.to_bits(),
        r.response_p99.to_bits(),
        r.billed_instance_seconds.to_bits(),
        r.wasted_prewarm_seconds.to_bits(),
        r.failed_requests,
        r.timeout_requests,
        r.coldstart_failures,
        r.retry_attempts,
        r.retry_exhausted,
        r.wasted_work_seconds.to_bits(),
        r.goodput.to_bits(),
    ]
}

fn fleet_digest(res: &simfaas::FleetResults) -> Vec<u64> {
    let mut d: Vec<u64> = res.per_function.iter().flat_map(digest).collect();
    let a = &res.aggregate;
    d.extend([
        a.total_requests,
        a.cold_requests,
        a.rejected_requests,
        a.cap_rejections,
        a.prewarm_starts,
        a.cold_start_prob.to_bits(),
        a.avg_server_count.to_bits(),
        a.response_p95.to_bits(),
        a.billed_instance_seconds.to_bits(),
        a.wasted_prewarm_seconds.to_bits(),
        a.failed_requests,
        a.timeout_requests,
        a.coldstart_failures,
        a.retry_attempts,
        a.retry_exhausted,
        a.wasted_work_seconds.to_bits(),
        a.goodput.to_bits(),
    ]);
    d
}

fn const_cfg(arrival: f64, warm: f64, cold: f64, threshold: f64, horizon: f64) -> SimConfig {
    SimConfig {
        arrival: Process::constant(arrival),
        batch_size: None,
        warm_service: Process::constant(warm),
        cold_service: Process::constant(cold),
        expiration_threshold: threshold,
        expiration_process: None,
        max_concurrency: 1000,
        horizon,
        skip_initial: 0.0,
        seed: 7,
        capture_request_log: false,
        sample_interval: 0.0,
        fault: FaultProfile::disabled(),
        retry: RetryPolicy::none(),
    }
}

/// Steady fixture: arrivals every 5 s, warm 1 s, cold 2 s, threshold 600 s,
/// horizon 10_000 s. One cold start at t=5, the instance then lives to the
/// horizon serving every request warm (idle gaps of 3–4 s never expire).
#[test]
fn steady_deterministic_golden() {
    let r = ServerlessSimulator::new(const_cfg(5.0, 1.0, 2.0, 600.0, 10_000.0)).run();
    assert_eq!(r.total_requests, 1999); // arrivals at 5, 10, ..., 9995
    assert_eq!(r.cold_requests, 1);
    assert_eq!(r.warm_requests, 1998);
    assert_eq!(r.rejected_requests, 0);
    assert_eq!(r.instances_created, 1);
    assert_eq!(r.instances_expired, 0);
    // Busy seconds: 2 (cold) + 1998 * 1 (warm), all exact in f64.
    assert_eq!(r.billed_instance_seconds, 2000.0);
    // Alive from t=5 to the 10_000 s horizon.
    assert!((r.avg_server_count - 0.9995).abs() < 1e-12);
    assert!((r.avg_running_count - 0.2).abs() < 1e-12);
    assert!((r.avg_idle_count - 0.7995).abs() < 1e-12);
    assert_eq!(r.max_server_count, 1.0);
    assert!((r.avg_response_time - 2000.0 / 1999.0).abs() < 1e-9);
    assert!((r.observed_arrival_rate - 0.1999).abs() < 1e-12);
    assert!((r.cold_start_prob - 1.0 / 1999.0).abs() < 1e-15);
    assert_eq!(r.prewarm_starts, 0);
    assert_eq!(r.wasted_prewarm_seconds, 0.0);
}

/// The same fixture must come out of all three engine surfaces
/// bit-for-bit: the scale-per-request simulator, the concurrency-value
/// simulator at c=1, and a 1-function fleet under the fixed policy.
#[test]
fn steady_fixture_identical_across_all_three_engines() {
    let cfg = const_cfg(5.0, 1.0, 2.0, 600.0, 10_000.0);
    let spr = ServerlessSimulator::new(cfg.clone()).run();
    let par = ParServerlessSimulator::new(cfg.clone(), 1).run();
    let fleet = FleetConfig::from_sim_configs(&[cfg], PolicySpec::fixed(600.0)).run();
    assert_eq!(digest(&spr), digest(&par));
    assert_eq!(digest(&spr), digest(&fleet.per_function[0]));
}

/// Stochastic cross-engine digests: with exponential processes the three
/// surfaces are specified to draw the identical RNG stream.
#[test]
fn stochastic_cross_engine_digests_match() {
    let cfg = SimConfig::table1().with_horizon(30_000.0).with_seed(0xD1CE);
    let spr = ServerlessSimulator::new(cfg.clone()).run();
    let par = ParServerlessSimulator::new(cfg.clone(), 1).run();
    let fleet = FleetConfig::from_sim_configs(&[cfg], PolicySpec::fixed(600.0)).run();
    assert_eq!(digest(&spr), digest(&par));
    assert_eq!(digest(&spr), digest(&fleet.per_function[0]));
}

/// Concurrency-value fixture (c=2): arrivals every 1 s, service 1.5 s. One
/// instance absorbs everything with 1–2 requests in flight at all times;
/// the busy period never closes, so — per the historical billing rule
/// (bill when the instance drains) — billed time stays 0.
#[test]
fn par_deterministic_golden() {
    let r = ParServerlessSimulator::new(const_cfg(1.0, 1.5, 1.5, 10.0, 100.0), 2).run();
    assert_eq!(r.total_requests, 99); // arrivals at 1, 2, ..., 99
    assert_eq!(r.cold_requests, 1);
    assert_eq!(r.warm_requests, 98);
    assert_eq!(r.rejected_requests, 0);
    assert_eq!(r.instances_created, 1);
    assert_eq!(r.instances_expired, 0);
    assert_eq!(r.billed_instance_seconds, 0.0);
    assert!((r.avg_server_count - 0.99).abs() < 1e-12);
    // In-flight integral: [1,2] at 1, then per period 0.5 s at 2 + 0.5 s
    // at 1 -> 1 + 98 * 1.5 = 148 over 100 s.
    assert!((r.avg_running_count - 1.48).abs() < 1e-12);
    // The instance is busy the whole [1, 100] window: zero idle.
    assert!(r.avg_idle_count.abs() < 1e-12);
    assert_eq!(r.max_server_count, 1.0);
    // Every response is exactly 1.5 s, so even the P² estimators are exact.
    assert_eq!(r.avg_response_time, 1.5);
    assert_eq!(r.response_p50, 1.5);
    assert_eq!(r.response_p99, 1.5);
}

/// Temporal fixture: 3 just-idle instances at t=0, threshold 25 s,
/// deterministic arrivals every 10 s, warm 1 s, horizon 200 s. Newest-first
/// routing starves instances 0 and 1 (they expire at exactly t=25) while
/// instance 2 serves all 19 arrivals warm.
#[test]
fn temporal_deterministic_golden() {
    let cfg = const_cfg(10.0, 1.0, 1.2, 25.0, 200.0);
    let sim = ServerlessTemporalSimulator::new(cfg, InitialState::warm_pool(3), 3);
    let res = sim.run();
    assert_eq!(res.runs.len(), 3);
    for r in &res.runs {
        assert_eq!(r.total_requests, 19); // arrivals at 10, 20, ..., 190
        assert_eq!(r.cold_requests, 0);
        assert_eq!(r.warm_requests, 19);
        assert_eq!(r.instances_expired, 2);
        assert_eq!(r.avg_lifespan, 25.0);
        assert_eq!(r.billed_instance_seconds, 19.0);
        // Level: 3 instances until t=25, then 1 until 200 -> 250/200.
        assert!((r.avg_server_count - 1.25).abs() < 1e-12);
        assert_eq!(r.max_server_count, 3.0);
    }
    // Identical deterministic replications -> zero CI half-width.
    assert!((res.avg_server_count_ci.0 - 1.25).abs() < 1e-12);
    assert!(res.avg_server_count_ci.1.abs() < 1e-12);
}

/// The temporal engine is replication-for-replication the plain simulator
/// with `replica_with_seed(seed + i)` and the same initial state.
#[test]
fn temporal_replications_match_manual_core_runs() {
    let mut cfg = SimConfig::table1().with_horizon(3_000.0).with_seed(0xBEE);
    cfg.skip_initial = 0.0;
    let res = ServerlessTemporalSimulator::new(cfg.clone(), InitialState::warm_pool(2), 4).run();
    for (i, run) in res.runs.iter().enumerate() {
        let mut solo = ServerlessSimulator::new(cfg.replica_with_seed(cfg.seed + i as u64));
        solo.set_initial_state(&[0.0, 0.0], &[]);
        assert_eq!(digest(run), digest(&solo.run()), "replication {i}");
    }
}

/// Capped-fleet fixture: two deterministic functions, fleet cap 1. The
/// first cold start (function A at t=4, busy 100 s) holds the only slot
/// for the whole 50 s horizon; every other request in either function is a
/// gate-only rejection.
#[test]
fn capped_fleet_deterministic_golden() {
    let a = const_cfg(4.0, 1.0, 100.0, 600.0, 50.0);
    let b = const_cfg(5.0, 1.0, 100.0, 600.0, 50.0);
    let res = FleetConfig::from_sim_configs(&[a, b], PolicySpec::fixed(600.0))
        .with_fleet_cap(1)
        .run();
    let (fa, fb) = (&res.per_function[0], &res.per_function[1]);
    assert_eq!((fa.total_requests, fa.cold_requests, fa.rejected_requests), (12, 1, 11));
    assert_eq!((fb.total_requests, fb.cold_requests, fb.rejected_requests), (9, 0, 9));
    let agg = &res.aggregate;
    assert_eq!(agg.total_requests, 21);
    assert_eq!(agg.rejected_requests, 20);
    assert_eq!(agg.cap_rejections, 20); // per-function limits never bind
    // A's instance is alive (busy) from t=4 to the horizon; B never runs.
    assert!((fa.avg_server_count - 0.92).abs() < 1e-12);
    assert_eq!(fb.avg_server_count, 0.0);
    // The busy period never closes before the horizon: nothing billed.
    assert_eq!(agg.billed_instance_seconds, 0.0);
}

/// Prewarm property: a provisioning lead of 0 — or a positive lead under
/// any policy without a prediction arm — reproduces the no-prewarm engine
/// bit-for-bit, on stochastic synthetic tenant mixes.
#[test]
fn prewarm_lead_zero_is_bit_identical_to_no_prewarm() {
    for seed in [3u64, 11, 42] {
        let mut rng = Rng::new(seed);
        let trace = SyntheticTrace::generate(6, &mut rng);
        for policy in [
            PolicySpec::fixed(300.0),
            PolicySpec::stochastic(Process::exp_mean(300.0)),
            PolicySpec::hybrid_histogram(600.0, 10.0),
        ] {
            let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, seed, policy.clone());
            let plain = base.clone().run();
            // Lead 0 is the disabled state.
            let lead_zero = base.clone().with_prewarm_lead(0.0).run();
            assert_eq!(fleet_digest(&plain), fleet_digest(&lead_zero), "seed {seed}");
            // A positive lead under a predictionless policy schedules no
            // Provision events, so it must also be bit-identical.
            if !matches!(policy, PolicySpec::HybridHistogram { .. }) {
                let lead_pos = base.clone().with_prewarm_lead(20.0).run();
                assert_eq!(fleet_digest(&plain), fleet_digest(&lead_pos), "seed {seed}");
            }
        }
    }
}

/// At most one prewarm is in flight at a time — including while an
/// instance is still *provisioning*: pool drains during the lead window
/// must not spawn a second speculative instance for the same predicted
/// arrival.
#[test]
fn single_prewarm_in_flight_covers_the_whole_lead_window() {
    use simfaas::fleet::{ArrivalMode, FunctionSpec, KeepAlivePolicy};
    use std::sync::Arc;

    /// Scripted policy: 0.5 s keep-alive, always predicts an arrival at
    /// t=40 (until that time passes). No RNG use anywhere.
    struct PredictForty;
    impl KeepAlivePolicy for PredictForty {
        fn keep_alive(&mut self, _now: f64, _rng: &mut simfaas::sim::Rng) -> f64 {
            0.5
        }
        fn predict_next_arrival(&mut self, now: f64) -> Option<f64> {
            (now < 40.0).then_some(40.0)
        }
        fn describe(&self) -> String {
            "predict-forty".into()
        }
    }

    let spec = FunctionSpec {
        name: "scripted".into(),
        arrival: ArrivalMode::Trace(Arc::new(vec![5.0, 6.0, 37.2])),
        batch_size: None,
        warm_service: Process::constant(1.0),
        cold_service: Process::constant(2.0),
        max_concurrency: 1000,
        memory_mb: 128.0,
        seed: 1,
    };
    let cfg = FleetConfig {
        functions: vec![spec],
        policy: PolicySpec::custom("predict-forty", || Box::new(PredictForty)),
        fleet_max_concurrency: None,
        cluster: None,
        capacity_domains: 1,
        horizon: 50.0,
        skip_initial: 0.0,
        threads: 1,
        prewarm_lead: 3.0,
        fault: FaultProfile::disabled(),
        retry: RetryPolicy::none(),
        telemetry: None,
        controller: None,
    };
    let results = cfg.run();
    let r = &results.per_function[0];
    // Timeline: cold starts at 5 and 6 expire by 8.5; the first drain (at
    // 7.5) schedules one Provision for t=37 (= predicted 40 - lead 3).
    // The second drain at 8.5 and — the regression — the drain at 39.7
    // (the t=37.2 cold start expiring *while the prewarm instance is
    // still provisioning*, Done at t=40) must both be absorbed by the
    // pending prewarm: exactly one speculative instance ever starts.
    assert_eq!(r.cold_requests, 3);
    assert_eq!(r.warm_requests, 0);
    assert_eq!(r.prewarm_starts, 1);
    // That one instance provisions at 37, is ready at 40, and expires
    // unused at 40.5: its whole 3.5 s lifespan is wasted prewarm time.
    assert_eq!(r.instances_expired, 4);
    assert!((r.wasted_prewarm_seconds - 3.5).abs() < 1e-9, "{}", r.wasted_prewarm_seconds);
}

/// Prewarm-enabled fleets keep the sharded determinism contract:
/// bit-identical output for any thread count.
#[test]
fn prewarm_fleet_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(77);
    let trace = SyntheticTrace::generate(10, &mut rng);
    let base = FleetConfig::from_trace(
        &trace,
        4_000.0,
        0.0,
        0xF1EE7,
        PolicySpec::hybrid_histogram(600.0, 10.0),
    )
    .with_prewarm_lead(15.0);
    let reference = base.clone().with_threads(1).run();
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
    }
    // And the coupled path agrees with the sharded path when the cap
    // never binds, prewarm instances included.
    let coupled = base.clone().with_fleet_cap(1_000_000).run();
    assert_eq!(fleet_digest(&coupled), fleet_digest(&reference));
}

/// Cluster-layer bit-identity contract: a single host with unbounded
/// memory and cpus admits everything, evicts nothing, and perturbs no
/// engine (no RNG draws, no extra events) — so the clustered runner must
/// reproduce the uncapped sharded fleet bit-for-bit, per function and in
/// aggregate, and the cluster counters must all stay zero.
#[test]
fn unbounded_single_host_cluster_matches_uncapped_fleet() {
    use simfaas::ClusterConfig;
    for seed in [9u64, 0xC1A5] {
        let mut rng = Rng::new(seed);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, seed, PolicySpec::fixed(120.0));
        let reference = base.clone().run();
        let clustered = base.clone().with_cluster(ClusterConfig::unbounded(1)).run();
        assert_eq!(fleet_digest(&clustered), fleet_digest(&reference), "seed {seed}");
        let a = &clustered.aggregate;
        assert_eq!((a.cap_rejections, a.placement_failures, a.evictions), (0, 0, 0));
        assert_eq!(a.host_utilization, vec![0.0]);
    }
}

/// The clustered runner is a single-queue engine: `threads` is ignored, so
/// a finite cluster — placements, failures, and evictions actually firing —
/// produces bit-identical output for any thread count.
#[test]
fn clustered_fleet_bit_identical_across_thread_counts() {
    use simfaas::{ClusterConfig, SchedulerSpec};
    let mut rng = Rng::new(55);
    let trace = SyntheticTrace::generate(10, &mut rng);
    let base = FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xC1A5, PolicySpec::fixed(300.0))
        .with_cluster(
            ClusterConfig::new(2, 512.0, 4.0).with_scheduler(SchedulerSpec::LeastLoaded),
        );
    let reference = base.clone().with_threads(1).run();
    // The hosts actually bind — this is not a vacuous pin.
    let a = &reference.aggregate;
    assert!(a.placement_failures > 0 || a.evictions > 0 || a.rejected_requests > 0);
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
    }
}

/// Reliability-layer bit-identity contract: a disabled [`FaultProfile`] —
/// even alongside an armed [`RetryPolicy`] — never touches the fault RNG
/// lane or schedules a reliability event, so every engine's output digest
/// (reliability counters included) equals the fault-free run's, bit for
/// bit.
#[test]
fn disabled_fault_profile_is_bit_identical_on_every_engine() {
    let cfg = SimConfig::table1().with_horizon(30_000.0).with_seed(0xFA17);
    let faulted = cfg
        .clone()
        .with_fault(FaultProfile::disabled())
        .with_retry(RetryPolicy::exponential(0.1, 5.0, 4));

    let steady = ServerlessSimulator::new(cfg.clone()).run();
    let steady_f = ServerlessSimulator::new(faulted.clone()).run();
    assert_eq!(digest(&steady), digest(&steady_f));
    assert_eq!(steady_f.failed_requests, 0);
    assert_eq!(steady_f.retry_attempts, 0);

    let par = ParServerlessSimulator::new(cfg.clone(), 3).run();
    let par_f = ParServerlessSimulator::new(faulted.clone(), 3).run();
    assert_eq!(digest(&par), digest(&par_f));

    let fleet = FleetConfig::from_sim_configs(&[cfg], PolicySpec::fixed(600.0)).run();
    let fleet_f = FleetConfig::from_sim_configs(&[faulted], PolicySpec::fixed(600.0))
        .with_fault(FaultProfile::disabled())
        .with_retry(RetryPolicy::exponential(0.1, 5.0, 4))
        .run();
    assert_eq!(fleet_digest(&fleet), fleet_digest(&fleet_f));
}

/// Retry storms keep the sharded determinism contract: each engine draws
/// retries and fault verdicts from its own seed-derived fault lane, so a
/// faulted fleet is bit-identical for any thread count (and the coupled
/// path agrees while the cap never binds).
#[test]
fn faulted_fleet_bit_identical_across_thread_counts() {
    let mut rng = Rng::new(91);
    let trace = SyntheticTrace::generate(10, &mut rng);
    let base = FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xFA57, PolicySpec::fixed(300.0))
        .with_fault(
            FaultProfile::disabled()
                .with_failure_prob(0.1)
                .with_coldstart_failure_prob(0.02)
                .with_timeout(8.0),
        )
        .with_retry(RetryPolicy::exponential(0.05, 2.0, 4));
    let reference = base.clone().with_threads(1).run();
    // The faults actually fired — this is not a vacuous pin.
    assert!(reference.aggregate.failed_requests > 0);
    assert!(reference.aggregate.retry_attempts > 0);
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
    }
    let coupled = base.clone().with_fleet_cap(1_000_000).run();
    assert_eq!(fleet_digest(&coupled), fleet_digest(&reference));
}

/// Control-layer inertness property: a *configured but inert* controller
/// — target-tracking with step limit 0, PID with every gain 0 — ticks on
/// schedule yet never actuates, so both backends (flat gate cap, finite
/// cluster) must reproduce the no-controller engines bit for bit.
#[test]
fn inert_controllers_are_bit_identical_to_no_controller_engines() {
    use simfaas::{ClusterConfig, ControllerSpec};
    let inert = [
        ControllerSpec::parse("target:0.7,60,0").expect("spec"),
        ControllerSpec::parse("pid:0,0,0,0.7").expect("spec"),
    ];
    let mut rng = Rng::new(23);
    let trace = SyntheticTrace::generate(8, &mut rng);
    let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 23, PolicySpec::fixed(300.0));

    let capped = base.clone().with_fleet_cap(3);
    let capped_ref = capped.clone().run();
    assert!(capped_ref.aggregate.cap_rejections > 0); // the cap binds
    for spec in inert {
        let res = capped.clone().with_controller(spec).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&capped_ref), "gate {}", spec.as_str());
        let ctl = res.control.expect("control report");
        assert!(ctl.ticks > 0);
        assert_eq!(ctl.scale_up_events + ctl.scale_down_events, 0);
    }

    let clustered = base.clone().with_cluster(ClusterConfig::new(2, 512.0, 4.0));
    let clustered_ref = clustered.clone().run();
    for spec in inert {
        let res = clustered.clone().with_controller(spec).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&clustered_ref), "cluster {}", spec.as_str());
        assert!(res.control.expect("control report").ticks > 0);
    }
}

/// The point of autoscaling, pinned as a digest inequality: a target-
/// tracking controller allowed to raise a tight gate cap mid-run must
/// shed gate-only rejections vs the static-cap run on the same seed.
#[test]
fn controller_raising_the_cap_sheds_gate_rejections() {
    use simfaas::ControllerSpec;
    let mut rng = Rng::new(31);
    let trace = SyntheticTrace::generate(8, &mut rng);
    let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 31, PolicySpec::fixed(300.0))
        .with_fleet_cap(2);
    let static_run = base.clone().run();
    assert!(static_run.aggregate.cap_rejections > 0, "static cap must bind");
    let spec = ControllerSpec::target_tracking(0.7).with_tick(20.0).with_bounds(2, 64);
    let controlled = base.with_controller(spec).run();
    let ctl = controlled.control.as_ref().expect("control report");
    assert!(ctl.scale_up_events > 0, "controller never scaled out");
    assert!(
        controlled.aggregate.cap_rejections < static_run.aggregate.cap_rejections,
        "controlled {} vs static {}",
        controlled.aggregate.cap_rejections,
        static_run.aggregate.cap_rejections
    );
}

/// Configured controllers keep the sharded determinism contract: control
/// state lives with each capacity domain's single-queue loop, so for a
/// fixed domain count a controlled fleet is bit-identical (samples
/// included) at any thread count.
#[test]
fn controlled_fleet_bit_identical_across_thread_counts() {
    use simfaas::ControllerSpec;
    let mut rng = Rng::new(47);
    let trace = SyntheticTrace::generate(10, &mut rng);
    let spec = ControllerSpec::target_tracking(0.7).with_tick(25.0).with_bounds(2, 32);
    for domains in [1usize, 3] {
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 47, PolicySpec::fixed(300.0))
            .with_fleet_cap(4)
            .with_capacity_domains(domains)
            .with_controller(spec);
        let reference = base.clone().with_threads(1).run();
        let ref_ctl = reference.control.as_ref().expect("control report");
        assert!(ref_ctl.ticks > 0, "domains={domains}");
        for threads in [2, 8] {
            let res = base.clone().with_threads(threads).run();
            assert_eq!(
                fleet_digest(&res),
                fleet_digest(&reference),
                "domains={domains} threads={threads}"
            );
            assert_eq!(
                res.control.as_ref().expect("control report").samples,
                ref_ctl.samples,
                "domains={domains} threads={threads}"
            );
        }
    }
}

/// Telemetry zero-overhead contract: an *enabled* observer draws no RNG
/// and schedules no events, so every engine's output digest is
/// bit-identical to the unobserved run (and with telemetry off the fleet
/// carries no recorder buffers at all).
#[test]
fn telemetry_enabled_is_bit_identical_on_every_engine() {
    use simfaas::telemetry::Observer;
    let cfg = SimConfig::table1().with_horizon(30_000.0).with_seed(0x0B5);

    let plain = ServerlessSimulator::new(cfg.clone()).run();
    let mut observed = ServerlessSimulator::new(cfg.clone());
    observed.set_observer(Observer::recording(0, 60.0));
    let observed_res = observed.run();
    assert_eq!(digest(&plain), digest(&observed_res));
    let rec = observed.take_recorder().expect("recording observer");
    assert_eq!(rec.spans.len() as u64, plain.total_requests);
    assert!(!rec.samples.is_empty());

    let par_plain = ParServerlessSimulator::new(cfg.clone(), 3).run();
    let mut par_obs = ParServerlessSimulator::new(cfg.clone(), 3);
    par_obs.set_observer(Observer::recording(0, 60.0));
    let par_res = par_obs.run();
    assert_eq!(digest(&par_plain), digest(&par_res));
    assert!(par_obs.take_recorder().is_some());

    let fleet_plain =
        FleetConfig::from_sim_configs(&[cfg.clone()], PolicySpec::fixed(600.0)).run();
    let fleet_obs = FleetConfig::from_sim_configs(&[cfg], PolicySpec::fixed(600.0))
        .with_telemetry(60.0)
        .run();
    assert_eq!(fleet_digest(&fleet_plain), fleet_digest(&fleet_obs));
    assert!(fleet_plain.telemetry.is_none());
    let recs = fleet_obs.telemetry.as_ref().expect("telemetry enabled");
    assert_eq!(recs.len(), 1);
    assert_eq!(recs[0].spans.len() as u64, fleet_plain.aggregate.total_requests);
    assert!(!recs[0].samples.is_empty());
}
