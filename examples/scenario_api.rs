//! Scenario API tour: the declarative way to drive every experiment.
//!
//! Builds a `ScenarioSpec` fluently, runs it through the one
//! `run_scenario` entry point, then shows the same spec as the JSON a
//! `simfaas run <file>` scenario file would contain — the programmatic
//! and file-driven surfaces are the same object.
//!
//! Run with: `cargo run --release --example scenario_api`

use simfaas::scenario::{
    run_scenario, CostSpec, ExperimentSpec, ProcessSpec, ScenarioSpec,
};

fn main() -> anyhow::Result<()> {
    // 1. A priced steady-state experiment on a bursty MMPP workload.
    let spec = ScenarioSpec::new("bursty-priced")
        .with_arrival(ProcessSpec::Mmpp { rates: [2.0, 0.2], switch: [0.01, 0.02] })
        .with_services(
            ProcessSpec::LogNormal { mean: 1.5, cv: 0.6 },
            ProcessSpec::ExpMean(2.244),
        )
        .with_expiration_threshold(300.0)
        .with_horizon(100_000.0)
        .with_seed(7)
        .with_cost(CostSpec::default());

    println!("== scenario: {} ==", spec.name);
    let report = run_scenario(&spec)?;
    print!("{}", report.render(&spec));

    // 2. The identical experiment as a `simfaas run` file.
    println!("\n-- as scenario JSON (simfaas run <file>) --");
    println!("{}", spec.to_json_string());

    // 3. Swap one axis — the experiment — and the same description drives
    //    the replication ensemble instead (ensembles are not priced, so
    //    the cost axis comes off).
    let mut ensemble = spec
        .clone()
        .with_experiment(ExperimentSpec::ensemble(8))
        .with_horizon(20_000.0);
    ensemble.cost = None;
    println!("\n== same platform, ensemble experiment ==");
    let report = run_scenario(&ensemble)?;
    print!("{}", report.render(&ensemble));
    Ok(())
}
