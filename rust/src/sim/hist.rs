//! PDF/CDF approximation tools (paper §3: "tools that can accept custom
//! state encoding and generate approximations for Probability Density
//! Functions (PDF) and Cumulative Distribution Functions (CDF) from the
//! simulations").
//!
//! Two flavours:
//!
//! * [`CountDistribution`] — time-weighted distribution over integer levels
//!   (instance counts). This is what Fig. 3 plots: the portion of simulated
//!   time spent at each instance count.
//! * [`Histogram`] — fixed-bin histogram over continuous samples (response
//!   times, lifespans), with PDF/CDF extraction and comparison against an
//!   analytical CDF. For multi-million-sample traces the bin counting can
//!   also be offloaded to the AOT-compiled Pallas histogram kernel via
//!   `runtime::AnalyticsEngine`; `Histogram` is the pure-Rust reference the
//!   kernel is cross-checked against.

use super::time::SimTime;

/// Time-weighted distribution over small non-negative integer levels.
#[derive(Debug, Clone)]
pub struct CountDistribution {
    /// time spent at level i.
    weights: Vec<f64>,
    last_t: SimTime,
    level: usize,
    total: f64,
}

impl CountDistribution {
    pub fn new(start: SimTime, initial_level: usize) -> Self {
        CountDistribution { weights: vec![0.0; 16], last_t: start, level: initial_level, total: 0.0 }
    }

    /// Record a level change at time `t`.
    pub fn update(&mut self, t: SimTime, new_level: usize) {
        debug_assert!(t >= self.last_t);
        let dt = t.since(self.last_t);
        if self.level >= self.weights.len() {
            self.weights.resize(self.level + 1, 0.0);
        }
        self.weights[self.level] += dt;
        self.total += dt;
        self.last_t = t;
        self.level = new_level;
    }

    /// Close the window at `t` keeping the level.
    pub fn finish(&mut self, t: SimTime) {
        let lvl = self.level;
        self.update(t, lvl);
    }

    /// Restart accumulation (skip warm-up transient).
    pub fn reset_at(&mut self, t: SimTime) {
        self.weights.iter_mut().for_each(|w| *w = 0.0);
        self.total = 0.0;
        self.last_t = t;
    }

    /// Probability mass function over levels: portion of time at each count.
    pub fn pmf(&self) -> Vec<f64> {
        if self.total <= 0.0 {
            return vec![];
        }
        let hi = self
            .weights
            .iter()
            .rposition(|&w| w > 0.0)
            .map(|i| i + 1)
            .unwrap_or(0);
        self.weights[..hi].iter().map(|w| w / self.total).collect()
    }

    /// CDF over levels.
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.pmf()
            .into_iter()
            .map(|p| {
                acc += p;
                acc
            })
            .collect()
    }

    /// Time-weighted mean level.
    pub fn mean(&self) -> f64 {
        if self.total <= 0.0 {
            return f64::NAN;
        }
        self.weights
            .iter()
            .enumerate()
            .map(|(i, w)| i as f64 * w)
            .sum::<f64>()
            / self.total
    }

    pub fn total_time(&self) -> f64 {
        self.total
    }
}

/// Fixed-bin histogram over continuous non-negative samples.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    below: u64,
    above: u64,
    n: u64,
}

impl Histogram {
    /// `nbins` equal-width bins covering [lo, hi).
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], below: 0, above: 0, n: 0 }
    }

    /// Build from samples with automatic range (min..max padded).
    pub fn auto(samples: &[f64], nbins: usize) -> Self {
        assert!(!samples.is_empty());
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &s in samples {
            lo = lo.min(s);
            hi = hi.max(s);
        }
        if hi <= lo {
            hi = lo + 1.0;
        }
        let pad = (hi - lo) * 1e-9;
        let mut h = Histogram::new(lo, hi + pad, nbins);
        for &s in samples {
            h.push(s);
        }
        h
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let nbins = self.bins.len();
            let w = (self.hi - self.lo) / nbins as f64;
            let idx = (((x - self.lo) / w) as usize).min(nbins - 1);
            self.bins[idx] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.bins
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn range(&self) -> (f64, f64) {
        (self.lo, self.hi)
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.bins.len() as f64
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let w = self.bin_width();
        (0..self.bins.len())
            .map(|i| self.lo + (i as f64 + 0.5) * w)
            .collect()
    }

    /// Density estimate (integrates to the in-range mass).
    pub fn pdf(&self) -> Vec<f64> {
        if self.n == 0 {
            return vec![0.0; self.bins.len()];
        }
        let w = self.bin_width();
        self.bins
            .iter()
            .map(|&c| c as f64 / (self.n as f64 * w))
            .collect()
    }

    /// Empirical CDF evaluated at the right edge of each bin.
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = self.below as f64;
        self.bins
            .iter()
            .map(|&c| {
                acc += c as f64;
                acc / self.n as f64
            })
            .collect()
    }

    /// Max deviation between this histogram's CDF and an analytical CDF
    /// (paper §3: verify a developed model against simulation output).
    pub fn max_cdf_deviation<F: Fn(f64) -> f64>(&self, analytical: F) -> f64 {
        let w = self.bin_width();
        self.cdf()
            .iter()
            .enumerate()
            .map(|(i, &emp)| {
                let edge = self.lo + (i as f64 + 1.0) * w;
                (emp - analytical(edge)).abs()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn count_distribution_pmf_sums_to_one() {
        let mut d = CountDistribution::new(SimTime::ZERO, 0);
        d.update(SimTime::from_secs(1.0), 1); // level 0 for 1s
        d.update(SimTime::from_secs(3.0), 2); // level 1 for 2s
        d.finish(SimTime::from_secs(4.0)); // level 2 for 1s
        let pmf = d.pmf();
        assert_eq!(pmf.len(), 3);
        assert!((pmf.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((pmf[0] - 0.25).abs() < 1e-12);
        assert!((pmf[1] - 0.5).abs() < 1e-12);
        assert!((pmf[2] - 0.25).abs() < 1e-12);
        assert!((d.mean() - 1.0).abs() < 1e-12);
        let cdf = d.cdf();
        assert!((cdf[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn count_distribution_grows_levels() {
        let mut d = CountDistribution::new(SimTime::ZERO, 40);
        d.finish(SimTime::from_secs(2.0));
        assert_eq!(d.pmf().len(), 41);
        assert!((d.mean() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 1.5, 9.99] {
            h.push(x);
        }
        h.push(-1.0); // below
        h.push(10.0); // above (right-open)
        assert_eq!(h.n(), 6);
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[1], 1);
        assert_eq!(h.counts()[9], 1);
        let cdf = h.cdf();
        assert!((cdf[9] - 5.0 / 6.0).abs() < 1e-12); // 'above' never enters bins
    }

    #[test]
    fn histogram_pdf_integrates_to_mass() {
        let mut rng = Rng::new(20);
        let samples: Vec<f64> = (0..100_000).map(|_| rng.exponential(1.0)).collect();
        let h = Histogram::auto(&samples, 200);
        let mass: f64 = h.pdf().iter().sum::<f64>() * h.bin_width();
        assert!((mass - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cdf_deviation_against_true_exponential() {
        let mut rng = Rng::new(21);
        let mut h = Histogram::new(0.0, 20.0, 400);
        for _ in 0..200_000 {
            h.push(rng.exponential(1.0));
        }
        let dev = h.max_cdf_deviation(|x| 1.0 - (-x).exp());
        assert!(dev < 0.01, "dev={dev}");
    }
}
