//! Bench: regenerate Fig. 4 (mean instance count over time across 10
//! replications with 95% CI; the paper reports <1% deviation at the end).
#[path = "harness.rs"]
mod harness;

use simfaas::figures;

fn main() {
    harness::header(
        "Fig 4",
        "cumulative-average instance count vs time, 10 runs, 95% CI \
         (replications fan out on the sim::ensemble thread pool)",
        "CI deviation < 1% of the mean at the end of the run",
    );
    let horizon = if harness::quick() { 2e4 } else { 1e5 };
    let (_, band) = harness::bench("fig4/10_replications", 2, || {
        figures::fig4_band(horizon, horizon / 500.0, 10, 0x5EED)
    });
    println!();
    println!("t        mean     ci95");
    for (t, m, h) in band.iter().step_by(band.len() / 20) {
        println!("{t:>8.0} {m:>8.4} ±{h:.4}");
    }
    let last = band.last().unwrap();
    let pct = 100.0 * last.2 / last.1;
    println!(
        "final: {:.4} ± {:.4} => {:.3}% of mean (paper: <1%) {}",
        last.1,
        last.2,
        pct,
        if pct < 1.0 { "OK" } else { "ABOVE-PAPER" }
    );
}
