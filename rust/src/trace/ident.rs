//! Parameter identification (paper §5.2): estimate the simulator's input
//! parameters from measured traces — the exact procedures the paper runs
//! against AWS Lambda, here runnable against any trace in the shared CSV
//! schema (including the emulator's logs).
//!
//! * **Expiration threshold probing**: issue requests with increasing
//!   inter-arrival gaps until a cold start appears; the previous gap bounds
//!   the threshold ("starting inter-arrival time of 10 seconds, each time
//!   increasing it by 10 seconds until we see a cold start").
//! * **Warm/cold response-time estimation**: averages over the measured
//!   response times per outcome class.
//! * **Arrival-rate estimation** and instance-count reconstruction: count
//!   unique instance ids seen in a sliding window ("we count the number of
//!   unique instances that have responded ... in the past 10 minutes").

use super::record::{Outcome, RequestRecord};

/// Estimated workload/platform parameters.
#[derive(Debug, Clone, Copy)]
pub struct IdentifiedParams {
    pub arrival_rate: f64,
    pub warm_mean: f64,
    pub warm_std: f64,
    pub cold_mean: f64,
    pub cold_std: f64,
    pub cold_start_prob: f64,
    pub rejection_prob: f64,
}

/// Estimate workload parameters from a request trace.
pub fn identify(records: &[RequestRecord]) -> IdentifiedParams {
    assert!(!records.is_empty());
    let horizon = records.last().unwrap().arrived_at - records[0].arrived_at;
    let mut warm = Vec::new();
    let mut cold = Vec::new();
    let mut rejected = 0u64;
    for r in records {
        match r.outcome {
            Outcome::Warm => warm.push(r.response_time),
            Outcome::Cold => cold.push(r.response_time),
            Outcome::Rejected => rejected += 1,
            // Retried requests were served warm/cold on a later attempt;
            // their response time still measures a successful service.
            Outcome::Retried => warm.push(r.response_time),
            // Failed/timed-out executions measure the fault process, not
            // the service distribution — excluded from the estimators.
            Outcome::Failed | Outcome::Timeout => {}
        }
    }
    let stats = |xs: &[f64]| -> (f64, f64) {
        if xs.is_empty() {
            return (f64::NAN, f64::NAN);
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = if xs.len() > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        (mean, var.sqrt())
    };
    let (warm_mean, warm_std) = stats(&warm);
    let (cold_mean, cold_std) = stats(&cold);
    let served = warm.len() + cold.len();
    IdentifiedParams {
        arrival_rate: if horizon > 0.0 {
            records.len() as f64 / horizon
        } else {
            f64::NAN
        },
        warm_mean,
        warm_std,
        cold_mean,
        cold_std,
        cold_start_prob: if served > 0 {
            cold.len() as f64 / served as f64
        } else {
            0.0
        },
        rejection_prob: rejected as f64 / records.len() as f64,
    }
}

/// A probe target: something that answers "was this request, issued after
/// `gap` seconds of silence, a cold start?" — implemented by the emulator
/// and by the simulator-backed mock in tests.
pub trait ColdStartProbe {
    /// Issue a request after the given idle gap; returns true on cold start.
    fn probe(&mut self, gap_seconds: f64) -> bool;
}

/// The paper's §5.2 experiment: increasing inter-arrival probes. Returns
/// `(lower_bound, upper_bound)` for the expiration threshold: the last gap
/// that stayed warm, and the first gap that went cold.
pub fn probe_expiration_threshold(
    probe: &mut dyn ColdStartProbe,
    start_gap: f64,
    step: f64,
    max_gap: f64,
) -> (f64, f64) {
    assert!(start_gap > 0.0 && step > 0.0);
    // Prime: first request is always cold; second immediately after warms.
    let _ = probe.probe(0.0);
    let mut last_warm = 0.0;
    let mut gap = start_gap;
    while gap <= max_gap {
        if probe.probe(gap) {
            return (last_warm, gap);
        }
        last_warm = gap;
        gap += step;
    }
    (last_warm, f64::INFINITY)
}

/// Sliding-window unique-instance count (paper §5.3 "Mean Number of
/// Instances in the Warm Pool"): at each request time, count distinct
/// instance ids observed in the trailing `window` seconds. Returns
/// `(time, count)` samples at each request.
pub fn warm_pool_series(records: &[RequestRecord], window: f64) -> Vec<(f64, usize)> {
    use std::collections::HashMap;
    let mut out = Vec::with_capacity(records.len());
    let mut last_seen: HashMap<&str, f64> = HashMap::new();
    let mut order: std::collections::VecDeque<(f64, &str)> = Default::default();
    for r in records {
        if r.outcome != Outcome::Rejected && !r.instance_id.is_empty() {
            last_seen.insert(r.instance_id.as_str(), r.arrived_at);
            order.push_back((r.arrived_at, r.instance_id.as_str()));
        }
        // Evict entries whose *latest* sighting left the window.
        while let Some(&(t, id)) = order.front() {
            if t >= r.arrived_at - window {
                break;
            }
            order.pop_front();
            if last_seen.get(id) == Some(&t) {
                last_seen.remove(id);
            }
        }
        out.push((r.arrived_at, last_seen.len()));
    }
    out
}

/// Mean of the warm-pool series after a warm-up prefix.
pub fn mean_warm_pool(records: &[RequestRecord], window: f64, skip: f64) -> f64 {
    let series = warm_pool_series(records, window);
    if series.is_empty() {
        return f64::NAN;
    }
    let t0 = series[0].0 + skip;
    let tail: Vec<f64> = series
        .iter()
        .filter(|(t, _)| *t >= t0)
        .map(|(_, c)| *c as f64)
        .collect();
    if tail.is_empty() {
        f64::NAN
    } else {
        tail.iter().sum::<f64>() / tail.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Rng, SimProcess};

    #[test]
    fn identify_recovers_parameters() {
        // Build a synthetic trace with known parameters.
        let mut rng = Rng::new(42);
        let warm_p = crate::sim::ExpProcess::with_mean(2.0);
        let cold_p = crate::sim::ExpProcess::with_mean(3.0);
        let mut records = Vec::new();
        let mut t = 0.0;
        for i in 0..50_000 {
            t += rng.exponential(1.5);
            let cold = i % 100 == 0; // 1% cold
            records.push(RequestRecord {
                arrived_at: t,
                outcome: if cold { Outcome::Cold } else { Outcome::Warm },
                response_time: if cold {
                    cold_p.sample(&mut rng)
                } else {
                    warm_p.sample(&mut rng)
                },
                instance_id: format!("i-{:04}", i % 7),
            });
        }
        let p = identify(&records);
        assert!((p.arrival_rate - 1.5).abs() < 0.05, "rate={}", p.arrival_rate);
        assert!((p.warm_mean - 2.0).abs() < 0.05);
        assert!((p.cold_mean - 3.0).abs() < 0.3);
        assert!((p.cold_start_prob - 0.01).abs() < 0.002);
        assert_eq!(p.rejection_prob, 0.0);
    }

    /// Probe backed by the actual expiration rule.
    struct FakePlatform {
        threshold: f64,
        idle_since: Option<f64>,
        now: f64,
    }

    impl ColdStartProbe for FakePlatform {
        fn probe(&mut self, gap: f64) -> bool {
            self.now += gap;
            let cold = match self.idle_since {
                None => true,
                Some(t0) => self.now - t0 > self.threshold,
            };
            // Request processes instantly; instance idle from now on.
            self.idle_since = Some(self.now);
            cold
        }
    }

    #[test]
    fn probe_brackets_threshold() {
        let mut p = FakePlatform { threshold: 600.0, idle_since: None, now: 0.0 };
        let (lo, hi) = probe_expiration_threshold(&mut p, 10.0, 10.0, 1200.0);
        assert!(lo <= 600.0 && 600.0 <= hi, "({lo},{hi})");
        assert!((hi - lo - 10.0).abs() < 1e-9); // bracketed to one step
    }

    #[test]
    fn probe_gives_infinite_upper_when_never_cold() {
        let mut p = FakePlatform { threshold: 1e9, idle_since: None, now: 0.0 };
        let (lo, hi) = probe_expiration_threshold(&mut p, 10.0, 10.0, 100.0);
        assert_eq!(hi, f64::INFINITY);
        assert!(lo >= 90.0);
    }

    #[test]
    fn warm_pool_counts_unique_instances() {
        let records = vec![
            RequestRecord { arrived_at: 0.0, outcome: Outcome::Cold, response_time: 1.0, instance_id: "a".into() },
            RequestRecord { arrived_at: 1.0, outcome: Outcome::Cold, response_time: 1.0, instance_id: "b".into() },
            RequestRecord { arrived_at: 2.0, outcome: Outcome::Warm, response_time: 1.0, instance_id: "a".into() },
            // 700 s later, only "c" is in the 600 s window.
            RequestRecord { arrived_at: 700.0, outcome: Outcome::Cold, response_time: 1.0, instance_id: "c".into() },
        ];
        let series = warm_pool_series(&records, 600.0);
        assert_eq!(series[0].1, 1);
        assert_eq!(series[1].1, 2);
        assert_eq!(series[2].1, 2); // a seen twice, still 2 unique
        assert_eq!(series[3].1, 1); // a and b evicted
    }
}
