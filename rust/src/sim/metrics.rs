//! Online statistics used by the simulators and the validation harness.
//!
//! * [`OnlineStats`] — Welford's algorithm for per-request quantities
//!   (response times, lifespans).
//! * [`TimeWeighted`] — time-weighted averages for level processes
//!   (instance counts, running counts): the paper's "average server count"
//!   is the time integral of the count divided by the horizon.
//! * [`P2Quantile`] — the P² streaming quantile estimator (Jain & Chlamtac),
//!   used for tail response times without storing the trace.
//! * [`confidence_interval_95`] — Student-t CIs across independent runs
//!   (paper Fig. 4 plots the 95% CI over 10 simulations).
//! * [`mape`], [`avg_pct_error`], [`ks_distance`] — the error metrics the
//!   paper reports when validating simulation against experiment.

use super::time::SimTime;

/// Welford online mean/variance over scalar observations.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn std_err(&self) -> f64 {
        self.std_dev() / (self.n as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel reduction; Chan et al.).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Time-weighted average of a piecewise-constant level process.
///
/// `update(t, level)` must be called with non-decreasing `t`; the level is
/// assumed constant on [last_t, t). The average over [start, last_t] is
/// `integral / elapsed`.
#[derive(Debug, Clone)]
pub struct TimeWeighted {
    start: SimTime,
    last_t: SimTime,
    level: f64,
    integral: f64,
    max_level: f64,
}

impl TimeWeighted {
    pub fn new(start: SimTime, initial_level: f64) -> Self {
        TimeWeighted {
            start,
            last_t: start,
            level: initial_level,
            integral: 0.0,
            max_level: initial_level,
        }
    }

    /// Advance time to `t` with the level unchanged, then set a new level.
    #[inline]
    pub fn update(&mut self, t: SimTime, new_level: f64) {
        debug_assert!(t >= self.last_t, "time must be non-decreasing");
        self.integral += self.level * t.since(self.last_t);
        self.last_t = t;
        self.level = new_level;
        if new_level > self.max_level {
            self.max_level = new_level;
        }
    }

    /// Advance to `t` without changing the level (e.g. at the horizon).
    #[inline]
    pub fn advance(&mut self, t: SimTime) {
        let lvl = self.level;
        self.update(t, lvl);
    }

    pub fn average(&self) -> f64 {
        let elapsed = self.last_t.since(self.start);
        if elapsed <= 0.0 {
            self.level
        } else {
            self.integral / elapsed
        }
    }

    pub fn current(&self) -> f64 {
        self.level
    }

    pub fn max_level(&self) -> f64 {
        self.max_level
    }

    pub fn elapsed(&self) -> f64 {
        self.last_t.since(self.start)
    }

    /// Time of the most recent update.
    pub fn last_time(&self) -> SimTime {
        self.last_t
    }

    /// Integral of the level over [start, last_time].
    pub fn integral(&self) -> f64 {
        self.integral
    }

    /// Restart accumulation at `t` keeping the current level (used to skip
    /// the transient warm-up window, paper Table 1 "Skip Initial Time").
    pub fn reset_at(&mut self, t: SimTime) {
        self.start = t;
        self.last_t = t;
        self.integral = 0.0;
        self.max_level = self.level;
    }
}

/// P² streaming quantile estimator (Jain & Chlamtac 1985).
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights.
    q: [f64; 5],
    /// Marker positions (1-based).
    n: [f64; 5],
    /// Desired positions.
    np: [f64; 5],
    /// Position increments.
    dn: [f64; 5],
    count: usize,
    initial: Vec<f64>,
}

impl P2Quantile {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            initial: Vec::with_capacity(5),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        if self.initial.len() < 5 {
            self.initial.push(x);
            if self.initial.len() == 5 {
                self.initial.sort_by(|a, b| a.partial_cmp(b).unwrap());
                for i in 0..5 {
                    self.q[i] = self.initial[i];
                }
            }
            return;
        }
        // Find cell k.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= self.q[i] && x < self.q[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Adjust interior markers.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let q = &self.q;
        let n = &self.n;
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = (i as f64 + d) as usize;
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    pub fn quantile(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.initial.len() < 5 {
            let mut v = self.initial.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let idx = ((v.len() as f64 - 1.0) * self.p).round() as usize;
            return v[idx];
        }
        self.q[2]
    }
}

/// Two-sided 95% Student-t critical values; index = degrees of freedom.
/// Values beyond the table fall back to the normal quantile 1.96.
const T_95: [f64; 31] = [
    f64::NAN, 12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201,
    2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
    2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
];

/// 95% confidence half-width of the mean of `xs` (independent runs).
pub fn confidence_interval_95(xs: &[f64]) -> (f64, f64) {
    let n = xs.len();
    assert!(n >= 2, "CI needs at least 2 observations");
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
    let se = (var / n as f64).sqrt();
    let df = n - 1;
    let t = if df < T_95.len() { T_95[df] } else { 1.96 };
    (mean, t * se)
}

/// Mean Absolute Percentage Error between predictions and references,
/// in percent — the metric the paper reports for Figs. 7 and 8.
pub fn mape(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (p, t) in pred.iter().zip(truth) {
        if *t != 0.0 {
            acc += ((p - t) / t).abs();
            n += 1;
        }
    }
    100.0 * acc / n.max(1) as f64
}

/// Average percent error |p-t|/t, identical to MAPE; the paper labels the
/// Fig. 6 metric "average error", we keep both names for clarity at call
/// sites.
pub fn avg_pct_error(pred: &[f64], truth: &[f64]) -> f64 {
    mape(pred, truth)
}

/// Two-sample Kolmogorov–Smirnov distance between empirical CDFs.
pub fn ks_distance(a: &[f64], b: &[f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    let mut a = a.to_vec();
    let mut b = b.to_vec();
    a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (mut i, mut j) = (0usize, 0usize);
    let mut d: f64 = 0.0;
    while i < a.len() && j < b.len() {
        let x = a[i].min(b[j]);
        while i < a.len() && a[i] <= x {
            i += 1;
        }
        while j < b.len() && b[j] <= x {
            j += 1;
        }
        let fa = i as f64 / a.len() as f64;
        let fb = j as f64 / b.len() as f64;
        d = d.max((fa - fb).abs());
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::rng::Rng;

    #[test]
    fn online_stats_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let batch_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.variance() - batch_var).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn online_stats_merge_equals_single_pass() {
        let mut rng = Rng::new(11);
        let xs: Vec<f64> = (0..1000).map(|_| rng.normal(3.0, 2.0)).collect();
        let mut whole = OnlineStats::new();
        for &x in &xs {
            whole.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..400] {
            a.push(x);
        }
        for &x in &xs[400..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-8);
    }

    #[test]
    fn time_weighted_average() {
        let mut tw = TimeWeighted::new(SimTime::ZERO, 0.0);
        tw.update(SimTime::from_secs(10.0), 2.0); // level 0 on [0,10)
        tw.update(SimTime::from_secs(20.0), 4.0); // level 2 on [10,20)
        tw.advance(SimTime::from_secs(30.0)); // level 4 on [20,30)
        // integral = 0*10 + 2*10 + 4*10 = 60 over 30s
        assert!((tw.average() - 2.0).abs() < 1e-12);
        assert_eq!(tw.max_level(), 4.0);
        assert_eq!(tw.current(), 4.0);
    }

    #[test]
    fn time_weighted_reset_skips_warmup() {
        let mut tw = TimeWeighted::new(SimTime::ZERO, 100.0);
        tw.update(SimTime::from_secs(10.0), 1.0);
        tw.reset_at(SimTime::from_secs(10.0));
        tw.advance(SimTime::from_secs(20.0));
        assert!((tw.average() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn p2_approximates_quantiles() {
        let mut rng = Rng::new(12);
        let mut p50 = P2Quantile::new(0.5);
        let mut p99 = P2Quantile::new(0.99);
        let mut xs = Vec::new();
        for _ in 0..100_000 {
            let x = rng.exponential(1.0);
            p50.push(x);
            p99.push(x);
            xs.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let true_p50 = xs[xs.len() / 2];
        let true_p99 = xs[(xs.len() as f64 * 0.99) as usize];
        assert!((p50.quantile() - true_p50).abs() / true_p50 < 0.05);
        assert!((p99.quantile() - true_p99).abs() / true_p99 < 0.1);
    }

    #[test]
    fn ci_95_sane() {
        let xs = [10.0, 10.1, 9.9, 10.05, 9.95, 10.0, 10.02, 9.98, 10.03, 9.97];
        let (mean, hw) = confidence_interval_95(&xs);
        assert!((mean - 10.0).abs() < 0.01);
        assert!(hw > 0.0 && hw < 0.1);
    }

    #[test]
    fn mape_and_ks() {
        assert!((mape(&[1.1, 2.2], &[1.0, 2.0]) - 10.0).abs() < 1e-9);
        let a: Vec<f64> = (0..1000).map(|i| i as f64 / 1000.0).collect();
        let b: Vec<f64> = (0..1000).map(|i| i as f64 / 1000.0).collect();
        assert!(ks_distance(&a, &b) < 0.01);
        let c: Vec<f64> = a.iter().map(|x| x + 0.5).collect();
        assert!(ks_distance(&a, &c) > 0.4);
    }
}
