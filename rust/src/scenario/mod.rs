//! The declarative scenario layer — one typed description, one entry
//! point, every experiment.
//!
//! SimFaaS's pitch is "describe a platform configuration, get performance
//! and cost predictions". This module is that description made first-class:
//!
//! * [`spec`] — [`ScenarioSpec`], the typed experiment value (workload ×
//!   platform × experiment × cost × output) with a fluent builder. Plain
//!   data; building one runs nothing.
//! * [`json`] — the serialized form: [`ScenarioSpec::to_json`] /
//!   [`ScenarioSpec::from_json_str`] over the crate's own
//!   [`crate::output::json::JsonValue`] reader/writer. Bundled examples
//!   live in `examples/scenarios/`; the schema is documented in DESIGN.md.
//! * [`run`] — [`run_scenario`]: the single dispatcher that routes a spec
//!   to `ServerlessSimulator`, `ServerlessTemporalSimulator`, the
//!   replication ensemble, the fleet engine, what-if sweeps, the
//!   analytical baseline and the cost engine, returning a
//!   [`ScenarioReport`] that renders as the CLI's tables or as JSON.
//!
//! The CLI subcommands (`steady`, `temporal`, `ensemble`, `fleet`,
//! `sweep`, `compare`, `cost`, plus `simfaas run <scenario.json>`) are
//! thin flag→spec translators over this module, pinned bit-identical to
//! the pre-scenario code paths by regression tests. New experiment kinds
//! (trace files, autoscalers, learned policies — see ROADMAP.md) extend
//! [`ExperimentSpec`] here instead of growing another hand-wired
//! subcommand.
//!
//! ```no_run
//! use simfaas::scenario::{run_scenario, ExperimentSpec, ScenarioSpec};
//!
//! let spec = ScenarioSpec::new("quick-ci")
//!     .with_arrival_rate(1.5)
//!     .with_horizon(100_000.0)
//!     .with_experiment(ExperimentSpec::ensemble(8));
//! let report = run_scenario(&spec)?;
//! println!("{}", report.render(&spec));
//! # anyhow::Ok(())
//! ```

pub mod json;
pub mod run;
pub mod spec;

pub use run::{run_scenario, run_scenario_to_string, CostBlock, ScenarioReport, TelemetrySummary};
pub use spec::{
    CostSpec, ExperimentSpec, FleetScenario, KeepAliveSpec, ObservabilitySpec, OutputFormat,
    OutputSpec, PlatformSpec, ProcessSpec, ReliabilitySpec, RunSpec, ScenarioSpec, SourceSpec,
    WorkloadSpec, DEFAULT_SEED,
};
