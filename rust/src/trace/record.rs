//! Trace records and CSV I/O.
//!
//! The paper's validation pipeline stores each request's outcome in a CSV
//! ("The result is stored in a CSV file and then processed using Pandas"),
//! keyed by a unique per-instance identifier recovered via the technique of
//! Wang et al. 2018. The emulator writes the same schema, and the parameter
//! identification (`trace::ident`) and validation benches consume it — so
//! the exact code path a user would run against real AWS Lambda logs runs
//! here against emulator logs.
//!
//! Schema (`request` CSV): `arrived_at,outcome,response_time,instance_id`
//! with outcome ∈ {cold, warm, rejected, failed, timeout, retried} (the
//! last three are the reliability-layer outcomes; pre-reliability traces
//! simply never contain them).

use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};

/// One request observation (client side).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    /// Client-observed arrival (submission) time, seconds.
    pub arrived_at: f64,
    /// cold / warm / rejected.
    pub outcome: Outcome,
    /// Client-observed response time, seconds (0 for rejected).
    pub response_time: f64,
    /// Unique serving-instance identifier ("" if rejected).
    pub instance_id: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Cold,
    Warm,
    Rejected,
    /// Served but the execution failed transiently (reliability layer).
    Failed,
    /// Served but cut off at the platform's execution timeout.
    Timeout,
    /// Served successfully on a retry attempt (attempt > 1).
    Retried,
}

impl Outcome {
    pub fn as_str(&self) -> &'static str {
        match self {
            Outcome::Cold => "cold",
            Outcome::Warm => "warm",
            Outcome::Rejected => "rejected",
            Outcome::Failed => "failed",
            Outcome::Timeout => "timeout",
            Outcome::Retried => "retried",
        }
    }

    pub fn parse(s: &str) -> Result<Outcome> {
        match s {
            "cold" => Ok(Outcome::Cold),
            "warm" => Ok(Outcome::Warm),
            "rejected" => Ok(Outcome::Rejected),
            "failed" => Ok(Outcome::Failed),
            "timeout" => Ok(Outcome::Timeout),
            "retried" => Ok(Outcome::Retried),
            other => bail!("unknown outcome {other:?}"),
        }
    }
}

pub const REQUEST_CSV_HEADER: &str = "arrived_at,outcome,response_time,instance_id";

/// Write records as CSV (with header).
pub fn write_csv<W: Write>(mut w: W, records: &[RequestRecord]) -> Result<()> {
    writeln!(w, "{REQUEST_CSV_HEADER}")?;
    for r in records {
        writeln!(
            w,
            "{:.6},{},{:.6},{}",
            r.arrived_at,
            r.outcome.as_str(),
            r.response_time,
            r.instance_id
        )?;
    }
    Ok(())
}

/// Parse records from CSV (header required).
pub fn read_csv<R: BufRead>(r: R) -> Result<Vec<RequestRecord>> {
    let mut lines = r.lines();
    let header = lines
        .next()
        .context("empty trace file")?
        .context("read error")?;
    if header.trim() != REQUEST_CSV_HEADER {
        bail!("unexpected header {header:?}");
    }
    let mut out = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut parts = line.splitn(4, ',');
        let arrived_at: f64 = parts
            .next()
            .with_context(|| format!("line {}: missing arrived_at", lineno + 2))?
            .parse()
            .with_context(|| format!("line {}: bad arrived_at", lineno + 2))?;
        let outcome = Outcome::parse(parts.next().context("missing outcome")?)?;
        let response_time: f64 = parts
            .next()
            .context("missing response_time")?
            .parse()
            .context("bad response_time")?;
        let instance_id = parts.next().unwrap_or("").to_string();
        out.push(RequestRecord { arrived_at, outcome, response_time, instance_id });
    }
    Ok(out)
}

/// Convert the simulator's request log into trace records (bridges
/// `sim::RequestLogEntry` to the shared schema).
pub fn from_sim_log(log: &[crate::sim::RequestLogEntry]) -> Vec<RequestRecord> {
    log.iter()
        .map(|e| RequestRecord {
            arrived_at: e.arrived_at,
            outcome: match e.outcome {
                crate::sim::RequestOutcome::Cold => Outcome::Cold,
                crate::sim::RequestOutcome::Warm => Outcome::Warm,
                crate::sim::RequestOutcome::Rejected => Outcome::Rejected,
            },
            response_time: e.response_time,
            instance_id: e.instance.map(|i| i.to_string()).unwrap_or_default(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<RequestRecord> {
        vec![
            RequestRecord {
                arrived_at: 0.5,
                outcome: Outcome::Cold,
                response_time: 2.25,
                instance_id: "i-00000000".into(),
            },
            RequestRecord {
                arrived_at: 1.75,
                outcome: Outcome::Warm,
                response_time: 1.99,
                instance_id: "i-00000000".into(),
            },
            RequestRecord {
                arrived_at: 2.0,
                outcome: Outcome::Rejected,
                response_time: 0.0,
                instance_id: "".into(),
            },
        ]
    }

    #[test]
    fn csv_roundtrip() {
        let records = sample();
        let mut buf = Vec::new();
        write_csv(&mut buf, &records).unwrap();
        let parsed = read_csv(&buf[..]).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].outcome, Outcome::Cold);
        assert_eq!(parsed[2].outcome, Outcome::Rejected);
        assert!((parsed[1].response_time - 1.99).abs() < 1e-9);
        assert_eq!(parsed[1].instance_id, "i-00000000");
    }

    #[test]
    fn reliability_outcomes_roundtrip() {
        let records: Vec<RequestRecord> = [Outcome::Failed, Outcome::Timeout, Outcome::Retried]
            .iter()
            .enumerate()
            .map(|(i, &outcome)| RequestRecord {
                arrived_at: i as f64,
                outcome,
                response_time: 0.5,
                instance_id: "i-00000001".into(),
            })
            .collect();
        let mut buf = Vec::new();
        write_csv(&mut buf, &records).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.contains(",failed,"));
        assert!(text.contains(",timeout,"));
        assert!(text.contains(",retried,"));
        let parsed = read_csv(&buf[..]).unwrap();
        assert_eq!(parsed, records);
    }

    #[test]
    fn rejects_bad_header() {
        let data = b"nope\n1,cold,2,x\n";
        assert!(read_csv(&data[..]).is_err());
    }

    #[test]
    fn rejects_bad_outcome() {
        let data = format!("{REQUEST_CSV_HEADER}\n1.0,tepid,2.0,x\n");
        assert!(read_csv(data.as_bytes()).is_err());
    }

    #[test]
    fn sim_log_bridge() {
        use crate::sim::{ServerlessSimulator, SimConfig};
        let mut cfg = SimConfig::table1();
        cfg.horizon = 2_000.0;
        cfg.capture_request_log = true;
        let mut sim = ServerlessSimulator::new(cfg);
        let res = sim.run();
        let records = from_sim_log(sim.request_log());
        assert_eq!(records.len() as u64, res.total_requests);
        let cold = records.iter().filter(|r| r.outcome == Outcome::Cold).count() as u64;
        assert_eq!(cold, res.cold_requests);
    }
}
