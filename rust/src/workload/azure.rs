//! Synthetic Azure-Functions-style workload traces.
//!
//! The paper cites Shahrad et al. 2020 ("Serverless in the Wild") for
//! platform behaviour; that work characterizes production Azure Functions
//! invocation patterns: a heavy-tailed popularity distribution across
//! functions, strong diurnal cycles, and a large mass of rarely-invoked
//! functions. This module generates synthetic traces with those published
//! characteristics; real traces ingest through
//! [`super::azure_dataset::AzureDataset`] and both feed the same
//! [`super::source::TraceSource`] seam (the dual path documented in
//! DESIGN.md §3, with [`super::source::TraceSource::rate_stats`] as the
//! cross-validation yardstick).

use super::generator::{nonhomogeneous, Workload};
use super::stream::RateShape;
use crate::sim::rng::Rng;
use anyhow::{bail, Result};

/// One synthetic function's workload profile.
#[derive(Debug, Clone)]
pub struct FunctionProfile {
    pub name: String,
    /// Mean invocation rate (req/s) averaged over a day.
    pub mean_rate: f64,
    /// Diurnal modulation depth in [0,1): rate(t) = mean*(1 + depth*sin).
    pub diurnal_depth: f64,
    /// Phase offset of the daily peak, seconds.
    pub peak_offset: f64,
    /// Mean warm service time (s).
    pub warm_service_mean: f64,
    /// Mean cold service time (s).
    pub cold_service_mean: f64,
}

/// Tuning constants of the synthetic generator — previously hard-coded in
/// [`SyntheticTrace::generate`]. The defaults reproduce the historical
/// generator draw-for-draw (regression-pinned below); deviate to explore
/// other mixes, e.g. after comparing against an ingested dataset's
/// [`super::source::TraceStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct SynthesisOptions {
    /// Pareto scale `x_m` of the popularity distribution — the minimum
    /// per-function mean rate (req/s).
    pub rate_floor: f64,
    /// Pareto tail index `alpha` (~1.1 per Shahrad et al.'s heavy tail).
    pub pareto_alpha: f64,
    /// Upper clamp on a function's mean rate (req/s), keeping single
    /// functions from dominating a whole fleet run.
    pub rate_cap: f64,
    /// Probability that a function is IO-bound (long, high-variance
    /// service) rather than CPU-bound.
    pub io_fraction: f64,
}

impl Default for SynthesisOptions {
    fn default() -> Self {
        SynthesisOptions {
            rate_floor: 0.002,
            pareto_alpha: 1.1,
            rate_cap: 5.0,
            io_fraction: 0.5,
        }
    }
}

/// A bundle of functions approximating an Azure-style tenant mix.
#[derive(Debug, Clone)]
pub struct SyntheticTrace {
    pub functions: Vec<FunctionProfile>,
}

impl SyntheticTrace {
    /// Generate `n` functions with the default [`SynthesisOptions`]: mean
    /// rates follow a Pareto popularity distribution (alpha ~ 1.1, per
    /// Shahrad et al.'s heavy tail), with random diurnal depth and phase,
    /// and a CPU/IO service-time mix (paper §5: "a combination of CPU
    /// intensive and I/O intensive workloads").
    pub fn generate(n: usize, rng: &mut Rng) -> Self {
        Self::generate_with(n, &SynthesisOptions::default(), rng)
    }

    /// Generate `n` functions under explicit tuning. With the default
    /// options this draws the identical RNG sequence as the historical
    /// `generate`, so existing seeds reproduce bit-for-bit.
    pub fn generate_with(n: usize, opts: &SynthesisOptions, rng: &mut Rng) -> Self {
        let mut functions = Vec::with_capacity(n);
        for k in 0..n {
            // Popularity: heavy-tailed rates clamped to a sane band.
            let raw = rng.pareto(opts.rate_floor, opts.pareto_alpha);
            let mean_rate = raw.min(opts.rate_cap);
            let io_bound = rng.uniform() < opts.io_fraction;
            let (warm, cold) = if io_bound {
                // IO-intensive: longer, higher-variance service.
                (rng.uniform_range(0.5, 3.0), rng.uniform_range(1.5, 5.0))
            } else {
                // CPU-intensive: shorter service, dominated by compute.
                (rng.uniform_range(0.05, 0.8), rng.uniform_range(0.3, 2.0))
            };
            functions.push(FunctionProfile {
                name: format!("fn-{k:04}"),
                mean_rate,
                diurnal_depth: rng.uniform_range(0.2, 0.9),
                peak_offset: rng.uniform_range(0.0, 86_400.0),
                warm_service_mean: warm,
                cold_service_mean: cold.max(warm * 1.05),
            });
        }
        SyntheticTrace { functions }
    }

    /// Materialize one function's arrivals over `horizon` seconds. An
    /// out-of-range index or a non-positive peak rate is an error (the
    /// historical version panicked). Prefer the streaming path
    /// ([`super::source::TraceSource::function_specs`]) for simulation —
    /// it yields the identical arrivals without materializing them.
    pub fn arrivals_for(&self, idx: usize, horizon: f64, rng: &mut Rng) -> Result<Workload> {
        let Some(f) = self.functions.get(idx) else {
            bail!(
                "function index {idx} is out of range: the trace has {} functions",
                self.functions.len()
            );
        };
        // One shared definition of the diurnal rate: the same RateShape the
        // streaming path evaluates, so eager and lazy generation cannot
        // drift apart.
        let shape = RateShape::Sinusoid {
            mean: f.mean_rate,
            depth: f.diurnal_depth,
            peak_offset: f.peak_offset,
        };
        let rate_max = shape.max_rate();
        if rate_max <= 0.0 {
            bail!("function {idx} ({}) has a non-positive peak rate {rate_max}", f.name);
        }
        Ok(nonhomogeneous(|t| shape.eval(t), rate_max, horizon, rng))
    }

    /// Aggregate mean rate across all functions.
    pub fn total_mean_rate(&self) -> f64 {
        self.functions.iter().map(|f| f.mean_rate).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_heavy_tailed_mix() {
        let mut rng = Rng::new(9);
        let trace = SyntheticTrace::generate(500, &mut rng);
        assert_eq!(trace.functions.len(), 500);
        let mut rates: Vec<f64> = trace.functions.iter().map(|f| f.mean_rate).collect();
        rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Heavy tail: the top function dominates the median by >10x.
        let median = rates[250];
        let top = rates[499];
        assert!(top / median > 10.0, "top={top} median={median}");
        // Cold > warm for every function.
        assert!(trace.functions.iter().all(|f| f.cold_service_mean > f.warm_service_mean));
    }

    #[test]
    fn default_options_reproduce_the_historical_generator() {
        // SynthesisOptions::default() must not drift: the documented
        // defaults are the constants the generator always used.
        let opts = SynthesisOptions::default();
        assert_eq!(opts.rate_floor, 0.002);
        assert_eq!(opts.pareto_alpha, 1.1);
        assert_eq!(opts.rate_cap, 5.0);
        assert_eq!(opts.io_fraction, 0.5);
        let a = SyntheticTrace::generate(20, &mut Rng::new(3));
        let b = SyntheticTrace::generate_with(20, &opts, &mut Rng::new(3));
        for (x, y) in a.functions.iter().zip(&b.functions) {
            assert_eq!(x.mean_rate.to_bits(), y.mean_rate.to_bits());
            assert_eq!(x.peak_offset.to_bits(), y.peak_offset.to_bits());
            assert_eq!(x.warm_service_mean.to_bits(), y.warm_service_mean.to_bits());
        }
    }

    #[test]
    fn synthesis_options_shape_the_mix() {
        let opts = SynthesisOptions { rate_cap: 0.5, io_fraction: 1.0, ..Default::default() };
        let trace = SyntheticTrace::generate_with(100, &opts, &mut Rng::new(4));
        assert!(trace.functions.iter().all(|f| f.mean_rate <= 0.5));
        // io_fraction = 1: every function draws the IO-bound service band.
        assert!(trace.functions.iter().all(|f| f.warm_service_mean >= 0.5));
    }

    #[test]
    fn arrivals_follow_mean_rate() {
        let mut rng = Rng::new(10);
        let mut trace = SyntheticTrace::generate(3, &mut rng);
        trace.functions[0].mean_rate = 1.0;
        trace.functions[0].diurnal_depth = 0.5;
        let w = trace.arrivals_for(0, 2.0 * 86_400.0, &mut rng).unwrap();
        // Over whole days the diurnal modulation integrates out.
        let rate = w.rate_over(2.0 * 86_400.0);
        assert!((rate - 1.0).abs() < 0.05, "rate={rate}");
    }

    #[test]
    fn arrivals_for_rejects_bad_indices_instead_of_panicking() {
        let mut rng = Rng::new(12);
        let trace = SyntheticTrace::generate(3, &mut rng);
        let err = trace.arrivals_for(7, 100.0, &mut rng).unwrap_err().to_string();
        assert!(err.contains("out of range") && err.contains('7'), "{err}");
        // A zero-rate profile errors instead of tripping an assert.
        let mut flat = trace.clone();
        flat.functions[0].mean_rate = 0.0;
        let err = flat.arrivals_for(0, 100.0, &mut rng).unwrap_err().to_string();
        assert!(err.contains("peak rate"), "{err}");
    }

    #[test]
    fn deterministic_generation_per_seed() {
        let t1 = SyntheticTrace::generate(10, &mut Rng::new(5));
        let t2 = SyntheticTrace::generate(10, &mut Rng::new(5));
        for (a, b) in t1.functions.iter().zip(&t2.functions) {
            assert_eq!(a.mean_rate, b.mean_rate);
            assert_eq!(a.peak_offset, b.peak_offset);
        }
    }
}
