//! Parallel parameter sweeps over the simulator.
//!
//! `sweep` fans a list of parameter points across OS threads and returns
//! results in input order — the machinery behind Fig. 5 (cold-start
//! probability vs arrival rate × expiration threshold) and the validation
//! figures' arrival-rate sweeps. The scheduling primitive is shared with
//! the replication engine ([`crate::sim::ensemble::run_indexed`]), so
//! sweeps inherit its determinism contract: point `i` always computes
//! `f(&points[i])` and lands in slot `i`, regardless of thread count.

use crate::sim::ensemble::run_indexed;

/// Outcome of one grid point (generic in the result type).
pub type SweepOutcome<'a, P, R> = (&'a P, R);

/// Run `f` over `points` in parallel (one worker per available core);
/// results return in input order.
pub fn sweep<'a, P, R, F>(points: &'a [P], f: F) -> Vec<SweepOutcome<'a, P, R>>
where
    P: Sync,
    R: Send,
    F: Fn(&P) -> R + Sync,
{
    let results = run_indexed(points.len(), 0, |i| f(&points[i]));
    points.iter().zip(results).collect()
}

/// A 2-D grid point (e.g. arrival rate × expiration threshold, Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridPoint {
    pub x: f64,
    pub y: f64,
}

/// Cartesian-product sweep over two axes.
pub fn sweep_grid<R, F>(xs: &[f64], ys: &[f64], f: F) -> Vec<(GridPoint, R)>
where
    R: Send,
    F: Fn(f64, f64) -> R + Sync,
{
    let points: Vec<GridPoint> = ys
        .iter()
        .flat_map(|&y| xs.iter().map(move |&x| GridPoint { x, y }))
        .collect();
    sweep(&points, |p| f(p.x, p.y))
        .into_iter()
        .map(|(p, r)| (*p, r))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_preserves_order_and_values() {
        let points: Vec<u64> = (0..64).collect();
        let out = sweep(&points, |&p| p * p);
        for (i, (p, r)) in out.iter().enumerate() {
            assert_eq!(**p, i as u64);
            assert_eq!(*r, (i * i) as u64);
        }
    }

    #[test]
    fn sweep_runs_simulations_in_parallel() {
        use crate::sim::{ServerlessSimulator, SimConfig};
        let rates = [0.3, 0.9, 1.5];
        let out = sweep(&rates, |&rate| {
            let cfg = SimConfig::table1().with_arrival_rate(rate).with_horizon(20_000.0);
            ServerlessSimulator::new(cfg).run()
        });
        // Higher arrival rate -> more running servers.
        assert!(out[0].1.avg_running_count < out[1].1.avg_running_count);
        assert!(out[1].1.avg_running_count < out[2].1.avg_running_count);
    }

    #[test]
    fn grid_covers_product() {
        let out = sweep_grid(&[1.0, 2.0], &[10.0, 20.0, 30.0], |x, y| x + y);
        assert_eq!(out.len(), 6);
        assert!(out.iter().any(|(p, r)| p.x == 2.0 && p.y == 30.0 && *r == 32.0));
    }

    #[test]
    fn empty_sweep_is_empty() {
        let out: Vec<(&f64, f64)> = sweep(&[], |&x: &f64| x);
        assert!(out.is_empty());
    }
}
