//! Function instance lifecycle (`FunctionInstance` in the paper's package
//! diagram).
//!
//! Each instance moves through the three states the paper identifies
//! (§2 "Function Instance States"):
//!
//! ```text
//!   Initializing ──────► Running ◄──────► Idle ──────► (terminated)
//!   (cold start:          (billed)        (not billed;  after
//!    platform + app                        expires      expiration
//!    init; app part                        after the    threshold of
//!    billed)                               expiration   inactivity
//!                                          threshold)
//! ```
//!
//! In scale-per-request platforms a cold request's *response* time spans the
//! initializing and running states; the paper's "cold service time" input
//! covers provisioning + service, so the simulator models a cold request as
//! a single busy period of that duration (matching the reference SimFaaS
//! implementation). Instances record their lifespan and billed time so the
//! simulator can report developer cost and provider infrastructure cost.

use super::time::SimTime;

/// Dense instance identifier. Ids are allocated monotonically by the
/// simulator, so a larger id always means a *newer* instance — the paper's
/// newest-first routing priority reduces to "max id in the idle pool".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(pub u64);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "i-{:08}", self.0)
    }
}

/// Instance lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Spinning up (cold start in flight; the triggering request is being
    /// provisioned-for and then served).
    Initializing,
    /// Serving a request (billed).
    Running,
    /// Warm and unoccupied; expires after the expiration threshold.
    Idle,
    /// Expired and reclaimed.
    Terminated,
}

/// A single function instance plus its accounting.
#[derive(Debug, Clone)]
pub struct FunctionInstance {
    pub id: InstanceId,
    pub state: InstanceState,
    /// Creation (cold-start trigger) time.
    pub created_at: SimTime,
    /// When the instance last became idle (valid while `state == Idle`).
    pub idle_since: SimTime,
    /// When the current busy period started (valid while busy).
    pub busy_since: SimTime,
    /// When the instance was terminated (valid once `Terminated`).
    pub terminated_at: SimTime,
    /// Generation counter guarding expiration events (bumped on every
    /// reuse; stale expiration events carry an older generation).
    pub generation: u64,
    /// Cumulative billed busy time (running, plus the billed app-init part
    /// of cold starts — the whole cold service time here, matching the
    /// paper's billing note that app init is billed).
    pub busy_time: f64,
    /// Requests served (including the cold-start request).
    pub requests_served: u64,
    /// True if this instance has only ever served its cold-start request.
    pub cold_only: bool,
    /// Requests currently in flight on this instance. Scale-per-request
    /// platforms hold this at 0/1; the concurrency-value engine
    /// ([`crate::sim::ParServerlessSimulator`]) packs up to its
    /// concurrency value.
    pub in_flight: u32,
    /// True if this instance was started by the prewarm (provisioning-lead)
    /// path rather than by a cold-started request.
    pub prewarmed: bool,
}

impl FunctionInstance {
    /// Create an instance that immediately starts serving its cold request.
    pub fn cold_start(id: InstanceId, now: SimTime) -> Self {
        FunctionInstance {
            id,
            state: InstanceState::Initializing,
            created_at: now,
            idle_since: now,
            busy_since: now,
            terminated_at: now,
            generation: 0,
            busy_time: 0.0,
            requests_served: 0,
            cold_only: true,
            in_flight: 0,
            prewarmed: false,
        }
    }

    /// The cold request finishes provisioning+service and the instance
    /// becomes idle. Returns the new generation for the expiration event.
    pub fn finish_request(&mut self, now: SimTime, busy: f64) -> u64 {
        debug_assert!(matches!(self.state, InstanceState::Initializing | InstanceState::Running));
        self.state = InstanceState::Idle;
        self.idle_since = now;
        self.busy_time += busy;
        self.requests_served += 1;
        self.generation += 1;
        self.generation
    }

    /// A warm request is routed to this (idle) instance.
    pub fn start_warm(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, InstanceState::Idle);
        debug_assert!(now >= self.idle_since);
        self.state = InstanceState::Running;
        self.cold_only = false;
        self.busy_since = now;
        // Bump generation so the pending expiration event is invalidated.
        self.generation += 1;
    }

    /// Expire the instance (only valid while idle).
    pub fn terminate(&mut self, now: SimTime) {
        debug_assert_eq!(self.state, InstanceState::Idle);
        self.state = InstanceState::Terminated;
        self.terminated_at = now;
    }

    /// Lifespan from creation to termination (paper Table 1 "Average
    /// Instance Lifespan"). Valid once terminated; for live instances,
    /// pass the current time.
    pub fn lifespan(&self, now: SimTime) -> f64 {
        match self.state {
            InstanceState::Terminated => self.terminated_at.since(self.created_at),
            _ => now.since(self.created_at),
        }
    }

    /// Fraction of its life this instance spent billed (busy).
    pub fn utilization(&self, now: SimTime) -> f64 {
        let life = self.lifespan(now);
        if life <= 0.0 {
            0.0
        } else {
            (self.busy_time / life).clamp(0.0, 1.0)
        }
    }

    pub fn is_idle(&self) -> bool {
        self.state == InstanceState::Idle
    }

    pub fn is_busy(&self) -> bool {
        matches!(self.state, InstanceState::Initializing | InstanceState::Running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> SimTime {
        SimTime::from_secs(s)
    }

    #[test]
    fn cold_start_lifecycle() {
        let mut inst = FunctionInstance::cold_start(InstanceId(0), t(0.0));
        assert_eq!(inst.state, InstanceState::Initializing);
        assert!(inst.is_busy());

        let g = inst.finish_request(t(2.244), 2.244);
        assert_eq!(g, 1);
        assert!(inst.is_idle());
        assert_eq!(inst.requests_served, 1);
        assert!(inst.cold_only);

        inst.start_warm(t(10.0));
        assert_eq!(inst.state, InstanceState::Running);
        assert!(!inst.cold_only);
        assert_eq!(inst.generation, 2); // expiration from gen 1 now stale

        let g = inst.finish_request(t(12.0), 2.0);
        assert_eq!(g, 3);
        assert!((inst.busy_time - 4.244).abs() < 1e-12);

        inst.terminate(t(612.0));
        assert_eq!(inst.state, InstanceState::Terminated);
        assert!((inst.lifespan(t(9999.0)) - 612.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_bounds() {
        let mut inst = FunctionInstance::cold_start(InstanceId(1), t(0.0));
        inst.finish_request(t(1.0), 1.0);
        inst.terminate(t(601.0));
        let u = inst.utilization(t(601.0));
        assert!(u > 0.0 && u < 1.0);
        assert!((u - 1.0 / 601.0).abs() < 1e-9);
    }

    #[test]
    fn live_lifespan_uses_now() {
        let inst = FunctionInstance::cold_start(InstanceId(2), t(5.0));
        assert!((inst.lifespan(t(15.0)) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn id_ordering_is_creation_order() {
        assert!(InstanceId(10) > InstanceId(9));
        assert_eq!(format!("{}", InstanceId(3)), "i-00000003");
    }
}
