//! Simulation time.
//!
//! Simulation time is a non-negative `f64` measured in **seconds** since the
//! start of the simulation. We wrap it in a newtype to get a total order
//! (`f64` is only `PartialOrd`) and to keep time arithmetic explicit at call
//! sites. NaN times are a logic error and panic on construction in debug
//! builds.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulation time, in seconds since simulation start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd)]
pub struct SimTime(f64);

impl SimTime {
    /// Simulation epoch (t = 0).
    pub const ZERO: SimTime = SimTime(0.0);
    /// A time later than any event a simulation will produce.
    pub const INFINITY: SimTime = SimTime(f64::INFINITY);

    /// Construct from seconds. Panics on NaN (a NaN event time would corrupt
    /// the event-queue ordering silently otherwise).
    #[inline]
    pub fn from_secs(secs: f64) -> Self {
        debug_assert!(!secs.is_nan(), "SimTime cannot be NaN");
        SimTime(secs)
    }

    /// Seconds since simulation start.
    #[inline]
    pub fn as_secs(self) -> f64 {
        self.0
    }

    /// `self + dt` where `dt` is in seconds.
    #[inline]
    pub fn after(self, dt: f64) -> Self {
        SimTime::from_secs(self.0 + dt)
    }

    /// Duration from `earlier` to `self`, in seconds (may be negative).
    #[inline]
    pub fn since(self, earlier: SimTime) -> f64 {
        self.0 - earlier.0
    }

    #[inline]
    pub fn is_finite(self) -> bool {
        self.0.is_finite()
    }

    #[inline]
    pub fn min(self, other: SimTime) -> SimTime {
        if self <= other {
            self
        } else {
            other
        }
    }

    #[inline]
    pub fn max(self, other: SimTime) -> SimTime {
        if self >= other {
            self
        } else {
            other
        }
    }
}

impl Eq for SimTime {}

impl Ord for SimTime {
    #[inline]
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Safe: construction forbids NaN.
        self.0.partial_cmp(&other.0).expect("SimTime is never NaN")
    }
}

impl Add<f64> for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: f64) -> SimTime {
        self.after(rhs)
    }
}

impl AddAssign<f64> for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: f64) {
        *self = self.after(rhs);
    }
}

impl Sub<SimTime> for SimTime {
    type Output = f64;
    #[inline]
    fn sub(self, rhs: SimTime) -> f64 {
        self.since(rhs)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.0)
    }
}

impl Default for SimTime {
    fn default() -> Self {
        SimTime::ZERO
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_is_total() {
        let a = SimTime::from_secs(1.0);
        let b = SimTime::from_secs(2.0);
        assert!(a < b);
        assert_eq!(a.max(b), b);
        assert_eq!(a.min(b), a);
        assert!(SimTime::INFINITY > b);
    }

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs(10.0);
        assert_eq!((t + 5.0).as_secs(), 15.0);
        assert_eq!(t.after(2.5).since(t), 2.5);
        assert_eq!(t - SimTime::from_secs(4.0), 6.0);
        let mut u = t;
        u += 1.0;
        assert_eq!(u.as_secs(), 11.0);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn nan_panics() {
        let _ = SimTime::from_secs(f64::NAN);
    }
}
