//! End-to-end tests for the `TraceSource` workload seam: real Azure-trace
//! ingestion (the checked-in sample fixture), the streaming-vs-eager
//! bit-identity contract behind `FleetConfig::from_source`, and the
//! scenario/CLI surface (`fleet_azure_trace.json`).

use simfaas::fleet::{ArrivalMode, FleetConfig, FleetResults, FunctionSpec, PolicySpec};
use simfaas::scenario::{run_scenario, ScenarioReport, ScenarioSpec, SourceSpec};
use simfaas::sim::ensemble::derive_seeds;
use simfaas::sim::{Rng, SimResults};
use simfaas::workload::{AzureDataset, SyntheticTrace, TraceSource};
use std::path::PathBuf;
use std::sync::Arc;

fn sample_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/traces/azure_sample")
}

fn digest(r: &SimResults) -> Vec<u64> {
    vec![
        r.total_requests,
        r.cold_requests,
        r.warm_requests,
        r.rejected_requests,
        r.instances_created,
        r.instances_expired,
        r.cold_start_prob.to_bits(),
        r.avg_server_count.to_bits(),
        r.avg_running_count.to_bits(),
        r.avg_idle_count.to_bits(),
        r.avg_response_time.to_bits(),
        r.response_p95.to_bits(),
        r.billed_instance_seconds.to_bits(),
    ]
}

fn fleet_digest(res: &FleetResults) -> Vec<u64> {
    let mut d: Vec<u64> = res.per_function.iter().flat_map(digest).collect();
    d.push(res.aggregate.total_requests);
    d.push(res.aggregate.cold_start_prob.to_bits());
    d.push(res.aggregate.billed_instance_seconds.to_bits());
    d
}

/// The headline tentpole regression: a synthetic fleet through the new
/// streaming `TraceSource` seam is bit-identical to a fleet whose arrival
/// vectors are materialized eagerly with the same derived seeds — the
/// pre-redesign construction.
#[test]
fn streaming_fleet_is_bit_identical_to_eager_materialization() {
    let mut rng = Rng::new(5);
    let trace = SyntheticTrace::generate(8, &mut rng);
    let (horizon, root_seed) = (4_000.0, 99u64);

    let streamed = FleetConfig::from_source(
        &TraceSource::Synthetic(trace.clone()),
        horizon,
        0.0,
        root_seed,
        PolicySpec::fixed(300.0),
    )
    .run();

    // Hand-build the eager fleet exactly as the historical from_trace did:
    // per-function arrival RNG seeded from the same SplitMix64 stream,
    // arrivals materialized over the horizon, replayed from a Vec.
    let seeds = derive_seeds(root_seed, 2 * trace.functions.len());
    let functions: Vec<FunctionSpec> = trace
        .functions
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let mut arr_rng = Rng::new(seeds[2 * i]);
            let w = trace.arrivals_for(i, horizon, &mut arr_rng).unwrap();
            FunctionSpec {
                name: f.name.clone(),
                arrival: ArrivalMode::Trace(Arc::new(w.arrivals)),
                batch_size: None,
                warm_service: simfaas::Process::exp_mean(f.warm_service_mean),
                cold_service: simfaas::Process::exp_mean(f.cold_service_mean),
                max_concurrency: 1000,
                memory_mb: 128.0,
                seed: seeds[2 * i + 1],
            }
        })
        .collect();
    let eager = FleetConfig {
        functions,
        policy: PolicySpec::fixed(300.0),
        fleet_max_concurrency: None,
        cluster: None,
        capacity_domains: 1,
        horizon,
        skip_initial: 0.0,
        threads: 0,
        prewarm_lead: 0.0,
        fault: simfaas::sim::FaultProfile::disabled(),
        retry: simfaas::sim::RetryPolicy::none(),
        telemetry: None,
        controller: None,
    }
    .run();

    assert_eq!(fleet_digest(&streamed), fleet_digest(&eager));
    assert!(streamed.aggregate.total_requests > 0);
}

#[test]
fn sample_fixture_ingests_with_sane_profiles() {
    let ds = AzureDataset::load(&sample_dir()).expect("checked-in sample trace parses");
    assert_eq!(ds.functions.len(), 20);
    assert_eq!(ds.raw_functions, 20);
    assert!(ds.transforms.is_empty());
    for f in &ds.functions {
        assert_eq!(f.minute_rates.len(), 1440, "{}", f.name);
        assert!(f.warm_service_mean > 0.0, "{}", f.name);
        assert!(f.cold_service_mean > f.warm_service_mean, "{}", f.name);
        assert!(f.memory_mb >= 128.0, "{}", f.name);
    }
    // The mix totals ~2 req/s (the fixture generator's construction).
    let total = ds.total_mean_rate();
    assert!((1.5..3.0).contains(&total), "total rate {total}");
    // Popularity stats exist and compare against a synthetic mix.
    let src = TraceSource::AzureDataset(ds);
    let ingested = src.rate_stats().expect("ingested traces have rate stats");
    let mut rng = Rng::new(1);
    let synthetic = TraceSource::Synthetic(SyntheticTrace::generate(20, &mut rng));
    let syn_stats = synthetic.rate_stats().unwrap();
    let table = ingested.comparison_table("ingested", &syn_stats, "synthetic");
    assert!(table.contains("total rate"), "{table}");
    assert_eq!(ingested.functions, 20);
}

#[test]
fn ingested_fleet_runs_and_is_thread_count_invariant() {
    let ds = AzureDataset::load(&sample_dir()).unwrap().top_k(10);
    let src = TraceSource::AzureDataset(ds);
    let base =
        FleetConfig::from_source(&src, 7_200.0, 0.0, 0xA22E, PolicySpec::fixed(600.0));
    let reference = base.clone().with_threads(1).run();
    assert!(reference.aggregate.total_requests > 100);
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
    }
    // Repeated runs replay identical arrivals (streaming sources reseed).
    let again = base.clone().run();
    assert_eq!(fleet_digest(&again), fleet_digest(&reference));
}

#[test]
fn scenario_with_azure_source_reports_provenance() {
    let dir = sample_dir().display().to_string();
    let spec = ScenarioSpec::new("azure-e2e")
        .with_horizon(3_600.0)
        .with_skip_initial(0.0)
        .with_seed(7)
        .with_experiment(simfaas::ExperimentSpec::Fleet(
            simfaas::scenario::FleetScenario::new(1),
        ))
        .with_source(SourceSpec::AzureDataset {
            dir,
            top_k: Some(8),
            slice: None,
            scale_rate: 1.0,
        });
    let report = run_scenario(&spec).unwrap();
    match &report {
        ScenarioReport::Fleet { results, provenance, .. } => {
            assert_eq!(results.per_function.len(), 8);
            assert_eq!(provenance.kind, "azure_dataset");
            assert!(provenance.detail.contains("top_k(8)"), "{}", provenance.detail);
        }
        _ => panic!("expected a fleet report"),
    }
    // Provenance lands in both the table and the JSON.
    let table = report.render(&spec);
    assert!(table.contains("workload: azure_dataset"), "{table}");
    let json = report.to_json(&spec).to_string();
    assert!(json.contains("\"trace\":"), "{json}");
    assert!(json.contains("azure_dataset"), "{json}");
}

#[test]
fn synthetic_scenario_reports_provenance_too() {
    let spec = ScenarioSpec::new("syn")
        .with_horizon(800.0)
        .with_skip_initial(0.0)
        .with_experiment(simfaas::ExperimentSpec::Fleet(
            simfaas::scenario::FleetScenario::new(3),
        ));
    let report = run_scenario(&spec).unwrap();
    let table = report.render(&spec);
    assert!(table.contains("workload: synthetic"), "{table}");
    let json = report.to_json(&spec).to_string();
    assert!(json.contains("\"source\":\"synthetic\""), "{json}");
}

/// The bundled scenario file executes end to end after resolving its
/// relative dataset path against the file's location — the in-process
/// version of `simfaas run examples/scenarios/fleet_azure_trace.json`.
#[test]
fn bundled_azure_scenario_file_runs_end_to_end() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/scenarios/fleet_azure_trace.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let mut spec = ScenarioSpec::from_json_str(&text).unwrap();
    spec.resolve_source_paths(path.parent().unwrap());
    let report = run_scenario(&spec).unwrap();
    match &report {
        ScenarioReport::Fleet { results, provenance, .. } => {
            assert_eq!(results.per_function.len(), 20);
            assert_eq!(provenance.kind, "azure_dataset");
            assert!(results.aggregate.total_requests > 10_000);
        }
        _ => panic!("expected a fleet report"),
    }
}

/// The bundled autoscaling scenario (Azure sample trace + target-tracking
/// host scaling on a 2-host cluster) executes end to end with a control
/// report in the output — the in-process version of
/// `simfaas run examples/scenarios/fleet_autoscale.json`.
#[test]
fn bundled_autoscale_scenario_file_runs_end_to_end() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/scenarios/fleet_autoscale.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let mut spec = ScenarioSpec::from_json_str(&text).unwrap();
    spec.resolve_source_paths(path.parent().unwrap());
    let report = run_scenario(&spec).unwrap();
    match &report {
        ScenarioReport::Fleet { results, .. } => {
            let ctl = results.control.as_ref().expect("control report");
            assert!(ctl.ticks > 0, "controller never ticked");
            assert!(ctl.spec.starts_with("target:0.7"), "{}", ctl.spec);
        }
        _ => panic!("expected a fleet report"),
    }
    let rendered = report.render(&spec);
    assert!(rendered.contains("Controller target:0.7"), "{rendered}");
    assert!(rendered.contains("scale events"), "{rendered}");
}

#[test]
fn missing_dataset_fails_with_named_dir() {
    let spec = ScenarioSpec::new("bad")
        .with_experiment(simfaas::ExperimentSpec::Fleet(
            simfaas::scenario::FleetScenario::new(1),
        ))
        .with_source(SourceSpec::AzureDataset {
            dir: "/nonexistent/azure".into(),
            top_k: None,
            slice: None,
            scale_rate: 1.0,
        });
    let err = format!("{:#}", run_scenario(&spec).unwrap_err());
    assert!(err.contains("/nonexistent/azure"), "{err}");
}

#[test]
fn explicit_and_recorded_sources_drive_fleets() {
    // Recorded: one function replaying a fixed workload.
    let w = simfaas::workload::Workload { arrivals: (1..=50).map(|i| i as f64).collect() };
    let res = FleetConfig::from_source(
        &TraceSource::Recorded(w),
        100.0,
        0.0,
        3,
        PolicySpec::fixed(600.0),
    )
    .run();
    assert_eq!(res.per_function.len(), 1);
    assert_eq!(res.aggregate.total_requests, 50);
    // Exponential Table-1 services: at least the first request is cold and
    // the 600 s keep-alive guarantees nothing is rejected.
    assert!(res.aggregate.cold_requests >= 1);
    assert_eq!(res.aggregate.rejected_requests, 0);

    // Explicit: specs pass through unchanged.
    let spec = FunctionSpec {
        name: "explicit".into(),
        arrival: ArrivalMode::Trace(Arc::new(vec![5.0, 6.0])),
        batch_size: None,
        warm_service: simfaas::Process::constant(0.5),
        cold_service: simfaas::Process::constant(1.0),
        max_concurrency: 4,
        memory_mb: 64.0,
        seed: 9,
    };
    let res = FleetConfig::from_source(
        &TraceSource::Explicit(vec![spec]),
        50.0,
        0.0,
        1,
        PolicySpec::fixed(600.0),
    )
    .run();
    assert_eq!(res.aggregate.total_requests, 2);
    assert_eq!(res.aggregate.warm_requests, 1);
}
