//! Terminal plots: multi-series line charts and histograms rendered as
//! ASCII. Every figure of the paper regenerates as one of these (plus a CSV
//! for external plotting).

/// A named data series for [`ascii_lines`].
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new<S: Into<String>>(name: S, points: Vec<(f64, f64)>) -> Self {
        Series { name: name.into(), points }
    }
}

/// Render series into a `width x height` character grid with axis labels.
/// Each series gets a distinct glyph; overlapping points show the later
/// series' glyph.
pub fn ascii_lines(series: &[Series], width: usize, height: usize) -> String {
    const GLYPHS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];
    let pts: Vec<&(f64, f64)> = series.iter().flat_map(|s| &s.points).collect();
    if pts.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &&(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let g = GLYPHS[si % GLYPHS.len()];
        for &(x, y) in &s.points {
            let cx = (((x - x0) / (x1 - x0)) * (width as f64 - 1.0)).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height as f64 - 1.0)).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = g;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{y1:>12.4} ┐\n"));
    for row in grid {
        out.push_str("             │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("{y0:>12.4} └"));
    out.push_str(&"─".repeat(width));
    out.push('\n');
    out.push_str(&format!("{:>14}{:.4} .. {:.4}\n", "x: ", x0, x1));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], s.name));
    }
    out
}

/// Render a histogram/PMF as horizontal bars (Fig. 3 style: one bar per
/// integer level, length proportional to probability).
pub fn ascii_histogram(labels: &[String], values: &[f64], width: usize) -> String {
    assert_eq!(labels.len(), values.len());
    let vmax = values.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
    let lw = labels.iter().map(|l| l.len()).max().unwrap_or(1);
    let mut out = String::new();
    for (l, &v) in labels.iter().zip(values) {
        let bar = ((v / vmax) * width as f64).round() as usize;
        out.push_str(&format!(
            "{l:>lw$} │{} {v:.4}\n",
            "█".repeat(bar),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_renders_monotone_series() {
        let s = Series::new("test", (0..20).map(|i| (i as f64, i as f64 * 2.0)).collect());
        let out = ascii_lines(&[s], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains("test"));
        // y-max label present
        assert!(out.contains("38.0000"));
    }

    #[test]
    fn lines_multi_series_glyphs() {
        let a = Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]);
        let b = Series::new("b", vec![(0.0, 1.0), (1.0, 0.0)]);
        let out = ascii_lines(&[a, b], 20, 8);
        assert!(out.contains('*') && out.contains('o'));
    }

    #[test]
    fn histogram_bars_proportional() {
        let labels: Vec<String> = (0..3).map(|i| i.to_string()).collect();
        let out = ascii_histogram(&labels, &[0.1, 0.2, 0.4], 20);
        let bars: Vec<usize> = out.lines().map(|l| l.matches('█').count()).collect();
        assert_eq!(bars[2], 20);
        assert_eq!(bars[1], 10);
        assert_eq!(bars[0], 5);
    }

    #[test]
    fn empty_series_no_panic() {
        assert_eq!(ascii_lines(&[], 10, 5), "(no data)\n");
    }
}
