//! Markovian steady-state model of a scale-per-request platform — the
//! analytical baseline SimFaaS is positioned against (Mahmoudi & Khazaei,
//! "Performance Modeling of Serverless Computing Platforms", 2020a).
//!
//! The model is a CTMC over `(busy, idle)` instance counts:
//!
//! * arrivals: Poisson(λ). With an idle instance, the arrival occupies one
//!   (warm start, `(b, i) -> (b+1, i-1)`); otherwise, below the concurrency
//!   cap a cold start spins up a new busy instance (`(b, i) -> (b+1, i)`);
//!   at the cap the request is rejected (no transition).
//! * services: each busy instance completes at rate μ = 1/E[S]
//!   (`(b, i) -> (b-1, i+1)` — the instance parks in the idle pool).
//! * expirations: **the Markovian approximation** — each idle instance
//!   expires at rate γ = 1/threshold (`(b, i) -> (b, i-1)`).
//!
//! The deterministic 10-minute threshold used by real platforms is *not*
//! exponential; this memorylessness assumption is exactly the limitation the
//! paper cites when motivating a simulator ("those models are limited to
//! Markovian processes"). `analytical::compare` quantifies the gap against
//! the discrete-event simulator, which handles the deterministic threshold
//! natively.

use super::ctmc::Ctmc;

/// Model parameters.
#[derive(Debug, Clone, Copy)]
pub struct SteadyStateModel {
    /// Arrival rate λ (req/s).
    pub arrival_rate: f64,
    /// Mean service time E[S] in seconds (warm; the model does not
    /// distinguish cold service duration — a second-order effect at the
    /// loads the paper studies).
    pub mean_service_time: f64,
    /// Expiration threshold in seconds (expires at rate 1/threshold).
    pub expiration_threshold: f64,
    /// Maximum concurrency level (cap on busy instances).
    pub max_concurrency: usize,
    /// State-space truncation for busy and idle dimensions.
    pub max_busy: usize,
    pub max_idle: usize,
}

/// Model outputs (the analytical analogue of `SimResults`).
#[derive(Debug, Clone, Copy)]
pub struct SteadyStateMetrics {
    pub cold_start_prob: f64,
    pub rejection_prob: f64,
    pub avg_server_count: f64,
    pub avg_running_count: f64,
    pub avg_idle_count: f64,
    pub wasted_capacity: f64,
    /// Mean rate at which new instances are created (cold starts /s).
    pub instance_creation_rate: f64,
    /// Mean instance lifespan implied by Little's law on the pool.
    pub avg_lifespan: f64,
}

impl SteadyStateModel {
    /// Sensible truncations for a given load: the busy dimension follows an
    /// M/M/∞ with mean λE[S]; idle pool mean is bounded by λ·threshold·
    /// P(idle-bound). We take generous multiples.
    pub fn new(arrival_rate: f64, mean_service_time: f64, expiration_threshold: f64) -> Self {
        let busy_mean = arrival_rate * mean_service_time;
        let idle_mean = arrival_rate * expiration_threshold; // upper bound-ish
        SteadyStateModel {
            arrival_rate,
            mean_service_time,
            expiration_threshold,
            max_concurrency: 1000,
            max_busy: ((busy_mean + 6.0 * busy_mean.sqrt()).ceil() as usize + 8).max(16),
            max_idle: ((idle_mean + 6.0 * idle_mean.sqrt()).ceil() as usize + 8).max(16),
        }
    }

    fn index(&self, b: usize, i: usize) -> usize {
        b * (self.max_idle + 1) + i
    }

    /// Build the CTMC generator.
    pub fn build_ctmc(&self) -> Ctmc {
        let nb = self.max_busy + 1;
        let ni = self.max_idle + 1;
        let mut c = Ctmc::new(nb * ni);
        let lambda = self.arrival_rate;
        let mu = 1.0 / self.mean_service_time;
        let gamma = 1.0 / self.expiration_threshold;
        let cap = self.max_concurrency.min(self.max_busy);
        for b in 0..nb {
            for i in 0..ni {
                let s = self.index(b, i);
                // Arrival.
                if i > 0 {
                    // Warm start.
                    if b < self.max_busy {
                        c.add(s, self.index(b + 1, i - 1), lambda);
                    }
                } else if b < cap {
                    // Cold start.
                    c.add(s, self.index(b + 1, i), lambda);
                }
                // (else: rejection, no transition)
                // Service completion.
                if b > 0 && i < self.max_idle {
                    c.add(s, self.index(b - 1, i + 1), b as f64 * mu);
                } else if b > 0 {
                    // Idle dimension saturated: completion folds straight to
                    // expiration (truncation guard, negligible mass).
                    c.add(s, self.index(b - 1, i), b as f64 * mu);
                }
                // Expiration.
                if i > 0 {
                    c.add(s, self.index(b, i - 1), i as f64 * gamma);
                }
            }
        }
        c
    }

    /// Solve for the steady-state metrics.
    pub fn solve(&self) -> SteadyStateMetrics {
        let c = self.build_ctmc();
        let pi = c.steady_state(1e-12, 50_000);
        let ni = self.max_idle + 1;
        let cap = self.max_concurrency.min(self.max_busy);

        let mut avg_busy = 0.0;
        let mut avg_idle = 0.0;
        let mut p_no_idle_below_cap = 0.0; // states where an arrival is cold
        let mut p_reject = 0.0; // states where an arrival is rejected
        for (s, &p) in pi.iter().enumerate() {
            let b = s / ni;
            let i = s % ni;
            avg_busy += p * b as f64;
            avg_idle += p * i as f64;
            if i == 0 {
                if b < cap {
                    p_no_idle_below_cap += p;
                } else {
                    p_reject += p;
                }
            }
        }
        // PASTA: Poisson arrivals see time averages.
        let p_cold = p_no_idle_below_cap / (1.0 - p_reject).max(1e-300);
        let creation_rate = self.arrival_rate * p_no_idle_below_cap;
        let pool = avg_busy + avg_idle;
        // Little's law on the instance pool: N = creation_rate * lifespan.
        let lifespan = if creation_rate > 0.0 { pool / creation_rate } else { f64::INFINITY };
        SteadyStateMetrics {
            cold_start_prob: p_cold,
            rejection_prob: p_reject,
            avg_server_count: pool,
            avg_running_count: avg_busy,
            avg_idle_count: avg_idle,
            wasted_capacity: if pool > 0.0 { avg_idle / pool } else { 0.0 },
            instance_creation_rate: creation_rate,
            avg_lifespan: lifespan,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_servers_follow_littles_law() {
        let m = SteadyStateModel::new(0.9, 1.991, 600.0);
        let r = m.solve();
        // The busy dimension is effectively M/M/inf: E[b] = lambda E[S].
        let expect = 0.9 * 1.991;
        assert!(
            (r.avg_running_count - expect).abs() / expect < 0.01,
            "busy={} expect={}",
            r.avg_running_count,
            expect
        );
        assert!(r.rejection_prob < 1e-9);
        assert!(r.cold_start_prob > 0.0 && r.cold_start_prob < 0.05);
        // Total = busy + idle.
        assert!(
            (r.avg_server_count - r.avg_running_count - r.avg_idle_count).abs() < 1e-9
        );
    }

    #[test]
    fn higher_rate_lowers_cold_start_prob() {
        // More traffic keeps the pool warm: p_cold decreases with lambda
        // in this regime (paper Fig. 6 shows the same trend).
        let lo = SteadyStateModel::new(0.2, 1.991, 600.0).solve();
        let hi = SteadyStateModel::new(2.0, 1.991, 600.0).solve();
        assert!(hi.cold_start_prob < lo.cold_start_prob);
    }

    #[test]
    fn longer_threshold_lowers_cold_start_prob() {
        // Paper Fig. 5 trend.
        let short = SteadyStateModel::new(0.9, 1.991, 120.0).solve();
        let long = SteadyStateModel::new(0.9, 1.991, 1200.0).solve();
        assert!(long.cold_start_prob < short.cold_start_prob);
        // ... at the cost of more idle instances (provider cost).
        assert!(long.avg_idle_count > short.avg_idle_count);
    }

    #[test]
    fn concurrency_cap_produces_rejections() {
        let mut m = SteadyStateModel::new(10.0, 2.0, 60.0);
        m.max_concurrency = 5;
        let r = m.solve();
        assert!(r.rejection_prob > 0.2, "p_reject={}", r.rejection_prob);
        assert!(r.avg_running_count <= 5.0 + 1e-9);
    }

    #[test]
    fn idle_pool_scales_with_threshold() {
        // With gamma-expiration, idle pool mean ~ creation_rate/gamma at low
        // reuse; sanity check monotonicity and magnitude.
        let r = SteadyStateModel::new(0.9, 1.991, 600.0).solve();
        assert!(r.avg_idle_count > 1.0 && r.avg_idle_count < 20.0);
        assert!(r.avg_lifespan > 600.0); // instances live at least a threshold
    }
}
