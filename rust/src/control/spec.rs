//! Serializable controller specs, mirroring `cluster::SchedulerSpec`:
//! a compact string grammar with `parse`/`as_str` round-tripping, used by
//! the CLI flag, the scenario JSON schema, and the what-if harness.
//!
//! Grammar (`;key=value` options apply to any kind):
//!
//! ```text
//! target:UTIL[,COOLDOWN,STEP]     # target tracking (cooldown s, step units)
//! pid:KP,KI,KD[,TARGET]           # PID over utilization error
//! step:LOW,HIGH[,STEP]            # threshold ladder
//!   [;tick=SECS][;min=N][;max=N][;delay=SECS]
//! ```
//!
//! Defaults: cooldown 60 s, step 4 (target) / 1 (step), PID target 0.7,
//! tick 10 s, min 1, max 0 (unbounded), provisioning delay 60 s.

use super::controller::{Controller, Pid, StepPolicy, TargetTracking};

/// Default simulated seconds between control ticks.
pub const DEFAULT_TICK_INTERVAL: f64 = 10.0;
/// Default lower capacity bound (never scale to zero).
pub const DEFAULT_MIN_CAPACITY: u64 = 1;
/// Default upper capacity bound (0 = unbounded).
pub const DEFAULT_MAX_CAPACITY: u64 = 0;
/// Default host provisioning delay in simulated seconds (cluster backend;
/// gate actuation is always instant).
pub const DEFAULT_PROVISION_DELAY: f64 = 60.0;
/// Default target-tracking scale-in cooldown in simulated seconds.
pub const DEFAULT_COOLDOWN: f64 = 60.0;
/// Default target-tracking per-tick step limit.
pub const DEFAULT_TARGET_STEP: u32 = 4;
/// Default PID utilization setpoint.
pub const DEFAULT_PID_TARGET: f64 = 0.7;
/// Default step-policy ladder rung.
pub const DEFAULT_LADDER_STEP: u32 = 1;

/// Which controller to run (the positional part of the spec grammar).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControllerKind {
    /// `target:UTIL,COOLDOWN,STEP` — hold a target utilization.
    TargetTracking {
        /// Utilization setpoint.
        target: f64,
        /// Simulated seconds between scale-ins.
        cooldown: f64,
        /// Max capacity units moved per tick (0 = inert).
        max_step: u32,
    },
    /// `pid:KP,KI,KD,TARGET` — PID over the utilization error.
    Pid {
        /// Proportional gain.
        kp: f64,
        /// Integral gain.
        ki: f64,
        /// Derivative gain.
        kd: f64,
        /// Utilization setpoint.
        target: f64,
    },
    /// `step:LOW,HIGH,STEP` — threshold ladder.
    Step {
        /// Scale-in threshold.
        low: f64,
        /// Scale-out threshold.
        high: f64,
        /// Capacity units moved per breach.
        step: u32,
    },
}

impl ControllerKind {
    /// Instantiate the runtime controller for one capacity domain.
    pub fn build(&self) -> Box<dyn Controller> {
        match *self {
            ControllerKind::TargetTracking { target, cooldown, max_step } => {
                Box::new(TargetTracking::new(target, cooldown, max_step))
            }
            ControllerKind::Pid { kp, ki, kd, target } => Box::new(Pid::new(kp, ki, kd, target)),
            ControllerKind::Step { low, high, step } => Box::new(StepPolicy::new(low, high, step)),
        }
    }

    /// The signal value the controller steers toward.
    pub fn setpoint(&self) -> f64 {
        match *self {
            ControllerKind::TargetTracking { target, .. } => target,
            ControllerKind::Pid { target, .. } => target,
            ControllerKind::Step { low, high, .. } => (low + high) / 2.0,
        }
    }

    /// Short kind name (`target`, `pid`, `step`) for labels and tables.
    pub fn name(&self) -> &'static str {
        match self {
            ControllerKind::TargetTracking { .. } => "target",
            ControllerKind::Pid { .. } => "pid",
            ControllerKind::Step { .. } => "step",
        }
    }
}

/// A complete, serializable controller configuration: the kind plus the
/// tick interval, capacity bounds, and provisioning delay shared by all
/// kinds. `parse(&s.as_str()) == Some(s)` for every valid spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControllerSpec {
    /// Which controller to run.
    pub kind: ControllerKind,
    /// Simulated seconds between control ticks.
    pub tick_interval: f64,
    /// Lower capacity bound (fleet-wide; striped across domains).
    pub min_capacity: u64,
    /// Upper capacity bound, 0 = unbounded (fleet-wide; striped).
    pub max_capacity: u64,
    /// Host provisioning delay in simulated seconds (cluster backend).
    pub provision_delay: f64,
}

impl ControllerSpec {
    fn with_kind(kind: ControllerKind) -> ControllerSpec {
        ControllerSpec {
            kind,
            tick_interval: DEFAULT_TICK_INTERVAL,
            min_capacity: DEFAULT_MIN_CAPACITY,
            max_capacity: DEFAULT_MAX_CAPACITY,
            provision_delay: DEFAULT_PROVISION_DELAY,
        }
    }

    /// Target-tracking spec with default cooldown/step/options.
    pub fn target_tracking(target: f64) -> ControllerSpec {
        ControllerSpec::with_kind(ControllerKind::TargetTracking {
            target,
            cooldown: DEFAULT_COOLDOWN,
            max_step: DEFAULT_TARGET_STEP,
        })
    }

    /// PID spec with the default setpoint and options.
    pub fn pid(kp: f64, ki: f64, kd: f64) -> ControllerSpec {
        ControllerSpec::with_kind(ControllerKind::Pid { kp, ki, kd, target: DEFAULT_PID_TARGET })
    }

    /// Step-ladder spec with the default rung and options.
    pub fn step(low: f64, high: f64) -> ControllerSpec {
        ControllerSpec::with_kind(ControllerKind::Step { low, high, step: DEFAULT_LADDER_STEP })
    }

    /// Override the tick interval (simulated seconds).
    pub fn with_tick(mut self, tick_interval: f64) -> ControllerSpec {
        self.tick_interval = tick_interval;
        self
    }

    /// Override the fleet-wide capacity bounds (`max` 0 = unbounded).
    pub fn with_bounds(mut self, min: u64, max: u64) -> ControllerSpec {
        self.min_capacity = min;
        self.max_capacity = max;
        self
    }

    /// Override the host provisioning delay (simulated seconds).
    pub fn with_provision_delay(mut self, delay: f64) -> ControllerSpec {
        self.provision_delay = delay;
        self
    }

    /// Parse the spec grammar (see the module docs); `None` on anything
    /// malformed — unknown kind or option key, wrong arity, non-numeric
    /// fields.
    pub fn parse(s: &str) -> Option<ControllerSpec> {
        let mut parts = s.split(';');
        let head = parts.next()?.trim();
        let (kind_name, params) = head.split_once(':')?;
        let nums: Vec<&str> = params.split(',').map(str::trim).collect();
        let f = |i: usize| nums.get(i).and_then(|v| v.parse::<f64>().ok());
        let u = |i: usize| nums.get(i).and_then(|v| v.parse::<u32>().ok());
        let kind = match kind_name.trim() {
            "target" if (1..=3).contains(&nums.len()) => ControllerKind::TargetTracking {
                target: f(0)?,
                cooldown: if nums.len() > 1 { f(1)? } else { DEFAULT_COOLDOWN },
                max_step: if nums.len() > 2 { u(2)? } else { DEFAULT_TARGET_STEP },
            },
            "pid" if (3..=4).contains(&nums.len()) => ControllerKind::Pid {
                kp: f(0)?,
                ki: f(1)?,
                kd: f(2)?,
                target: if nums.len() > 3 { f(3)? } else { DEFAULT_PID_TARGET },
            },
            "step" if (2..=3).contains(&nums.len()) => ControllerKind::Step {
                low: f(0)?,
                high: f(1)?,
                step: if nums.len() > 2 { u(2)? } else { DEFAULT_LADDER_STEP },
            },
            _ => return None,
        };
        let mut spec = ControllerSpec::with_kind(kind);
        for opt in parts {
            let (key, value) = opt.trim().split_once('=')?;
            match key.trim() {
                "tick" => spec.tick_interval = value.trim().parse().ok()?,
                "min" => spec.min_capacity = value.trim().parse().ok()?,
                "max" => spec.max_capacity = value.trim().parse().ok()?,
                "delay" => spec.provision_delay = value.trim().parse().ok()?,
                _ => return None,
            }
        }
        Some(spec)
    }

    /// Canonical string form: full positional parameters, plus only the
    /// non-default `;key=value` options. Round-trips through [`parse`].
    ///
    /// [`parse`]: ControllerSpec::parse
    pub fn as_str(&self) -> String {
        let mut s = match self.kind {
            ControllerKind::TargetTracking { target, cooldown, max_step } => {
                format!("target:{target},{cooldown},{max_step}")
            }
            ControllerKind::Pid { kp, ki, kd, target } => format!("pid:{kp},{ki},{kd},{target}"),
            ControllerKind::Step { low, high, step } => format!("step:{low},{high},{step}"),
        };
        if self.tick_interval != DEFAULT_TICK_INTERVAL {
            s.push_str(&format!(";tick={}", self.tick_interval));
        }
        if self.min_capacity != DEFAULT_MIN_CAPACITY {
            s.push_str(&format!(";min={}", self.min_capacity));
        }
        if self.max_capacity != DEFAULT_MAX_CAPACITY {
            s.push_str(&format!(";max={}", self.max_capacity));
        }
        if self.provision_delay != DEFAULT_PROVISION_DELAY {
            s.push_str(&format!(";delay={}", self.provision_delay));
        }
        s
    }

    /// Validate the numeric ranges a successful parse can still get
    /// wrong; returns a human-readable complaint for scenario validation.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.tick_interval.is_finite() && self.tick_interval > 0.0) {
            return Err(format!("controller tick interval must be positive, got {}", self.tick_interval));
        }
        if !(self.provision_delay.is_finite() && self.provision_delay >= 0.0) {
            return Err(format!("controller provisioning delay must be >= 0, got {}", self.provision_delay));
        }
        if self.max_capacity != 0 && self.max_capacity < self.min_capacity {
            return Err(format!(
                "controller max capacity {} is below min capacity {}",
                self.max_capacity, self.min_capacity
            ));
        }
        match self.kind {
            ControllerKind::TargetTracking { target, cooldown, .. } => {
                if !(target.is_finite() && target > 0.0) {
                    return Err(format!("target-tracking setpoint must be positive, got {target}"));
                }
                if !(cooldown.is_finite() && cooldown >= 0.0) {
                    return Err(format!("target-tracking cooldown must be >= 0, got {cooldown}"));
                }
            }
            ControllerKind::Pid { kp, ki, kd, target } => {
                for (name, g) in [("kp", kp), ("ki", ki), ("kd", kd)] {
                    if !(g.is_finite() && g >= 0.0) {
                        return Err(format!("PID gain {name} must be a finite value >= 0, got {g}"));
                    }
                }
                if !(target.is_finite() && target > 0.0) {
                    return Err(format!("PID setpoint must be positive, got {target}"));
                }
            }
            ControllerKind::Step { low, high, .. } => {
                if !(low.is_finite() && high.is_finite() && low < high) {
                    return Err(format!(
                        "step thresholds must satisfy low < high, got low {low} high {high}"
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_fills_defaults() {
        let spec = ControllerSpec::parse("target:0.7").unwrap();
        assert_eq!(
            spec.kind,
            ControllerKind::TargetTracking {
                target: 0.7,
                cooldown: DEFAULT_COOLDOWN,
                max_step: DEFAULT_TARGET_STEP
            }
        );
        assert_eq!(spec.tick_interval, DEFAULT_TICK_INTERVAL);
        assert_eq!(spec.min_capacity, 1);
        assert_eq!(spec.max_capacity, 0);
        let spec = ControllerSpec::parse("pid:0.5,0.1,0").unwrap();
        assert_eq!(spec.kind, ControllerKind::Pid { kp: 0.5, ki: 0.1, kd: 0.0, target: 0.7 });
        let spec = ControllerSpec::parse("step:0.3,0.9").unwrap();
        assert_eq!(spec.kind, ControllerKind::Step { low: 0.3, high: 0.9, step: 1 });
    }

    #[test]
    fn parse_options_and_whitespace() {
        let spec = ControllerSpec::parse(" target:0.6,30,2 ; tick=5 ; min=2 ; max=12 ; delay=90 ").unwrap();
        assert_eq!(spec.tick_interval, 5.0);
        assert_eq!(spec.min_capacity, 2);
        assert_eq!(spec.max_capacity, 12);
        assert_eq!(spec.provision_delay, 90.0);
    }

    #[test]
    fn parse_rejects_malformed() {
        for s in [
            "",
            "target",
            "target:",
            "target:x",
            "target:0.7,1,2,3",
            "pid:1,2",
            "step:0.5",
            "warp:0.7",
            "target:0.7;bogus=1",
            "target:0.7;tick=abc",
            "target:0.7;tick",
        ] {
            assert!(ControllerSpec::parse(s).is_none(), "{s:?} should not parse");
        }
    }

    #[test]
    fn as_str_round_trips() {
        for s in [
            "target:0.7",
            "target:0.55,120,1",
            "pid:0.8,0.05,0.2",
            "pid:1,0,0,0.5",
            "step:0.3,0.85,2",
            "target:0.7;tick=30;max=6",
            "step:0.2,0.8;min=2;delay=15",
        ] {
            let spec = ControllerSpec::parse(s).unwrap();
            let canon = spec.as_str();
            assert_eq!(ControllerSpec::parse(&canon), Some(spec), "{s} -> {canon}");
        }
        // Canonical form is stable: re-serializing the reparse is a no-op.
        let spec = ControllerSpec::parse("target:0.7;tick=30").unwrap();
        assert_eq!(ControllerSpec::parse(&spec.as_str()).unwrap().as_str(), spec.as_str());
    }

    #[test]
    fn builders_match_grammar() {
        assert_eq!(
            ControllerSpec::target_tracking(0.7).with_tick(30.0).with_bounds(1, 6),
            ControllerSpec::parse("target:0.7;tick=30;max=6").unwrap()
        );
        assert_eq!(
            ControllerSpec::pid(0.8, 0.05, 0.2),
            ControllerSpec::parse("pid:0.8,0.05,0.2").unwrap()
        );
        assert_eq!(
            ControllerSpec::step(0.3, 0.85).with_provision_delay(5.0),
            ControllerSpec::parse("step:0.3,0.85;delay=5").unwrap()
        );
    }

    #[test]
    fn validate_catches_bad_ranges() {
        assert!(ControllerSpec::parse("target:0.7;tick=0").unwrap().validate().is_err());
        assert!(ControllerSpec::parse("target:-0.5").unwrap().validate().is_err());
        assert!(ControllerSpec::parse("step:0.9,0.3").unwrap().validate().is_err());
        assert!(ControllerSpec::parse("pid:-1,0,0").unwrap().validate().is_err());
        assert!(ControllerSpec::parse("target:0.7;min=5;max=2").unwrap().validate().is_err());
        assert!(ControllerSpec::parse("target:0.7,60,4;min=1;max=8").unwrap().validate().is_ok());
    }
}
