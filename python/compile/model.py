"""Layer-2 JAX model: the serverless function payloads and analysis graphs.

The emulator's function instances execute these computations on their
request path (via the AOT artifacts — Python never runs at serve time):

* ``payload_small`` / ``payload_medium`` / ``payload_large`` — MLP-inference
  serverless functions at three sizes, standing in for the paper's three
  memory configurations (128/256/512 MB): larger memory on Lambda means a
  proportionally faster-but-bigger footprint; here it means a bigger model
  per request, giving distinct, realistic service-time distributions.
* ``trace_histogram`` — the simulator-side analysis graph: fixed-bin
  histogram of a sample trace (PDF/CDF tooling), backed by the Pallas
  histogram kernel.

Weights are generated once from a fixed seed and baked into the lowered
HLO as constants — a deployed inference function's weights are part of its
deployment package, which is exactly the paper's "application initializing"
story (load model once per instance).
"""

import jax
import jax.numpy as jnp

from .kernels import hist as hist_kernel
from .kernels import mlp as mlp_kernel

# Payload geometry per emulated memory configuration. Feature dims are
# 128-lane aligned; batch is one BLOCK_B tile.
PAYLOAD_SHAPES = {
    # name: (batch, d_in, d_hidden, d_out)
    "small": (128, 128, 256, 128),
    "medium": (128, 256, 512, 128),
    "large": (128, 512, 1024, 128),
}

# Histogram geometry (must match rust/src/runtime/payload.rs).
HIST_N = hist_kernel.BLOCK_N * 2  # two grid steps exercises accumulation
HIST_NBINS = 64


def make_weights(name: str, seed: int = 0):
    """Deterministic weights for a payload variant."""
    batch, d_in, d_hidden, d_out = PAYLOAD_SHAPES[name]
    del batch
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    scale1 = (2.0 / d_in) ** 0.5
    scale2 = (2.0 / d_hidden) ** 0.5
    return (
        jax.random.normal(k1, (d_in, d_hidden), jnp.float32) * scale1,
        jax.random.normal(k2, (d_hidden,), jnp.float32) * 0.01,
        jax.random.normal(k3, (d_hidden, d_out), jnp.float32) * scale2,
        jax.random.normal(k4, (d_out,), jnp.float32) * 0.01,
    )


def make_payload(name: str):
    """Build the payload function ``x -> logits`` with baked weights,
    plus its example input spec (for lowering)."""
    batch, d_in, _, _ = PAYLOAD_SHAPES[name]
    w1, b1, w2, b2 = make_weights(name)

    def payload(x):
        return (mlp_kernel.mlp_forward(x, w1, b1, w2, b2),)

    example = jax.ShapeDtypeStruct((batch, d_in), jnp.float32)
    return payload, (example,)


def payload_small(x):
    return make_payload("small")[0](x)


def payload_medium(x):
    return make_payload("medium")[0](x)


def payload_large(x):
    return make_payload("large")[0](x)


def make_trace_histogram():
    """Analysis graph: histogram of a fixed-size sample trace over a
    dynamic range [lo, hi)."""

    def trace_histogram(samples, lo, hi):
        return (
            hist_kernel.histogram(samples, lo, hi, nbins=HIST_NBINS),
        )

    example = (
        jax.ShapeDtypeStruct((HIST_N,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    return trace_histogram, example


#: All AOT entry points: name -> (fn, example_args).
ENTRY_POINTS = {
    "payload_small": make_payload("small"),
    "payload_medium": make_payload("medium"),
    "payload_large": make_payload("large"),
    "trace_histogram": make_trace_histogram(),
}
