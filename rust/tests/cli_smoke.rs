//! CLI smoke tests: every subcommand runs end-to-end through the real
//! binary (std::process on `CARGO_BIN_EXE_simfaas`) with small horizons.

use std::process::Command;

fn simfaas(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_simfaas"))
        .args(args)
        .output()
        .expect("binary runs");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// Every subcommand the binary dispatches. `simfaas help` and the
/// unknown-command error must list each one (both derive from the same
/// command table in main.rs; this pins the table against rot).
const ALL_COMMANDS: &[&str] = &[
    "run", "steady", "temporal", "ensemble", "fleet", "sweep", "emulate", "validate",
    "compare", "cost", "identify", "inspect", "probe", "figures",
];

#[test]
fn help_lists_every_command() {
    let (ok, text) = simfaas(&["help"]);
    assert!(ok);
    for cmd in ALL_COMMANDS {
        assert!(text.contains(cmd), "help missing {cmd}: {text}");
    }
}

#[test]
fn unknown_command_enumerates_every_command() {
    let (ok, text) = simfaas(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"), "{text}");
    for cmd in ALL_COMMANDS {
        assert!(text.contains(cmd), "unknown-command message missing {cmd}: {text}");
    }
}

#[test]
fn ensemble_reports_ci_summary() {
    let (ok, text) = simfaas(&[
        "ensemble",
        "--horizon",
        "5000",
        "--replications",
        "4",
        "--threads",
        "2",
        "--seed",
        "7",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("95% CI"), "{text}");
    assert!(text.contains("Cold Start Probability"), "{text}");

    // Zero replications is a clean CLI error, not a panic.
    let (ok, text) = simfaas(&["ensemble", "--horizon", "1000", "--replications", "0"]);
    assert!(!ok);
    assert!(text.contains("replications"), "{text}");
}

#[test]
fn ensemble_threshold_grid_reports_ci() {
    let (ok, text) = simfaas(&[
        "ensemble",
        "--horizon",
        "5000",
        "--replications",
        "3",
        "--thresholds",
        "120,600",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("threshold"), "{text}");
    assert!(text.contains("p_cold"), "{text}");
    assert!(text.contains("95% CI"), "{text}");
}

#[test]
fn steady_reports_table1_rows() {
    let (ok, text) = simfaas(&["steady", "--horizon", "20000", "--seed", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("Cold Start Probability"));
    assert!(text.contains("Average Server Count"));
}

#[test]
fn steady_json_is_parsable_shape() {
    let (ok, text) = simfaas(&["steady", "--horizon", "10000", "--json"]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"cold_start_prob\":"));
    assert!(line.ends_with('}'));
}

/// The fault-injection flags flow through to both engines and both output
/// formats, and a malformed retry spec is a clean error naming the flag.
#[test]
fn fault_flags_surface_reliability_metrics() {
    let (ok, text) = simfaas(&[
        "steady",
        "--horizon",
        "10000",
        "--seed",
        "3",
        "--failure-rate",
        "0.1",
        "--timeout",
        "30",
        "--retry",
        "exponential:0.1,5,4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Success Rate"), "{text}");
    assert!(text.contains("Failures (transient/timeout/coldstart)"), "{text}");
    assert!(text.contains("Retries (attempts/exhausted)"), "{text}");

    let (ok, text) =
        simfaas(&["steady", "--horizon", "10000", "--failure-rate", "0.1", "--json"]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"failed_requests\":"), "{line}");
    assert!(line.contains("\"goodput\":"), "{line}");

    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "4",
        "--horizon",
        "2000",
        "--skip",
        "0",
        "--failure-rate",
        "0.1",
        "--retry",
        "fixed:0.5,3",
        "--json",
    ]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"retry_attempts\":"), "{line}");
    assert!(line.contains("\"success_rate\":"), "{line}");

    let (ok, text) = simfaas(&["steady", "--horizon", "1000", "--retry", "cubic:1"]);
    assert!(!ok);
    assert!(text.contains("--retry"), "{text}");
}

#[test]
fn temporal_prints_ci() {
    let (ok, text) =
        simfaas(&["temporal", "--horizon", "3000", "--replications", "4", "--interval", "100"]);
    assert!(ok, "{text}");
    assert!(text.contains("95% CI"));
    assert!(text.contains("cold start probability"));
}

#[test]
fn fleet_reports_aggregate_and_cost() {
    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "5",
        "--horizon",
        "2000",
        "--seed",
        "3",
        "--threads",
        "2",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Cold Start Probability"), "{text}");
    assert!(text.contains("Functions"), "{text}");
    assert!(text.contains("developer cost"), "{text}");
    assert!(text.contains("top"), "{text}");
}

#[test]
fn fleet_json_and_policy_comparison() {
    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "4",
        "--horizon",
        "1500",
        "--policy",
        "adaptive",
        "--json",
    ]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"aggregate\""), "{line}");
    assert!(line.contains("\"cost\""), "{line}");
    assert!(line.ends_with('}'));

    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "4",
        "--horizon",
        "1500",
        "--compare-thresholds",
        "60,600",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("fixed(60s)"), "{text}");
    assert!(text.contains("fixed(600s)"), "{text}");
    assert!(text.contains("hybrid-histogram"), "{text}");
    assert!(text.contains("p_cold"), "{text}");
}

fn sample_trace_dir() -> String {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/traces/azure_sample")
        .display()
        .to_string()
}

#[test]
fn fleet_trace_dir_ingests_the_sample_dataset() {
    let dir = sample_trace_dir();
    let (ok, text) = simfaas(&[
        "fleet",
        "--trace-dir",
        &dir,
        "--trace-top-k",
        "10",
        "--horizon",
        "7200",
        "--skip",
        "0",
    ]);
    assert!(ok, "{text}");
    // Trace provenance in the table report.
    assert!(text.contains("workload: azure_dataset"), "{text}");
    assert!(text.contains("top_k(10)"), "{text}");
    assert!(text.contains("Cold Start Probability"), "{text}");

    // JSON output carries the provenance block.
    let (ok, text) = simfaas(&[
        "fleet",
        "--trace-dir",
        &dir,
        "--horizon",
        "3600",
        "--skip",
        "0",
        "--json",
    ]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"trace\":"), "{line}");
    assert!(line.contains("azure_dataset"), "{line}");
}

#[test]
fn fleet_trace_flags_fail_cleanly() {
    // Trace transforms without a trace dir are rejected.
    let (ok, text) = simfaas(&["fleet", "--trace-top-k", "5"]);
    assert!(!ok);
    assert!(text.contains("--trace-dir"), "{text}");
    // A missing dataset directory is a clean error naming the path.
    let (ok, text) = simfaas(&["fleet", "--trace-dir", "/nonexistent/azure"]);
    assert!(!ok);
    assert!(text.contains("/nonexistent/azure"), "{text}");
    // Synthetic-mix axes are rejected (not silently ignored) with a trace.
    let (ok, text) =
        simfaas(&["fleet", "--trace-dir", &sample_trace_dir(), "--functions", "500"]);
    assert!(!ok);
    assert!(text.contains("--functions"), "{text}");
}

/// The acceptance criterion: `simfaas run` executes the checked-in sample
/// trace end to end, with provenance in both output formats.
#[test]
fn run_executes_the_bundled_azure_trace_scenario() {
    let path = scenarios_dir().join("fleet_azure_trace.json");
    let (ok, text) = simfaas(&["run", path.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("workload: azure_dataset"), "{text}");
    let (ok, text) = simfaas(&["run", path.to_str().unwrap(), "--json"]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"trace\":"), "{line}");
}

#[test]
fn fleet_rejects_bad_flags() {
    // Unknown flag is a clean error, not a panic.
    let (ok, text) = simfaas(&["fleet", "--functions", "2", "--horizont", "100"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
    // Unknown policy name is a clean error too.
    let (ok, text) = simfaas(&["fleet", "--functions", "2", "--policy", "oracle"]);
    assert!(!ok);
    assert!(text.contains("unknown policy"), "{text}");
    // Zero functions is rejected.
    let (ok, text) = simfaas(&["fleet", "--functions", "0"]);
    assert!(!ok);
    assert!(text.contains("functions"), "{text}");
}

/// The autoscaling flag flows through the fleet translator: the report
/// gains its §Control section, the JSON carries the digest, and bad or
/// unanchored controller specs are clean errors.
#[test]
fn fleet_controller_flag_reports_control_section() {
    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "6",
        "--horizon",
        "2000",
        "--skip",
        "0",
        "--fleet-cap",
        "4",
        "--controller",
        "target:0.7",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Controller target:0.7"), "{text}");
    assert!(text.contains("scale events"), "{text}");
    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "6",
        "--horizon",
        "2000",
        "--skip",
        "0",
        "--fleet-cap",
        "4",
        "--controller",
        "target:0.7",
        "--json",
    ]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"control\":"), "{line}");
    assert!(line.contains("\"settling_time\":"), "{line}");
    // A malformed controller spec is a clean error naming the grammar.
    let (ok, text) = simfaas(&["fleet", "--fleet-cap", "4", "--controller", "bang:1"]);
    assert!(!ok);
    assert!(text.contains("target:UTIL"), "{text}");
    // A controller without a capacity model is rejected before running.
    let (ok, text) = simfaas(&["fleet", "--functions", "2", "--controller", "target:0.7"]);
    assert!(!ok);
    assert!(text.contains("fleet_cap or a cluster"), "{text}");
}

#[test]
fn sweep_prints_grid() {
    let (ok, text) = simfaas(&[
        "sweep",
        "--rates",
        "0.5,1.0",
        "--thresholds",
        "300,600",
        "--horizon",
        "20000",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("p_cold@300s"));
    assert!(text.contains("p_cold@600s"));
}

#[test]
fn emulate_writes_csv_trace() {
    let dir = std::env::temp_dir().join(format!("simfaas-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("trace.csv");
    let (ok, text) = simfaas(&[
        "emulate",
        "--rate",
        "1.0",
        "--horizon",
        "2000",
        "--scale",
        "4000",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cold start prob"));
    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.starts_with("arrived_at,outcome,response_time,instance_id"));
    assert!(content.lines().count() > 1000);

    // identify reads the trace back.
    let (ok, text) = simfaas(&["identify", "--trace", csv.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("arrival rate"));
    assert!(text.contains("warm mean"));
    std::fs::remove_dir_all(&dir).ok();
}

/// The telemetry loop: `steady --record-trace` emits the three export
/// files, and `inspect` recomputes §5.2-style estimates from the span
/// JSONL alone.
#[test]
fn record_trace_then_inspect_closes_the_loop() {
    let dir = std::env::temp_dir().join(format!("simfaas-telemetry-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("steady.jsonl");
    let (ok, text) = simfaas(&[
        "steady",
        "--horizon",
        "10000",
        "--seed",
        "2",
        "--record-trace",
        trace.to_str().unwrap(),
        "--metrics-interval",
        "60",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("telemetry:"), "{text}");
    assert!(trace.exists());
    assert!(dir.join("steady.perfetto.json").exists());
    assert!(dir.join("steady.metrics.csv").exists());
    let perfetto = std::fs::read_to_string(dir.join("steady.perfetto.json")).unwrap();
    assert!(perfetto.contains("\"traceEvents\":"), "{perfetto}");
    let metrics = std::fs::read_to_string(dir.join("steady.metrics.csv")).unwrap();
    assert!(metrics.starts_with("function,t,live,busy,idle"), "{metrics}");

    let (ok, text) = simfaas(&["inspect", trace.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("arrival rate"), "{text}");
    assert!(text.contains("cold start prob"), "{text}");
    assert!(text.contains("warm pool"), "{text}");

    let (ok, text) = simfaas(&["inspect", trace.to_str().unwrap(), "--json"]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"mean_warm_pool\":"), "{line}");
    assert!(line.contains("\"cold_start_prob\":"), "{line}");

    // A missing trace is a clean error naming the path.
    let (ok, text) = simfaas(&["inspect", "/nonexistent/trace.jsonl"]);
    assert!(!ok);
    assert!(text.contains("/nonexistent/trace.jsonl"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Telemetry flags flow through the fleet translator too, and are
/// rejected in comparison mode instead of being silently dropped.
#[test]
fn fleet_record_trace_exports_and_comparison_rejects_it() {
    let dir = std::env::temp_dir().join(format!("simfaas-fleet-tel-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("fleet.jsonl");
    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "3",
        "--horizon",
        "1500",
        "--skip",
        "0",
        "--threads",
        "2",
        "--record-trace",
        trace.to_str().unwrap(),
        "--metrics-interval",
        "120",
        "--json",
    ]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"telemetry\":"), "{line}");
    assert!(line.contains("\"perfetto_path\":"), "{line}");
    assert!(trace.exists());
    assert!(dir.join("fleet.perfetto.json").exists());

    let (ok, text) = simfaas(&[
        "fleet",
        "--functions",
        "2",
        "--horizon",
        "500",
        "--compare-thresholds",
        "60,600",
        "--record-trace",
        trace.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(text.contains("--record-trace"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compare_shows_model_gap_table() {
    let (ok, text) = simfaas(&[
        "compare",
        "--rate",
        "0.9",
        "--threshold",
        "120",
        "--horizon",
        "50000",
        "--markovian-expiration",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("cold_start_prob"));
    assert!(text.contains("avg_server_count"));
}

#[test]
fn cost_reports_monthly() {
    let (ok, text) =
        simfaas(&["cost", "--horizon", "20000", "--memory", "256", "--provider", "azure"]);
    assert!(ok, "{text}");
    assert!(text.contains("per 30 days"));
    assert!(text.contains("provider infra cost"));
}

#[test]
fn unknown_flag_fails_before_simulating() {
    // A typo'd flag must error without first burning a full
    // default-parameter run (steady's default horizon is 1e6 s).
    let (ok, text) = simfaas(&["steady", "--horizont", "1"]);
    assert!(!ok);
    assert!(text.contains("unknown flag"), "{text}");
    assert!(!text.contains("Cold Start Probability"), "{text}");
}

#[test]
fn stray_positional_fails_fast() {
    // `steady 5` (typo for `--rate 5`) must fail before any simulation
    // output, not after running a full default-parameter run.
    let (ok, text) = simfaas(&["steady", "5"]);
    assert!(!ok);
    assert!(text.contains("unexpected positional"), "{text}");
    assert!(!text.contains("Cold Start Probability"), "{text}");
    // Same for an extra operand after `run`'s scenario file.
    let (ok, text) = simfaas(&["run", "a.json", "b.json"]);
    assert!(!ok);
    assert!(text.contains("unexpected positional"), "{text}");
}

fn scenarios_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

/// The acceptance contract: `simfaas run` executes every bundled scenario
/// end to end (the CI workflow repeats this against the release binary).
#[test]
fn run_executes_all_bundled_scenarios() {
    let mut seen = 0;
    for entry in std::fs::read_dir(scenarios_dir()).expect("examples/scenarios exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let (ok, text) = simfaas(&["run", path.to_str().unwrap()]);
        assert!(ok, "{path:?} failed: {text}");
        assert!(!text.trim().is_empty(), "{path:?} produced no output");
        seen += 1;
    }
    assert!(seen >= 8, "expected the bundled scenario set, found {seen}");
}

/// `simfaas run` on a spec mirroring the `steady` translator defaults
/// prints byte-identical JSON to `steady --json` — the CLI-level
/// regression for the flags→spec rework.
#[test]
fn run_matches_steady_subcommand_output() {
    let dir = std::env::temp_dir().join(format!("simfaas-run-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = dir.join("steady_equiv.json");
    std::fs::write(
        &spec,
        r#"{"name":"equiv","run":{"horizon":20000,"seed":1},"experiment":{"type":"steady"},"output":{"format":"json"}}"#,
    )
    .unwrap();
    let (ok, via_run) = simfaas(&["run", spec.to_str().unwrap()]);
    assert!(ok, "{via_run}");
    let (ok, via_steady) = simfaas(&["steady", "--horizon", "20000", "--seed", "1", "--json"]);
    assert!(ok, "{via_steady}");
    assert_eq!(via_run, via_steady, "scenario file and flag path diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_print_spec_echoes_canonical_json() {
    let path = scenarios_dir().join("table1_steady.json");
    let (ok, text) = simfaas(&["run", path.to_str().unwrap(), "--print-spec"]);
    assert!(ok, "{text}");
    let line = text.lines().find(|l| l.starts_with('{')).expect("json line");
    assert!(line.contains("\"experiment\""), "{line}");
    assert!(line.contains("\"table1-steady\""), "{line}");
    // --print-spec must not run the simulation.
    assert!(!text.contains("Cold Start Probability"), "{text}");
}

#[test]
fn run_rejects_missing_and_malformed_specs() {
    let (ok, text) = simfaas(&["run"]);
    assert!(!ok);
    assert!(text.contains("usage: simfaas run"), "{text}");

    let (ok, text) = simfaas(&["run", "/nonexistent/scenario.json"]);
    assert!(!ok);
    assert!(text.contains("reading"), "{text}");

    let dir = std::env::temp_dir().join(format!("simfaas-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, r#"{"name":"x","experiment":{"type":"warp"}}"#).unwrap();
    let (ok, text) = simfaas(&["run", bad.to_str().unwrap()]);
    assert!(!ok);
    assert!(
        text.contains("steady|temporal|ensemble|sweep|compare|fleet"),
        "{text}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn figures_quick_subset_runs() {
    let dir = std::env::temp_dir().join(format!("simfaas-figs-{}", std::process::id()));
    let (ok, text) = simfaas(&[
        "figures",
        "--fig",
        "3",
        "--quick",
        "--out-dir",
        dir.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("Fig 3"));
    assert!(dir.join("fig3.csv").exists());
    std::fs::remove_dir_all(&dir).ok();
}
