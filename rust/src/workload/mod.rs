//! Workload substrate: every way requests enter the simulator.
//!
//! * [`generator`] — open-loop arrival generators (the equivalent of the
//!   paper's `pacswg` Poisson load generator): Poisson, deterministic,
//!   batch, MMPP, non-homogeneous thinning.
//! * [`azure`] — synthetic Azure-style multi-function traces (Shahrad et
//!   al. characteristics, tunable via [`SynthesisOptions`]).
//! * [`azure_dataset`] — reader for the real Azure Functions 2019 dataset
//!   (per-minute invocation counts + duration/memory percentiles), with
//!   line-numbered errors and top-K/slice/scale transforms.
//! * [`stream`] — the streaming arrival seam: [`ArrivalSource`] and the
//!   lazy thinning generator replacing eager arrival materialization.
//! * [`source`] — [`TraceSource`], the one typed seam (synthetic /
//!   ingested / explicit / recorded) every trace-driven experiment
//!   consumes, plus provenance and validation statistics.

pub mod azure;
pub mod azure_dataset;
pub mod generator;
pub mod source;
pub mod stream;

pub use azure::{FunctionProfile, SynthesisOptions, SyntheticTrace};
pub use azure_dataset::{AzureDataset, IngestedFunction};
pub use generator::{batch, deterministic, from_process, nonhomogeneous, poisson, Workload};
pub use source::{ArrivalMode, FunctionSpec, TraceProvenance, TraceSource, TraceStats};
pub use stream::{ArrivalSource, RateShape, StreamSpec, StreamingArrivals};
