//! Discrete-event engine: the future event list.
//!
//! A classic binary-heap future-event list with two SimFaaS-specific
//! features:
//!
//! * **Deterministic tie-breaking** — events at equal times pop in insertion
//!   order (a monotone sequence number), so runs are bit-reproducible.
//! * **Generation-tagged expiration events** — per the paper, each idle
//!   instance expires `expiration_threshold` seconds after its last request.
//!   Reusing the instance must cancel its pending expiration; instead of an
//!   O(n) heap removal we tag expiration events with the instance's
//!   *generation* counter and drop stale ones on pop (lazy cancellation).

use super::instance::InstanceId;
use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Events the serverless simulator reacts to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// A request arrives at the platform.
    Arrival,
    /// The request being processed on `InstanceId` completes.
    Departure(InstanceId),
    /// Provider-initiated prewarm trigger: start provisioning an instance
    /// ahead of a predicted arrival. Handled by [`crate::sim::core`] when a
    /// provisioning lead time is configured; the instance becomes warm one
    /// lead later via [`Event::ProvisioningDone`].
    Provision,
    /// Instance finished provisioning and joins the warm pool (scheduled by
    /// the prewarm path; lifecycle core only).
    ProvisioningDone(InstanceId),
    /// Idle-expiration check for an instance; `gen` guards staleness.
    Expiration { id: InstanceId, gen: u64 },
    /// The request running on `InstanceId` hit the fault profile's
    /// execution timeout with kill semantics: the execution is cut off and
    /// the instance torn down with it. Scheduled *instead of* the
    /// request's [`Event::Departure`] (never alongside it), so no
    /// generation guard is needed.
    RequestTimeout(InstanceId),
    /// A failed or timed-out request re-enters the platform after its
    /// backoff delay. `attempt` is the dispatch attempt this arrival makes
    /// (2 = first retry); `prev_delay_bits` carries the previous backoff
    /// delay as raw `f64` bits — the decorrelated-jitter state — so
    /// `Event` stays `Copy + Eq`.
    RetryArrival {
        /// Dispatch attempt number for this re-arrival (first attempt = 1).
        attempt: u32,
        /// Previous backoff delay, as `f64::to_bits`.
        prev_delay_bits: u64,
    },
    /// Degradation window `window` of the fault profile begins: effective
    /// capacity shrinks by its factor.
    DegradationStart {
        /// Index into [`crate::sim::FaultProfile::degradation`].
        window: u32,
    },
    /// Degradation window `window` of the fault profile ends.
    DegradationEnd {
        /// Index into [`crate::sim::FaultProfile::degradation`].
        window: u32,
    },
    /// End of simulation horizon.
    Horizon,
}

#[derive(Debug, Clone)]
struct Scheduled {
    at: SimTime,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse to get earliest-first, then
        // lowest-seq-first among equal times.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Future event list.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    /// Schedule `event` at absolute time `at`.
    #[inline]
    pub fn schedule(&mut self, at: SimTime, event: Event) {
        debug_assert!(at.is_finite(), "cannot schedule at infinity");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Pop the earliest event.
    #[inline]
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.heap.pop().map(|s| (s.at, s.event))
    }

    /// Time of the next event without popping.
    #[inline]
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.at)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(3.0), Event::Arrival);
        q.schedule(SimTime::from_secs(1.0), Event::Horizon);
        q.schedule(SimTime::from_secs(2.0), Event::Departure(InstanceId(7)));
        let (t1, e1) = q.pop().unwrap();
        let (t2, e2) = q.pop().unwrap();
        let (t3, e3) = q.pop().unwrap();
        assert_eq!((t1.as_secs(), e1), (1.0, Event::Horizon));
        assert_eq!((t2.as_secs(), e2), (2.0, Event::Departure(InstanceId(7))));
        assert_eq!((t3.as_secs(), e3), (3.0, Event::Arrival));
        assert!(q.pop().is_none());
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        let t = SimTime::from_secs(5.0);
        for i in 0..100 {
            q.schedule(t, Event::Departure(InstanceId(i)));
        }
        for i in 0..100 {
            let (_, e) = q.pop().unwrap();
            assert_eq!(e, Event::Departure(InstanceId(i)));
        }
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(1.5), Event::Arrival);
        assert_eq!(q.peek_time().unwrap().as_secs(), 1.5);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn interleaved_schedule_pop() {
        let mut q = EventQueue::new();
        q.schedule(SimTime::from_secs(10.0), Event::Arrival);
        q.schedule(SimTime::from_secs(5.0), Event::Arrival);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 5.0);
        q.schedule(SimTime::from_secs(7.0), Event::Horizon);
        let (t, e) = q.pop().unwrap();
        assert_eq!((t.as_secs(), e), (7.0, Event::Horizon));
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 10.0);
    }
}
