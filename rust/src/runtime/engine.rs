//! PJRT engine: load HLO-text artifacts and execute them.
//!
//! The pattern follows the verified reference in /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. HLO *text*
//! is the interchange format (serialized protos from jax ≥ 0.5 are rejected
//! by xla_extension 0.5.1 — see `python/compile/aot.py`).
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so an `Engine` is thread-bound;
//! multi-threaded consumers use [`super::pool::ComputePool`], which owns one
//! engine per worker thread.

use super::payload::{PayloadKind, HIST_ARTIFACT, HIST_N, HIST_NBINS};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A thread-bound PJRT execution engine over the AOT artifacts.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Engine {
    /// Load and compile every artifact in `dir` (per `manifest.txt`).
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.txt");
        let manifest = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?}; run `make artifacts` first"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for line in manifest.lines() {
            let name = match line.split_whitespace().next() {
                Some(n) => n,
                None => continue,
            };
            let path = dir.join(format!("{name}.hlo.txt"));
            let exe = Self::compile_file(&client, &path)
                .with_context(|| format!("compiling {path:?}"))?;
            executables.insert(name.to_string(), exe);
        }
        if executables.is_empty() {
            bail!("no artifacts found in {dir:?}");
        }
        Ok(Engine { client, executables, dir })
    }

    fn compile_file(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("unknown artifact {name:?}"))?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        // Lowered with return_tuple=True: single replica/partition, 1-tuple.
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple1()?)
    }

    /// Run an inference payload: `x` is the flattened f32 input of shape
    /// (batch, d_in); returns the flattened (batch, d_out) logits.
    pub fn run_payload(&self, kind: PayloadKind, x: &[f32]) -> Result<Vec<f32>> {
        let (batch, d_in, _) = kind.shape();
        if x.len() != batch * d_in {
            bail!("payload {kind:?} expects {} f32s, got {}", batch * d_in, x.len());
        }
        let lit = xla::Literal::vec1(x).reshape(&[batch as i64, d_in as i64])?;
        let out = self.execute(kind.artifact_name(), &[lit])?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run the histogram analysis graph over exactly [`HIST_N`] samples.
    pub fn run_histogram_block(&self, samples: &[f32], lo: f32, hi: f32) -> Result<Vec<f32>> {
        if samples.len() != HIST_N {
            bail!("histogram expects {HIST_N} samples, got {}", samples.len());
        }
        let x = xla::Literal::vec1(samples);
        let lo = xla::Literal::scalar(lo);
        let hi = xla::Literal::scalar(hi);
        let out = self.execute(HIST_ARTIFACT, &[x, lo, hi])?;
        let counts = out.to_vec::<f32>()?;
        debug_assert_eq!(counts.len(), HIST_NBINS);
        Ok(counts)
    }

    /// Histogram over arbitrarily many samples: chunks into [`HIST_N`]
    /// blocks (padding the tail with out-of-range sentinels) and sums the
    /// per-block counts. This is the accelerated backend for
    /// `sim::hist::Histogram` on multi-million-sample traces.
    pub fn run_histogram(&self, samples: &[f32], lo: f32, hi: f32) -> Result<Vec<f64>> {
        let mut counts = vec![0.0f64; HIST_NBINS];
        let sentinel = hi + 1.0;
        let mut block = vec![sentinel; HIST_N];
        for chunk in samples.chunks(HIST_N) {
            block[..chunk.len()].copy_from_slice(chunk);
            block[chunk.len()..].fill(sentinel);
            let partial = self.run_histogram_block(&block, lo, hi)?;
            for (acc, p) in counts.iter_mut().zip(partial) {
                *acc += p as f64;
            }
        }
        Ok(counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Engine {
        Engine::load_dir(artifacts_dir()).expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn loads_all_artifacts() {
        let e = engine();
        for k in PayloadKind::ALL {
            assert!(e.has(k.artifact_name()));
        }
        assert!(e.has(HIST_ARTIFACT));
    }

    #[test]
    fn payload_executes_and_is_deterministic() {
        let e = engine();
        let k = PayloadKind::Small;
        let x = vec![0.5f32; k.input_len()];
        let a = e.run_payload(k, &x).unwrap();
        let b = e.run_payload(k, &x).unwrap();
        assert_eq!(a.len(), k.output_len());
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
        // Not all zeros (weights baked in).
        assert!(a.iter().any(|v| v.abs() > 1e-6));
    }

    #[test]
    fn payload_rejects_bad_input_len() {
        let e = engine();
        assert!(e.run_payload(PayloadKind::Small, &[0.0; 3]).is_err());
    }

    #[test]
    fn histogram_matches_rust_reference() {
        let e = engine();
        let mut rng = crate::sim::Rng::new(42);
        let samples: Vec<f32> = (0..300_000).map(|_| rng.exponential(1.0) as f32).collect();
        let counts = e.run_histogram(&samples, 0.0, 8.0).unwrap();
        // Pure-rust reference.
        let mut h = crate::sim::Histogram::new(0.0, 8.0, HIST_NBINS);
        for &s in &samples {
            h.push(s as f64);
        }
        let expect: Vec<f64> = h.counts().iter().map(|&c| c as f64).collect();
        assert_eq!(counts, expect);
    }

    #[test]
    fn histogram_empty_input_gives_zero_counts() {
        let e = engine();
        let counts = e.run_histogram(&[], 0.0, 1.0).unwrap();
        assert_eq!(counts, vec![0.0; HIST_NBINS]);
    }
}
