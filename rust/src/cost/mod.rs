//! Cost engine (paper §4.4 "Cost Calculation").
//!
//! All charges incurred by serverless functions are either **per-request**
//! charges or **runtime** charges billed on execution time and memory
//! (GB-s). Developer cost needs the request rate, cold-start probability
//! and average running-server count that the simulator predicts; the
//! provider's infrastructure cost is linearly proportional to the *total*
//! server count (busy + idle), which the simulator also reports.

pub mod pricing;

pub use pricing::{PricingTable, Provider};

use crate::sim::SimResults;

/// A function's billing-relevant configuration.
#[derive(Debug, Clone, Copy)]
pub struct FunctionConfig {
    /// Allocated memory in MB (AWS Lambda bills GB-s of allocated memory).
    pub memory_mb: f64,
    /// Average per-request charge from external APIs/services (USD), on top
    /// of the platform's own per-request fee.
    pub external_per_request: f64,
}

impl FunctionConfig {
    pub fn new(memory_mb: f64) -> Self {
        FunctionConfig { memory_mb, external_per_request: 0.0 }
    }
}

/// Cost estimate over a time window.
#[derive(Debug, Clone, Copy)]
pub struct CostEstimate {
    /// Window length in seconds.
    pub window: f64,
    /// Requests billed.
    pub requests: f64,
    /// Billed GB-seconds.
    pub gb_seconds: f64,
    /// Developer: per-request platform + external charges (USD).
    pub request_charges: f64,
    /// Developer: runtime (GB-s) charges (USD).
    pub runtime_charges: f64,
    /// Provider: infrastructure cost ∝ total server count (USD,
    /// at `PricingTable::infra_cost_per_instance_hour`).
    pub provider_infra_cost: f64,
}

impl CostEstimate {
    /// An empty estimate over `window` (the additive identity for
    /// [`CostEstimate::accumulate`]; used by fleet cost rollups).
    pub fn zero(window: f64) -> Self {
        CostEstimate {
            window,
            requests: 0.0,
            gb_seconds: 0.0,
            request_charges: 0.0,
            runtime_charges: 0.0,
            provider_infra_cost: 0.0,
        }
    }

    /// Add another estimate over the same window (fleet totals are the sum
    /// of per-function estimates; every charge component is linear).
    pub fn accumulate(&mut self, other: &CostEstimate) {
        debug_assert!(
            (self.window - other.window).abs() < 1e-6,
            "accumulating estimates over different windows"
        );
        self.requests += other.requests;
        self.gb_seconds += other.gb_seconds;
        self.request_charges += other.request_charges;
        self.runtime_charges += other.runtime_charges;
        self.provider_infra_cost += other.provider_infra_cost;
    }

    pub fn developer_total(&self) -> f64 {
        self.request_charges + self.runtime_charges
    }

    /// Provider margin proxy: developer revenue minus infra cost.
    pub fn provider_margin(&self) -> f64 {
        self.runtime_charges + self.request_charges - self.provider_infra_cost
    }
}

/// Estimate costs from simulation results.
///
/// Runtime charges derive from `billed_instance_seconds` (busy time ×
/// memory); provider infrastructure cost derives from the average *total*
/// server count over the window.
pub fn estimate(
    results: &SimResults,
    cfg: &FunctionConfig,
    pricing: &PricingTable,
) -> CostEstimate {
    let window = results.measured_time;
    let served = (results.cold_requests + results.warm_requests) as f64;
    let gb = cfg.memory_mb / 1024.0;
    let gb_seconds = results.billed_instance_seconds * gb;
    let request_charges = served * (pricing.per_request + cfg.external_per_request);
    let runtime_charges = gb_seconds * pricing.per_gb_second;
    let instance_hours = results.avg_server_count * window / 3600.0;
    // Provider provisions a full instance regardless of busy/idle.
    let provider_infra_cost = instance_hours * pricing.infra_cost_per_instance_hour * gb;
    CostEstimate {
        window,
        requests: served,
        gb_seconds,
        request_charges,
        runtime_charges,
        provider_infra_cost,
    }
}

/// Scale an estimate to a different window (e.g. report per-month).
pub fn scale_to(est: &CostEstimate, window: f64) -> CostEstimate {
    let k = window / est.window;
    CostEstimate {
        window,
        requests: est.requests * k,
        gb_seconds: est.gb_seconds * k,
        request_charges: est.request_charges * k,
        runtime_charges: est.runtime_charges * k,
        provider_infra_cost: est.provider_infra_cost * k,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{ServerlessSimulator, SimConfig};

    #[test]
    fn cost_estimate_matches_hand_calculation() {
        let mut cfg = SimConfig::table1();
        cfg.horizon = 50_000.0;
        let results = ServerlessSimulator::new(cfg).run();
        let f = FunctionConfig::new(128.0);
        let pricing = PricingTable::aws_lambda();
        let est = estimate(&results, &f, &pricing);

        let served = (results.cold_requests + results.warm_requests) as f64;
        assert!((est.requests - served).abs() < 1e-9);
        let expect_gbs = results.billed_instance_seconds * 128.0 / 1024.0;
        assert!((est.gb_seconds - expect_gbs).abs() < 1e-9);
        assert!(est.runtime_charges > 0.0);
        assert!(est.request_charges > 0.0);
        assert!(est.provider_infra_cost > 0.0);
        // Billed busy time ~ lambda * E[S] * window * gb
        let rough = 0.9 * 1.9915 * results.measured_time * (128.0 / 1024.0);
        assert!((est.gb_seconds - rough).abs() / rough < 0.05);
    }

    #[test]
    fn monthly_scaling_linear() {
        let mut cfg = SimConfig::table1();
        cfg.horizon = 20_000.0;
        let results = ServerlessSimulator::new(cfg).run();
        let est = estimate(&results, &FunctionConfig::new(256.0), &PricingTable::aws_lambda());
        let month = scale_to(&est, 30.0 * 86_400.0);
        let k = month.window / est.window;
        assert!((month.runtime_charges - est.runtime_charges * k).abs() < 1e-9);
        assert!((month.developer_total() - est.developer_total() * k).abs() < 1e-9);
    }

    #[test]
    fn external_charges_add_per_request() {
        let mut cfg = SimConfig::table1();
        cfg.horizon = 20_000.0;
        let results = ServerlessSimulator::new(cfg).run();
        let mut f = FunctionConfig::new(128.0);
        let base = estimate(&results, &f, &PricingTable::aws_lambda());
        f.external_per_request = 1e-4;
        let with_ext = estimate(&results, &f, &PricingTable::aws_lambda());
        let delta = with_ext.request_charges - base.request_charges;
        assert!((delta - with_ext.requests * 1e-4).abs() < 1e-9);
    }

    #[test]
    fn providers_have_distinct_tables() {
        let aws = PricingTable::aws_lambda();
        let gcf = PricingTable::google_cloud_functions();
        let az = PricingTable::azure_functions();
        assert!(aws.per_gb_second > 0.0 && gcf.per_gb_second > 0.0 && az.per_gb_second > 0.0);
        assert!(aws.per_request != gcf.per_request || aws.per_gb_second != gcf.per_gb_second);
    }
}
