//! The SimFaaS discrete-event simulation core.
//!
//! Mirrors the paper's package diagram (Fig. 2): [`process`] is
//! `SimProcess`, [`instance`] is `FunctionInstance`, [`simulator`] is
//! `ServerlessSimulator`, [`temporal`] is `ServerlessTemporalSimulator`,
//! and [`metrics`]/[`hist`] are the `Utility` helpers. [`par_simulator`] is
//! the `ParServerlessSimulator` extension (§3.1). Beyond the paper,
//! [`self::core`] is the single lifecycle engine every simulator (including the
//! fleet's per-function engines) is a configuration of, [`ensemble`] is
//! the deterministic multi-threaded replication engine and
//! [`process::Process`] the monomorphic hot-path dispatch (DESIGN.md
//! §Perf).

pub mod arena;
pub mod calendar;
pub mod core;
pub mod ensemble;
pub mod event;
pub mod fault;
pub mod hist;
pub mod instance;
pub mod metrics;
pub mod par_simulator;
pub mod process;
pub mod results;
pub mod retry;
pub mod rng;
pub mod simulator;
pub mod temporal;
pub mod time;

pub use self::core::{ConfigExpiration, CoreParams, EngineCore, LifecycleHooks, Scheduler};
pub use ensemble::{
    derive_seeds, run_ensemble, run_indexed, run_par_ensemble, EnsembleOpts, EnsembleResults,
    EnsembleSummary, MetricCi,
};
pub use arena::InstanceArena;
pub use calendar::CalendarQueue;
pub use event::{CalendarEventQueue, Event, EventQueue, HeapEventQueue};
pub use fault::{DegradationWindow, FaultProfile, TimeoutAction};
pub use hist::{CountDistribution, Histogram};
pub use instance::{FunctionInstance, InstanceId, InstanceState};
pub use metrics::{confidence_interval_95, ks_distance, mape, OnlineStats, P2Quantile, TimeWeighted};
pub use par_simulator::ParServerlessSimulator;
pub use process::{
    ConstProcess, EmpiricalProcess, ExpProcess, GammaProcess, GaussianProcess,
    LogNormalProcess, MmppProcess, ParetoProcess, Process, SimProcess, WeibullProcess,
};
pub use results::SimResults;
pub use retry::{Backoff, RetryPolicy};
pub use rng::Rng;
pub use simulator::{
    CountSample, RequestLogEntry, RequestOutcome, ServerlessSimulator, SimConfig,
};
pub use temporal::{InitialState, ServerlessTemporalSimulator, TemporalResults};
pub use time::SimTime;
