//! Ablation: quantify the Markovian-expiration approximation error that
//! motivates SimFaaS (DESIGN.md §6 "Analytical baseline is first-class").
//!
//! The prior-work models (Mahmoudi & Khazaei 2020a/b) must approximate the
//! platform's *deterministic* idle-expiration threshold with an exponential
//! clock. This test shows:
//!  (a) when the simulator is forced to use exponential expiration too, the
//!      two implementations agree (cross-validation of both); and
//!  (b) against the true deterministic threshold, the Markovian model's
//!      cold-start estimate degrades while the simulator is exact by
//!      construction — the gap the paper's simulator closes.

use simfaas::analytical::SteadyStateModel;
use simfaas::sim::{Process, ServerlessSimulator, SimConfig};

fn base_cfg(threshold: f64, horizon: f64) -> SimConfig {
    SimConfig {
        arrival: Process::exp_rate(0.9),
        batch_size: None,
        warm_service: Process::exp_mean(1.991),
        cold_service: Process::exp_mean(1.991),
        expiration_threshold: threshold,
        expiration_process: None,
        max_concurrency: 1000,
        horizon,
        skip_initial: 500.0,
        seed: 1234,
        capture_request_log: false,
        sample_interval: 0.0,
        fault: simfaas::sim::FaultProfile::disabled(),
        retry: simfaas::sim::RetryPolicy::none(),
    }
}

#[test]
fn markovian_simulator_and_ctmc_agree_under_exponential_expiration() {
    let threshold = 120.0;
    let mut cfg = base_cfg(threshold, 400_000.0);
    cfg.expiration_process = Some(Process::exp_mean(threshold));
    let sim = ServerlessSimulator::new(cfg).run();
    let model = SteadyStateModel::new(0.9, 1.991, threshold).solve();

    let pct = |a: f64, b: f64| 100.0 * ((a - b) / b).abs();
    assert!(
        pct(model.avg_server_count, sim.avg_server_count) < 3.0,
        "servers: model {} sim {}",
        model.avg_server_count,
        sim.avg_server_count
    );
    assert!(
        pct(model.cold_start_prob, sim.cold_start_prob) < 12.0,
        "p_cold: model {} sim {}",
        model.cold_start_prob,
        sim.cold_start_prob
    );
    assert!(pct(model.avg_running_count, sim.avg_running_count) < 3.0);
}

#[test]
fn deterministic_threshold_breaks_the_markovian_approximation() {
    // With the real deterministic threshold, exponential-expiration CTMCs
    // overestimate cold starts (an exponential clock sometimes fires far
    // too early, killing instances that a deterministic platform would
    // have kept). The simulator handles the deterministic rule natively.
    let threshold = 120.0;
    let sim_det = ServerlessSimulator::new(base_cfg(threshold, 400_000.0)).run();
    let model = SteadyStateModel::new(0.9, 1.991, threshold).solve();

    let model_err =
        100.0 * ((model.cold_start_prob - sim_det.cold_start_prob) / sim_det.cold_start_prob).abs();
    assert!(
        model_err > 15.0,
        "expected a visible Markovian gap, got {model_err:.1}% \
         (model {} vs deterministic-threshold sim {})",
        model.cold_start_prob,
        sim_det.cold_start_prob
    );

    // And the direction is as predicted: exp-expiration kills more warm
    // instances -> more cold starts.
    assert!(model.cold_start_prob > sim_det.cold_start_prob);
}

#[test]
fn transient_model_and_temporal_simulator_agree_in_markovian_regime() {
    use simfaas::analytical::TransientModel;
    use simfaas::sim::{InitialState, ServerlessTemporalSimulator};

    let threshold = 60.0;
    let model = SteadyStateModel::new(0.9, 1.991, threshold);
    let tm = TransientModel::new(model);
    let init = tm.point_initial(0, 0);
    let at = tm.evaluate(&init, &[300.0])[0];

    let mut cfg = base_cfg(threshold, 300.0);
    cfg.skip_initial = 0.0;
    cfg.expiration_process = Some(Process::exp_mean(threshold));
    cfg.sample_interval = 300.0;
    let res = ServerlessTemporalSimulator::new(cfg, InitialState::empty(), 24).run();

    // Compare the *instantaneous* pool size at t=300 (CTMC) against the
    // replicated simulator's final sample.
    let finals: Vec<f64> = res
        .sample_series
        .iter()
        .filter_map(|s| s.last().map(|c| c.count))
        .collect();
    let sim_mean = finals.iter().sum::<f64>() / finals.len() as f64;
    let err = (at.avg_server_count - sim_mean).abs() / sim_mean.max(0.5);
    assert!(
        err < 0.25,
        "transient pool: model {} vs sim {} (err {:.0}%)",
        at.avg_server_count,
        sim_mean,
        err * 100.0
    );
}
