//! Exporters: JSONL span streams, CSV time-series, and Chrome
//! trace-event JSON (the format `ui.perfetto.dev` and `chrome://tracing`
//! open directly).
//!
//! All three are deterministic byte-for-byte: JSON objects serialize with
//! sorted keys through [`JsonValue`], floats use Rust's shortest
//! round-trip formatting, and records are written in per-function emission
//! order (the fleet merges per-function buffers in function order, so the
//! bytes are independent of the shard/thread count).

use super::recorder::TelemetryRecorder;
use super::span::{SpanOutcome, SpanRecord, SpanVerdict, StateSample};
use crate::control::ControlSample;
use crate::output::json::JsonValue;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, Write};

/// Header of the internal-state time-series CSV.
pub const SAMPLES_CSV_HEADER: &str = "function,t,live,busy,idle,in_flight,total_requests,\
cold_requests,warm_requests,cold_start_rate,degradation_active,cap_headroom";

/// Header of the autoscaling control-tick CSV.
pub const CONTROL_CSV_HEADER: &str = "domain,t,observed,error,actuation,capacity";

/// Serialize one span as a JSON object (sorted keys, compact).
pub fn span_to_json(s: &SpanRecord) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("attempt", u64::from(s.attempt))
        .set("function", u64::from(s.function))
        .set("instance", s.instance.map(JsonValue::from).unwrap_or(JsonValue::Null))
        .set("outcome", s.outcome.as_str())
        .set("queued_at", s.queued_at)
        .set("response_time", s.response_time)
        .set("started_at", s.started_at)
        .set("verdict", s.verdict.as_str());
    o
}

/// Parse one span back from its JSON object form.
pub fn span_from_json(v: &JsonValue) -> Result<SpanRecord> {
    let u32_field = |key: &str| -> Result<u32> {
        let n = v.get(key).and_then(JsonValue::as_u64).with_context(|| {
            format!("span record needs an unsigned integer {key:?} field")
        })?;
        u32::try_from(n).with_context(|| format!("span {key:?} field out of range"))
    };
    let f64_field = |key: &str| -> Result<f64> {
        v.get(key)
            .and_then(JsonValue::as_f64)
            .with_context(|| format!("span record needs a numeric {key:?} field"))
    };
    let outcome_text = v
        .get("outcome")
        .and_then(JsonValue::as_str)
        .context("span record needs a string \"outcome\" field")?;
    let outcome =
        SpanOutcome::parse(outcome_text).with_context(|| format!("unknown outcome {outcome_text:?}"))?;
    let verdict_text = v
        .get("verdict")
        .and_then(JsonValue::as_str)
        .context("span record needs a string \"verdict\" field")?;
    let verdict =
        SpanVerdict::parse(verdict_text).with_context(|| format!("unknown verdict {verdict_text:?}"))?;
    let instance = match v.get("instance") {
        None | Some(JsonValue::Null) => None,
        Some(other) => {
            Some(other.as_u64().context("span \"instance\" field must be an integer or null")?)
        }
    };
    Ok(SpanRecord {
        function: u32_field("function")?,
        queued_at: f64_field("queued_at")?,
        started_at: f64_field("started_at")?,
        response_time: f64_field("response_time")?,
        outcome,
        verdict,
        instance,
        attempt: u32_field("attempt")?,
    })
}

/// Write spans as JSONL (one sorted-key JSON object per line).
pub fn write_spans_jsonl<W: Write>(w: &mut W, spans: &[SpanRecord]) -> std::io::Result<()> {
    for s in spans {
        writeln!(w, "{}", span_to_json(s))?;
    }
    Ok(())
}

/// Read a span JSONL stream back (inverse of [`write_spans_jsonl`];
/// blank lines are skipped, errors carry the line number).
pub fn read_spans_jsonl<R: BufRead>(r: R) -> Result<Vec<SpanRecord>> {
    let mut spans = Vec::new();
    for (i, line) in r.lines().enumerate() {
        let line = line.with_context(|| format!("line {}: read error", i + 1))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = JsonValue::parse(&line).with_context(|| format!("line {}: bad JSON", i + 1))?;
        spans.push(span_from_json(&v).with_context(|| format!("line {}: bad span", i + 1))?);
    }
    Ok(spans)
}

/// Write the internal-state time-series as CSV (header +
/// `{:.6}`-formatted floats; `cap_headroom` is empty when uncapped).
pub fn write_samples_csv<W: Write>(w: &mut W, samples: &[StateSample]) -> std::io::Result<()> {
    writeln!(w, "{SAMPLES_CSV_HEADER}")?;
    for s in samples {
        let headroom = match s.cap_headroom {
            Some(h) => h.to_string(),
            None => String::new(),
        };
        writeln!(
            w,
            "{},{:.6},{},{},{},{},{},{},{},{:.6},{},{}",
            s.function,
            s.t,
            s.live_instances,
            s.busy_instances,
            s.idle_instances,
            s.in_flight,
            s.total_requests,
            s.cold_requests,
            s.warm_requests,
            s.cold_start_rate(),
            s.degradation_active,
            headroom,
        )?;
    }
    Ok(())
}

/// Write autoscaling control-tick records as CSV (header +
/// `{:.6}`-formatted floats). Samples arrive concatenated in domain
/// order from the fleet run loops, so the bytes are independent of the
/// shard/thread count.
pub fn write_control_csv<W: Write>(w: &mut W, samples: &[ControlSample]) -> std::io::Result<()> {
    writeln!(w, "{CONTROL_CSV_HEADER}")?;
    for s in samples {
        writeln!(
            w,
            "{},{:.6},{:.6},{:.6},{},{}",
            s.domain, s.t, s.observed, s.error, s.actuation, s.capacity,
        )?;
    }
    Ok(())
}

/// Build a Chrome trace-event document (the JSON Perfetto opens directly):
/// one process per function (named via metadata events), one track per
/// instance, an `"X"` complete event per span, and `"C"` counter tracks
/// for the sampled instance/in-flight levels. Timestamps are simulation
/// microseconds; within each `(pid, phase)` pair they are nondecreasing by
/// construction (records are emitted in event order).
pub fn chrome_trace(recorders: &[TelemetryRecorder], names: &[String]) -> JsonValue {
    let mut events: Vec<JsonValue> = Vec::new();
    for (i, rec) in recorders.iter().enumerate() {
        let name = names.get(i).map(String::as_str).unwrap_or("function");
        let mut meta = JsonValue::object();
        let mut margs = JsonValue::object();
        margs.set("name", name);
        meta.set("args", margs)
            .set("name", "process_name")
            .set("ph", "M")
            .set("pid", i)
            .set("tid", 0u64);
        events.push(meta);
        for s in &rec.spans {
            let mut args = JsonValue::object();
            args.set("attempt", u64::from(s.attempt))
                .set("queued_at", s.queued_at)
                .set("verdict", s.verdict.as_str());
            let mut e = JsonValue::object();
            e.set("args", args)
                .set("cat", "request")
                .set("dur", s.response_time * 1e6)
                .set("name", s.outcome.as_str())
                .set("ph", "X")
                .set("pid", u64::from(s.function))
                // Track 0 carries requests that never reached an instance.
                .set("tid", s.instance.map(|id| id + 1).unwrap_or(0))
                .set("ts", s.started_at * 1e6);
            events.push(e);
        }
        for s in &rec.samples {
            let mut args = JsonValue::object();
            args.set("busy", s.busy_instances).set("idle", s.idle_instances);
            let mut e = JsonValue::object();
            e.set("args", args)
                .set("name", "instances")
                .set("ph", "C")
                .set("pid", u64::from(s.function))
                .set("tid", 0u64)
                .set("ts", s.t * 1e6);
            events.push(e);
            let mut args = JsonValue::object();
            args.set("in_flight", s.in_flight);
            let mut e = JsonValue::object();
            e.set("args", args)
                .set("name", "in_flight")
                .set("ph", "C")
                .set("pid", u64::from(s.function))
                .set("tid", 0u64)
                .set("ts", s.t * 1e6);
            events.push(e);
        }
    }
    let mut doc = JsonValue::object();
    doc.set("displayTimeUnit", "ms").set("traceEvents", JsonValue::Array(events));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_span(attempt: u32) -> SpanRecord {
        SpanRecord {
            function: 2,
            queued_at: 9.5,
            started_at: 10.0,
            response_time: 0.25,
            outcome: SpanOutcome::Warm,
            verdict: SpanVerdict::Ok,
            instance: Some(7),
            attempt,
        }
    }

    fn sample_state() -> StateSample {
        StateSample {
            function: 2,
            t: 60.0,
            live_instances: 4,
            busy_instances: 1,
            idle_instances: 3,
            in_flight: 1,
            total_requests: 100,
            cold_requests: 5,
            warm_requests: 90,
            degradation_active: 0,
            cap_headroom: Some(996),
        }
    }

    #[test]
    fn span_jsonl_roundtrips_every_variant() {
        let spans = vec![
            sample_span(1),
            SpanRecord {
                outcome: SpanOutcome::Rejected,
                verdict: SpanVerdict::Ok,
                instance: None,
                response_time: 0.0,
                ..sample_span(2)
            },
            SpanRecord {
                outcome: SpanOutcome::ColdStartFailed,
                verdict: SpanVerdict::Failed,
                instance: None,
                response_time: 0.0,
                ..sample_span(3)
            },
            SpanRecord {
                outcome: SpanOutcome::Cold,
                verdict: SpanVerdict::Timeout,
                ..sample_span(1)
            },
        ];
        let mut bytes = Vec::new();
        write_spans_jsonl(&mut bytes, &spans).unwrap();
        let text = String::from_utf8(bytes).unwrap();
        assert_eq!(text.lines().count(), spans.len());
        let back = read_spans_jsonl(text.as_bytes()).unwrap();
        assert_eq!(back, spans);
    }

    #[test]
    fn jsonl_reader_rejects_bad_lines() {
        assert!(read_spans_jsonl("not json\n".as_bytes()).is_err());
        assert!(read_spans_jsonl("{\"attempt\":1}\n".as_bytes()).is_err());
        let bad_outcome = span_to_json(&sample_span(1)).to_string().replace("warm", "tepid");
        assert!(read_spans_jsonl(bad_outcome.as_bytes()).is_err());
    }

    #[test]
    fn samples_csv_has_header_and_rates() {
        let mut bytes = Vec::new();
        write_samples_csv(&mut bytes, &[sample_state()]).unwrap();
        let text = String::from_utf8(bytes).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some(SAMPLES_CSV_HEADER));
        let row = lines.next().unwrap();
        // cold_start_rate = 5 / 95.
        assert_eq!(row, "2,60.000000,4,1,3,1,100,5,90,0.052632,0,996");
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn control_csv_has_header_and_rows() {
        let samples = vec![
            ControlSample {
                domain: 0,
                t: 30.0,
                observed: 0.85,
                error: 0.15,
                actuation: 2,
                capacity: 10,
            },
            ControlSample {
                domain: 1,
                t: 30.0,
                observed: 0.4,
                error: -0.3,
                actuation: -1,
                capacity: 3,
            },
        ];
        let mut bytes = Vec::new();
        write_control_csv(&mut bytes, &samples).unwrap();
        let text = String::from_utf8(bytes).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next(), Some(CONTROL_CSV_HEADER));
        assert_eq!(lines.next(), Some("0,30.000000,0.850000,0.150000,2,10"));
        assert_eq!(lines.next(), Some("1,30.000000,0.400000,-0.300000,-1,3"));
        assert_eq!(lines.next(), None);
    }

    #[test]
    fn chrome_trace_emits_metadata_spans_and_counters() {
        let rec = TelemetryRecorder {
            spans: vec![sample_span(1)],
            samples: vec![sample_state()],
        };
        let doc = chrome_trace(&[rec], &["fn-a".to_string()]);
        let events = doc.get("traceEvents").and_then(JsonValue::as_array).unwrap();
        // 1 metadata + 1 span + 2 counters.
        assert_eq!(events.len(), 4);
        let phases: Vec<&str> =
            events.iter().map(|e| e.get("ph").and_then(JsonValue::as_str).unwrap()).collect();
        assert_eq!(phases, ["M", "X", "C", "C"]);
        let span = &events[1];
        assert_eq!(span.get("ts").and_then(JsonValue::as_f64), Some(10.0 * 1e6));
        assert_eq!(span.get("dur").and_then(JsonValue::as_f64), Some(0.25 * 1e6));
        assert_eq!(span.get("tid").and_then(JsonValue::as_u64), Some(8));
        assert_eq!(span.get("name").and_then(JsonValue::as_str), Some("warm"));
    }
}
