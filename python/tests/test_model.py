"""Layer-2 correctness: payload/analysis entry points (shapes, determinism,
and agreement with the un-jitted reference composition)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


class TestPayloads:
    def test_all_variants_produce_logits(self):
        for name, (batch, d_in, _, d_out) in model.PAYLOAD_SHAPES.items():
            fn, (spec,) = model.make_payload(name)
            assert spec.shape == (batch, d_in)
            x = jnp.ones(spec.shape, spec.dtype)
            (out,) = fn(x)
            assert out.shape == (batch, d_out), name
            assert bool(jnp.isfinite(out).all()), name

    def test_payload_matches_reference_composition(self):
        fn, (spec,) = model.make_payload("small")
        x = jax.random.normal(jax.random.PRNGKey(3), spec.shape, spec.dtype)
        (got,) = fn(x)
        w1, b1, w2, b2 = model.make_weights("small")
        want = ref.mlp_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_weights_deterministic(self):
        a = model.make_weights("medium")
        b = model.make_weights("medium")
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_variants_have_increasing_flops(self):
        # FLOPs ~ batch * (d_in*d_h + d_h*d_out): the service-time knob.
        def flops(name):
            batch, d_in, d_h, d_out = model.PAYLOAD_SHAPES[name]
            return batch * (d_in * d_h + d_h * d_out)

        assert flops("small") < flops("medium") < flops("large")


class TestTraceHistogram:
    def test_matches_reference(self):
        fn, (spec, _, _) = model.make_trace_histogram()
        x = jax.random.exponential(jax.random.PRNGKey(4), spec.shape).astype(jnp.float32)
        lo, hi = jnp.float32(0.0), jnp.float32(10.0)
        (got,) = fn(x, lo, hi)
        want = ref.histogram_ref(x, lo, hi, model.HIST_NBINS)
        np.testing.assert_allclose(got, want)
        assert got.shape == (model.HIST_NBINS,)

    def test_entry_points_registry_complete(self):
        assert set(model.ENTRY_POINTS) == {
            "payload_small",
            "payload_medium",
            "payload_large",
            "trace_histogram",
        }
        for name, (fn, args) in model.ENTRY_POINTS.items():
            assert callable(fn), name
            assert len(args) >= 1, name
