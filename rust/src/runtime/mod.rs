//! PJRT runtime bridge: loads the `artifacts/*.hlo.txt` produced by
//! `python/compile/aot.py` (Layer 2 lowering of the Layer-1 Pallas kernels)
//! and executes them from Rust. Python never runs on the request path.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod payload;
#[cfg(feature = "pjrt")]
pub mod pool;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use payload::{PayloadKind, HIST_ARTIFACT, HIST_N, HIST_NBINS};
#[cfg(feature = "pjrt")]
pub use pool::ComputePool;
// Without the `pjrt` feature (and the vendored `xla` bindings it needs)
// the runtime substitutes API-compatible stubs that fail at call time, so
// every compile-time consumer — CLI, emulator, benches, examples — builds
// and degrades gracefully.
#[cfg(not(feature = "pjrt"))]
pub use stub::{ComputePool, Engine};

use std::path::PathBuf;

/// Default artifacts directory: `$SIMFAAS_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SIMFAAS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}
