//! Bench: regenerate Fig. 5 (cold-start probability vs arrival rate for
//! several expiration thresholds — the what-if analysis showcase).
#[path = "harness.rs"]
mod harness;

use simfaas::figures;

fn main() {
    harness::header(
        "Fig 5",
        "P(cold) vs arrival rate x expiration threshold (what-if sweep)",
        "monotone decreasing in both rate and threshold; order-of-magnitude spread",
    );
    let rates = [0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.2, 1.5, 2.0, 2.5, 3.0];
    let thresholds = [120.0, 300.0, 600.0, 1200.0];
    let horizon = if harness::quick() { 5e4 } else { 3e5 };
    let (_, out) = harness::bench("fig5/44_point_sweep", 1, || {
        figures::fig5_sweep(&rates, &thresholds, horizon, 0x5EED)
    });
    println!();
    print!("rate    ");
    for (th, _) in &out {
        print!("  p@{th:>6}s");
    }
    println!();
    for (i, r) in rates.iter().enumerate() {
        print!("{r:<8.2}");
        for (_, s) in &out {
            print!("  {:>8.4}%", s[i].1 * 100.0);
        }
        println!();
    }
    // Shape checks the paper's figure exhibits.
    for w in out.windows(2) {
        let (short, long) = (&w[0].1, &w[1].1);
        let violations = short.iter().zip(long).filter(|(a, b)| b.1 > a.1).count();
        assert!(violations <= 2, "longer threshold should lower P(cold) almost everywhere");
    }
    println!("shape OK: P(cold) decreases with expiration threshold at every rate");
}
