//! # SimFaaS — a performance simulator for serverless computing platforms
//!
//! Rust + JAX + Pallas reproduction of *SimFaaS: A Performance Simulator for
//! Serverless Computing Platforms* (Mahmoudi & Khazaei, 2021).
//!
//! The crate is organized as the paper's system plus every substrate it
//! depends on:
//!
//! * [`sim`] — the discrete-event simulation core (`ServerlessSimulator`,
//!   `ServerlessTemporalSimulator`, `ParServerlessSimulator`, the
//!   `SimProcess` family, metrics and PDF/CDF tools).
//! * [`analytical`] — the Markovian performance models (Mahmoudi & Khazaei
//!   2020a/b) that SimFaaS supersedes; used as the cross-validation
//!   baseline.
//! * [`emulator`] — a tokio-based scale-per-request platform emulator with a
//!   real concurrent request path (the stand-in for the paper's AWS Lambda
//!   testbed); function bodies execute AOT-compiled JAX/Pallas payloads via
//!   PJRT.
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` produced by
//!   `python/compile/aot.py` and executes them from Rust.
//! * [`workload`] — the workload layer: open-loop generators (Poisson,
//!   deterministic, batch, MMPP), synthetic Azure-style traces, real Azure
//!   Functions 2019 dataset ingestion, and the `TraceSource` /
//!   `ArrivalSource` seams every engine pulls arrivals through.
//! * [`trace`] — request/instance trace records, CSV I/O, and parameter
//!   identification (expiration-threshold probing, service-time fitting).
//! * [`cost`] — provider pricing tables and developer/provider cost
//!   estimation.
//! * [`cluster`] — the provider-side host & placement layer: finite
//!   memory/CPU invoker hosts, a pluggable placement `Scheduler`
//!   (first-fit, least-loaded, round-robin, packing-aware), memory-pressure
//!   eviction and host-drain windows; replaces the flat fleet counter when
//!   configured.
//! * [`control`] — the autoscaling control subsystem: feedback controllers
//!   (target-tracking, PID, step ladder) observed/actuated on a fixed
//!   simulated-time tick, moving the fleet cap or the cluster host set.
//! * [`fleet`] — multi-function fleet simulation: N heterogeneous functions
//!   under a pluggable keep-alive policy, with an optional fleet-wide
//!   concurrency cap or a finite-resource [`cluster`], and a fleet cost
//!   rollup.
//! * [`whatif`] — parameter sweeps, configuration optimization and
//!   keep-alive policy comparison.
//! * [`scenario`] — **the documented programmatic surface**: a typed,
//!   serializable [`ScenarioSpec`] (workload × platform × experiment ×
//!   cost × output) executed by one [`run_scenario`] entry point. The CLI
//!   subcommands are thin translators over it, and `simfaas run
//!   <scenario.json>` executes spec files directly.
//! * [`telemetry`] — the observability layer: per-request span records and
//!   periodic internal-state samples captured through the `sim::core`
//!   seam, with JSONL/CSV/Chrome-trace (Perfetto) exporters.
//! * [`output`] — ASCII tables/plots and CSV/JSON writers used by the CLI,
//!   examples and benches.
//!
//! See `DESIGN.md` for the per-experiment index mapping every table and
//! figure of the paper to the modules and benches that regenerate it.

pub mod analytical;
pub mod cli;
pub mod cluster;
pub mod control;
pub mod cost;
pub mod emulator;
pub mod figures;
pub mod fleet;
pub mod output;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod telemetry;
pub mod trace;
pub mod whatif;
pub mod workload;

pub use cluster::{ClusterConfig, SchedulerSpec};
pub use control::{ControlReport, ControlSample, ControllerSpec};
pub use fleet::{FleetConfig, FleetResults, KeepAlivePolicy, PolicySpec};
pub use scenario::{
    run_scenario, ExperimentSpec, ProcessSpec, ScenarioReport, ScenarioSpec, SourceSpec,
};
pub use workload::{AzureDataset, SyntheticTrace, TraceSource};
pub use sim::{
    run_ensemble, EnsembleOpts, EnsembleResults, FaultProfile, Process, RetryPolicy,
    ServerlessSimulator, ServerlessTemporalSimulator, SimConfig, SimProcess, SimResults,
};
pub use telemetry::{Observer, TelemetryRecorder, TelemetrySink};
