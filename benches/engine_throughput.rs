//! Bench: core engine performance (the §Perf hot path) — simulator event
//! throughput, the PJRT payload latency, and the PJRT histogram vs the
//! pure-Rust histogram on large traces.
#[path = "harness.rs"]
mod harness;

use simfaas::runtime::{Engine, PayloadKind};
use simfaas::sim::{Histogram, Rng, ServerlessSimulator, SimConfig};

fn main() {
    harness::header(
        "Engine",
        "simulator events/s; PJRT payload latency; histogram backends",
        "(perf targets in DESIGN.md §Perf)",
    );
    // --- simulator throughput ---
    let horizon = if harness::quick() { 2e5 } else { 1e6 };
    let cfg = SimConfig::table1().with_horizon(horizon);
    let (res, results) = harness::bench("sim/table1_horizon_1e6", 5, || {
        ServerlessSimulator::new(cfg.clone()).run()
    });
    // Events: arrival + departure per request, plus expirations (~#instances)
    let events = results.total_requests * 2 + results.instances_expired;
    println!(
        "  -> {:.2} M events/s ({} events in {:.3} s)",
        events as f64 / res.mean_s / 1e6,
        events,
        res.mean_s
    );

    // High-load variant: bigger pools stress the idle-pool data structure.
    let cfg_hi = SimConfig::table1().with_arrival_rate(50.0).with_horizon(horizon / 10.0);
    let (res_hi, results_hi) = harness::bench("sim/high_load_rate50", 3, || {
        ServerlessSimulator::new(cfg_hi.clone()).run()
    });
    let events_hi = results_hi.total_requests * 2 + results_hi.instances_expired;
    println!(
        "  -> {:.2} M events/s at ~100-instance pool",
        events_hi as f64 / res_hi.mean_s / 1e6
    );

    // --- PJRT payload latency ---
    match Engine::load_dir(simfaas::runtime::default_artifacts_dir()) {
        Ok(engine) => {
            for kind in PayloadKind::ALL {
                let x = vec![0.25f32; kind.input_len()];
                let iters = if harness::quick() { 20 } else { 100 };
                let (r, _) = harness::bench(
                    &format!("pjrt/{}", kind.artifact_name()),
                    iters,
                    || engine.run_payload(kind, &x).unwrap(),
                );
                let (b, d_in, _) = kind.shape();
                let flops = 2.0 * b as f64 * (d_in * 2 * d_in + 2 * d_in * 128) as f64;
                println!("  -> ~{:.2} MFLOP/exec, {:.1} us/exec", flops / 1e6, r.mean_s * 1e6);
            }

            // --- histogram backends on a 4M-sample trace ---
            let mut rng = Rng::new(1);
            let n = if harness::quick() { 500_000 } else { 4_000_000 };
            let samples_f32: Vec<f32> = (0..n).map(|_| rng.exponential(0.5) as f32).collect();
            let samples_f64: Vec<f64> = samples_f32.iter().map(|&x| x as f64).collect();
            let (rust_r, h) = harness::bench("hist/pure_rust_4M", 5, || {
                let mut h = Histogram::new(0.0, 16.0, 64);
                for &s in &samples_f64 {
                    h.push(s);
                }
                h
            });
            let (pjrt_r, counts) = harness::bench("hist/pjrt_kernel_4M", 5, || {
                engine.run_histogram(&samples_f32, 0.0, 16.0).unwrap()
            });
            let expect: Vec<f64> = h.counts().iter().map(|&c| c as f64).collect();
            assert_eq!(counts, expect, "backends must agree exactly");
            println!(
                "  -> pure rust {:.1} Msamples/s, pjrt kernel {:.1} Msamples/s (identical counts)",
                n as f64 / rust_r.mean_s / 1e6,
                n as f64 / pjrt_r.mean_s / 1e6
            );
        }
        Err(e) => println!("(pjrt benches skipped: {e:#})"),
    }
}
