#!/usr/bin/env python3
"""Regenerate the miniature Azure-trace fixture in examples/traces/azure_sample/.

The fixture follows the Azure Functions 2019 dataset layout (Shahrad et
al., "Serverless in the Wild"): per-function invocations-per-minute,
per-function duration percentiles (ms), and per-app memory percentiles —
20 functions across 5 apps, deterministic (no RNG), with a mix of diurnal,
cron-style, bursty, rare and hot invocation patterns so the streaming
ingestion path sees every shape. Run from the repo root:

    python3 scripts/make_azure_sample.py
"""

import hashlib
import math
import os

OUT = os.path.join("examples", "traces", "azure_sample")
MINUTES = 1440


def h(name: str) -> str:
    return hashlib.sha256(name.encode()).hexdigest()[:16]


def diurnal(peak_min, amplitude, base):
    return [
        max(0, round(base + amplitude * (1 + math.sin(2 * math.pi * (m - peak_min + 360) / MINUTES)) / 2))
        for m in range(MINUTES)
    ]


def cron(period_min, count):
    return [count if m % period_min == 0 else 0 for m in range(MINUTES)]


def bursty(period_min, burst):
    return [burst if (m // period_min) % 4 == 0 and m % period_min < 3 else 0 for m in range(MINUTES)]


def rare(times):
    row = [0] * MINUTES
    for t in times:
        row[t] = 1
    return row


def steady(per_min):
    return [per_min] * MINUTES


APPS = [
    ("owner-a", "app-analytics", 128, 10),
    ("owner-a", "app-webshop", 256, 12),
    ("owner-b", "app-etl", 512, 30),
    ("owner-b", "app-chat", 192, 8),
    ("owner-c", "app-batch", 384, 25),
]

# (app index, short name, trigger, counts, avg_ms, p50_ms, p99_ms)
FUNCTIONS = [
    (0, "pageview", "http", steady(8), 45, 30, 220),
    (0, "clickstream", "event", diurnal(780, 12, 2), 80, 60, 500),
    (0, "report-daily", "timer", cron(1440, 1), 2600, 2400, 9000),
    (0, "sessionize", "queue", diurnal(800, 6, 1), 150, 120, 800),
    (1, "checkout", "http", diurnal(1140, 10, 3), 320, 250, 2400),
    (1, "cart-sync", "http", steady(5), 60, 45, 260),
    (1, "thumbnail", "blob", bursty(15, 10), 900, 700, 4200),
    (1, "email-receipt", "queue", diurnal(1150, 4, 1), 210, 160, 1100),
    (1, "restock-check", "timer", cron(60, 2), 140, 110, 620),
    (2, "ingest", "event", steady(30), 520, 400, 3800),
    (2, "transform", "queue", steady(28), 1400, 1100, 8800),
    (2, "compact", "timer", cron(360, 4), 5200, 4800, 21000),
    (2, "validate", "queue", bursty(30, 25), 240, 180, 1500),
    (3, "message-post", "http", diurnal(840, 16, 2), 35, 25, 180),
    (3, "presence-ping", "http", steady(12), 12, 8, 90),
    (3, "notify-push", "queue", diurnal(860, 8, 1), 95, 70, 450),
    (4, "train-nightly", "timer", cron(1440, 1), 45000, 42000, 160000),
    (4, "score-batch", "queue", bursty(120, 40), 2800, 2200, 12000),
    (4, "cleanup", "timer", cron(720, 1), 800, 650, 3100),
    (4, "audit-rare", "event", rare([123, 700, 1339]), 400, 320, 1900),
]


def main() -> None:
    os.makedirs(OUT, exist_ok=True)

    inv_header = "HashOwner,HashApp,HashFunction,Trigger," + ",".join(
        str(m) for m in range(1, MINUTES + 1)
    )
    inv_rows = [inv_header]
    dur_rows = [
        "HashOwner,HashApp,HashFunction,Average,Count,Minimum,Maximum,"
        "percentile_Average_0,percentile_Average_1,percentile_Average_25,"
        "percentile_Average_50,percentile_Average_75,percentile_Average_99,"
        "percentile_Average_100"
    ]
    mem_rows = ["HashOwner,HashApp,SampleCount,AverageAllocatedMb"]

    for owner, app, mb, samples in APPS:
        mem_rows.append(f"{h(owner)},{h(app)},{samples},{mb}")

    for app_idx, name, trigger, counts, avg, p50, p99 in FUNCTIONS:
        owner, app, _, _ = APPS[app_idx]
        total = sum(counts)
        p25 = round(p50 * 0.8)
        p75 = round((p50 + p99) / 2 * 0.7)
        lo = round(p50 * 0.5)
        hi = round(p99 * 1.1)
        inv_rows.append(
            f"{h(owner)},{h(app)},{h(name)},{trigger}," + ",".join(str(c) for c in counts)
        )
        dur_rows.append(
            f"{h(owner)},{h(app)},{h(name)},{avg},{total},{lo},{hi},"
            f"{lo},{round(p50 * 0.6)},{p25},{p50},{p75},{p99},{hi}"
        )

    for fname, rows in [
        ("invocations_per_function.csv", inv_rows),
        ("function_durations_percentiles.csv", dur_rows),
        ("app_memory_percentiles.csv", mem_rows),
    ]:
        path = os.path.join(OUT, fname)
        with open(path, "w") as f:
            f.write("\n".join(rows) + "\n")
        print(f"wrote {path} ({len(rows) - 1} data rows)")


if __name__ == "__main__":
    main()
