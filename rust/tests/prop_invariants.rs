//! Property-based tests over the simulator's invariants.
//!
//! No property-testing crate is available offline, so this file carries a
//! small in-repo harness: seeded random configuration generators (driven by
//! the library's own deterministic `Rng`) and a `forall` runner that, on
//! failure, reports the failing seed so the case can be replayed exactly.
//! Each property runs against dozens of randomized workload/platform
//! configurations spanning deterministic, exponential, gamma, Pareto and
//! MMPP processes, low/high load, tight/loose concurrency caps.

use simfaas::sim::process::*;
use simfaas::sim::{
    Rng, ServerlessSimulator, SimConfig, SimResults,
};

/// Mini property harness: run `prop` for `cases` generated configs; panic
/// with the seed on the first failure.
fn forall(name: &str, cases: u64, prop: impl Fn(&SimConfig, &SimResults)) {
    for case in 0..cases {
        let seed = 0xBEEF_0000 + case;
        let cfg = gen_config(seed);
        let results = ServerlessSimulator::new(cfg.clone()).run();
        // Property panics carry context via assert messages.
        let ctx = format!("property {name:?} failed for generator seed {seed:#x}");
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&cfg, &results)
        }));
        if let Err(e) = result {
            eprintln!("{ctx}: cfg horizon={} max_conc={}", cfg.horizon, cfg.max_concurrency);
            std::panic::resume_unwind(e);
        }
    }
}

/// Random but *valid* simulator configuration.
fn gen_config(seed: u64) -> SimConfig {
    let mut g = Rng::new(seed);
    let arrival: Process = match g.below(4) {
        0 => ExpProcess::with_rate(g.uniform_range(0.05, 5.0)).into(),
        1 => ConstProcess::new(g.uniform_range(0.2, 10.0)).into(),
        2 => GammaProcess::new(g.uniform_range(0.5, 4.0), g.uniform_range(0.2, 2.0)).into(),
        _ => MmppProcess::new(
            [g.uniform_range(0.5, 5.0), g.uniform_range(0.05, 0.5)],
            [g.uniform_range(0.005, 0.05), g.uniform_range(0.005, 0.05)],
        )
        .into(),
    };
    let service = |g: &mut Rng| -> Process {
        match g.below(4) {
            0 => ExpProcess::with_mean(g.uniform_range(0.2, 4.0)).into(),
            1 => ConstProcess::new(g.uniform_range(0.2, 4.0)).into(),
            2 => GaussianProcess::new(g.uniform_range(0.5, 3.0), g.uniform_range(0.1, 1.0))
                .into(),
            _ => ParetoProcess::new(g.uniform_range(0.2, 1.0), g.uniform_range(1.5, 3.0))
                .into(),
        }
    };
    let warm = service(&mut g);
    let cold = service(&mut g);
    SimConfig {
        arrival,
        batch_size: if g.uniform() < 0.25 {
            Some(GammaProcess::new(2.0, g.uniform_range(0.5, 2.0)).into())
        } else {
            None
        },
        warm_service: warm,
        cold_service: cold,
        expiration_threshold: g.uniform_range(10.0, 1200.0),
        expiration_process: if g.uniform() < 0.25 {
            Some(Process::exp_mean(g.uniform_range(10.0, 600.0)))
        } else {
            None
        },
        max_concurrency: if g.uniform() < 0.3 {
            g.below(20) as usize + 1 // tight cap: rejections happen
        } else {
            1000
        },
        horizon: g.uniform_range(2_000.0, 20_000.0),
        skip_initial: if g.uniform() < 0.5 { 0.0 } else { g.uniform_range(10.0, 500.0) },
        seed: g.next_u64(),
        capture_request_log: true,
        sample_interval: 0.0,
        fault: simfaas::sim::FaultProfile::disabled(),
        retry: simfaas::sim::RetryPolicy::none(),
    }
}

#[test]
fn request_accounting_is_exhaustive() {
    // Every arrival in the measured window is cold, warm, or rejected.
    forall("accounting", 40, |_cfg, r| {
        assert_eq!(
            r.total_requests,
            r.cold_requests + r.warm_requests + r.rejected_requests
        );
    });
}

#[test]
fn probabilities_are_probabilities() {
    forall("probabilities", 40, |_cfg, r| {
        assert!((0.0..=1.0).contains(&r.cold_start_prob), "p_cold={}", r.cold_start_prob);
        assert!((0.0..=1.0).contains(&r.rejection_prob));
        assert!((0.0..=1.0).contains(&r.wasted_capacity) || r.avg_server_count == 0.0);
    });
}

#[test]
fn level_decomposition_total_equals_running_plus_idle() {
    forall("levels", 40, |_cfg, r| {
        assert!(
            (r.avg_server_count - r.avg_running_count - r.avg_idle_count).abs() < 1e-6,
            "total {} != running {} + idle {}",
            r.avg_server_count,
            r.avg_running_count,
            r.avg_idle_count
        );
        assert!(r.avg_running_count >= -1e-12);
        assert!(r.avg_idle_count >= -1e-12);
        assert!(r.max_server_count + 1e-12 >= r.avg_server_count);
    });
}

#[test]
fn concurrency_cap_is_respected() {
    forall("cap", 40, |cfg, r| {
        assert!(
            r.max_server_count <= cfg.max_concurrency as f64 + 1e-9,
            "max {} exceeds cap {}",
            r.max_server_count,
            cfg.max_concurrency
        );
    });
}

#[test]
fn billed_time_bounded_by_server_time() {
    // Billed busy seconds cannot exceed the total instance-seconds online.
    forall("billing", 40, |_cfg, r| {
        let server_seconds = r.avg_server_count * r.measured_time;
        assert!(
            r.billed_instance_seconds <= server_seconds * (1.0 + 1e-6) + 1.0,
            "billed {} > online {}",
            r.billed_instance_seconds,
            server_seconds
        );
        assert!(r.billed_instance_seconds >= 0.0);
    });
}

#[test]
fn instance_creation_matches_cold_starts() {
    // In the measured window each cold start creates exactly one instance.
    forall("creation", 40, |_cfg, r| {
        assert_eq!(r.instances_created, r.cold_requests);
        assert!(r.instances_expired <= r.instances_created + 1000); // initial state margin
    });
}

#[test]
fn pmf_is_a_distribution() {
    forall("pmf", 30, |_cfg, r| {
        if r.instance_count_pmf.is_empty() {
            return;
        }
        let sum: f64 = r.instance_count_pmf.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "pmf sums to {sum}");
        assert!(r.instance_count_pmf.iter().all(|&p| (0.0..=1.0 + 1e-12).contains(&p)));
        // PMF mean equals the time-weighted average server count.
        let mean: f64 = r
            .instance_count_pmf
            .iter()
            .enumerate()
            .map(|(i, &p)| i as f64 * p)
            .sum();
        assert!(
            (mean - r.avg_server_count).abs() < 1e-6,
            "pmf mean {mean} != avg {}",
            r.avg_server_count
        );
    });
}

#[test]
fn quantiles_are_ordered() {
    forall("quantiles", 30, |_cfg, r| {
        if r.total_requests < 100 || r.cold_requests + r.warm_requests == 0 {
            return;
        }
        assert!(r.response_p50 <= r.response_p95 + 1e-9);
        assert!(r.response_p95 <= r.response_p99 + 1e-9);
        assert!(r.response_p50 >= 0.0);
    });
}

#[test]
fn request_log_is_chronological_and_consistent() {
    forall("log", 25, |_cfg, r| {
        // (log checked through a fresh run to access the simulator object)
        let _ = r;
    });
    // Direct check with a dedicated run:
    for seed in 0..10u64 {
        let cfg = gen_config(0xFACE + seed);
        let mut sim = ServerlessSimulator::new(cfg);
        let r = sim.run();
        let log = sim.request_log();
        assert_eq!(log.len() as u64, r.total_requests);
        assert!(log.windows(2).all(|w| w[0].arrived_at <= w[1].arrived_at));
        for e in log {
            match e.outcome {
                simfaas::sim::RequestOutcome::Rejected => assert!(e.instance.is_none()),
                _ => assert!(e.instance.is_some()),
            }
        }
    }
}

#[test]
fn same_seed_same_results_across_process_state() {
    // Bit-reproducibility: regenerating the same seed gives identical runs.
    // (Note: configs are *regenerated*, not cloned — a cloned config shares
    // any stateful process like MMPP, whose phase carries across runs by
    // design; fresh construction is the reproducibility contract.)
    for seed in [1u64, 99, 0xDEAD] {
        let a = ServerlessSimulator::new(gen_config(seed)).run();
        let b = ServerlessSimulator::new(gen_config(seed)).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.rejected_requests, b.rejected_requests);
        assert!((a.avg_server_count - b.avg_server_count).abs() < 1e-12);
        assert!((a.billed_instance_seconds - b.billed_instance_seconds).abs() < 1e-9);
    }
}

#[test]
fn newest_first_routing_targets_youngest_idle_instance() {
    // Direct check of the paper's §2 routing rule: seed a warm pool of
    // three idle instances (ids 0,1,2; 2 is the newest) and drive light
    // deterministic traffic. Every request must be served by instance 2,
    // and the starved instances 0 and 1 must expire at the threshold.
    use simfaas::sim::{InstanceId, InstanceState};
    let cfg = SimConfig {
        arrival: Process::constant(10.0),
        batch_size: None,
        warm_service: Process::constant(1.0),
        cold_service: Process::constant(1.2),
        expiration_threshold: 25.0,
        expiration_process: None,
        max_concurrency: 1000,
        horizon: 200.0,
        skip_initial: 0.0,
        seed: 42,
        capture_request_log: true,
        sample_interval: 0.0,
        fault: simfaas::sim::FaultProfile::disabled(),
        retry: simfaas::sim::RetryPolicy::none(),
    };
    let mut sim = ServerlessSimulator::new(cfg);
    sim.set_initial_state(&[0.0, 0.0, 0.0], &[]);
    let r = sim.run();
    assert_eq!(r.cold_requests, 0, "warm pool must absorb all traffic");
    assert!(sim
        .request_log()
        .iter()
        .all(|e| e.instance == Some(InstanceId(2))));
    let insts = sim.instances();
    assert_eq!(insts[0].state, InstanceState::Terminated);
    assert_eq!(insts[1].state, InstanceState::Terminated);
    assert_ne!(insts[2].state, InstanceState::Terminated);
    // The starved instances expired exactly at the threshold.
    assert!((insts[0].terminated_at.as_secs() - 25.0).abs() < 1e-9);
}

#[test]
fn batch_arrivals_spawn_parallel_instances() {
    // Paper §4.2/§6: batch arrivals (beyond Markovian models). A constant
    // batch of 4 with slow epochs and short service needs 4 instances at
    // every epoch: all four get created at the first epoch and then reused.
    let cfg = SimConfig {
        arrival: Process::constant(10.0),
        batch_size: Some(Process::constant(4.0)),
        warm_service: Process::constant(1.0),
        cold_service: Process::constant(1.5),
        expiration_threshold: 60.0,
        expiration_process: None,
        max_concurrency: 1000,
        horizon: 500.0,
        skip_initial: 0.0,
        seed: 9,
        capture_request_log: true,
        sample_interval: 0.0,
        fault: simfaas::sim::FaultProfile::disabled(),
        retry: simfaas::sim::RetryPolicy::none(),
    };
    let mut sim = ServerlessSimulator::new(cfg);
    let r = sim.run();
    assert_eq!(r.cold_requests, 4, "first epoch cold-starts the pool");
    assert_eq!(r.total_requests % 4, 0);
    assert!((r.max_server_count - 4.0).abs() < 1e-9);
    // Requests arrive in epochs of 4 simultaneous entries.
    let log = sim.request_log();
    for chunk in log.chunks(4) {
        assert_eq!(chunk.len(), 4);
        assert!(chunk.iter().all(|e| e.arrived_at == chunk[0].arrived_at));
    }
}

/// Satellite property (trace ingestion PR): non-homogeneous thinning hits
/// its target mean rate within a normal-approximation CI, eagerly and —
/// bit-identically — through the streaming `ArrivalSource` seam.
#[test]
fn nonhomogeneous_thinning_hits_target_mean_rate_within_ci() {
    use simfaas::workload::{nonhomogeneous, StreamSpec};
    let day = 86_400.0;
    let horizon = 4.0 * day;
    for (case, (mean, depth)) in
        [(0.3, 0.2), (0.8, 0.9), (1.5, 0.0), (2.5, 0.5), (0.05, 0.7)].into_iter().enumerate()
    {
        for seed_step in 0..4u64 {
            let seed = 0xACE0 + case as u64 * 16 + seed_step;
            let offset = 1_000.0 * case as f64;
            let rate = move |t: f64| {
                mean * (1.0 + depth * (2.0 * std::f64::consts::PI * (t + offset) / day).sin())
            };
            let mut rng = Rng::new(seed);
            let w = nonhomogeneous(rate, mean * (1.0 + depth), horizon, &mut rng);
            // Over whole days the sinusoid integrates out: expected count
            // is mean * horizon; Poisson sd = sqrt(expected). 4.5 sigma
            // keeps the 20-case sweep's false-failure odds negligible.
            let expected = mean * horizon;
            let sd = expected.sqrt();
            let n = w.len() as f64;
            assert!(
                (n - expected).abs() < 4.5 * sd,
                "case {case} seed {seed:#x}: n={n} expected={expected} sd={sd}"
            );
            // The streaming generator draws the identical sequence.
            let lazy: Vec<f64> =
                StreamSpec::sinusoid(mean, depth, offset, seed).build(horizon).collect();
            assert_eq!(lazy.len(), w.len());
            for (a, b) in w.arrivals.iter().zip(&lazy) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
