//! Platform emulator: the concurrent, virtual-clock stand-in for the
//! paper's AWS Lambda testbed. See `platform` for the architecture and
//! DESIGN.md §3 for why this substitution preserves the validation
//! methodology.

pub mod clock;
pub mod platform;
pub mod probe;

pub use clock::VirtualClock;
pub use platform::{EmulationResult, EmulatorConfig, EmuMetrics, InstanceRecord, Platform};
pub use probe::EmulatorProbe;

/// Serializes emulator-driven tests: the emulator measures real thread
/// timing, and two emulations sharing this single-core testbed distort
/// each other. Test-only.
#[cfg(test)]
pub(crate) static EMU_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
pub(crate) fn emu_test_guard() -> std::sync::MutexGuard<'static, ()> {
    EMU_TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}
