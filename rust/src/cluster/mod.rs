//! Cluster host & placement layer: finite-resource hosts, pluggable
//! schedulers, emergent capacity.
//!
//! SimFaaS models platform capacity as one abstract instance counter;
//! real platforms schedule containers onto a cluster of invoker hosts
//! with finite memory and CPU, where admission, eviction, and rejection
//! *emerge* from bin-packing. This module supplies that provider-side
//! layer:
//!
//! - [`Host`] — one invoker: memory/CPU capacity, per-container
//!   accounting, time-weighted utilization counters.
//! - [`Scheduler`] — the invoker-selection trait, with
//!   [`FirstFit`], [`LeastLoaded`], [`RoundRobin`], and [`PackingAware`]
//!   implementations selected via the serializable [`SchedulerSpec`].
//! - [`ClusterConfig`] / [`ClusterState`] — the declarative shape and
//!   the runtime cluster-gate that replaces the flat `FleetGate`
//!   counter when a cluster is configured, including memory-pressure
//!   eviction and [`HostDrain`] maintenance windows.
//!
//! Placement is routed through the `LifecycleHooks` seam in
//! [`crate::sim::core`]: `admit_cold` consults the scheduler for a host
//! with room, `on_cold_start` charges it, `on_expire` releases it. With
//! no cluster configured none of this code runs and every engine's
//! output is bit-identical to the flat-counter path. Per-function
//! memory footprints come from each `FunctionSpec` (for Azure-dataset
//! workloads, the per-app memory join in `workload::azure_dataset`).

mod cluster;
mod host;
mod placement;

pub use cluster::{ClusterConfig, ClusterState, ClusterUsage, HostDrain, CONTAINER_CPUS};
pub use host::Host;
pub use placement::{FirstFit, LeastLoaded, PackingAware, RoundRobin, Scheduler, SchedulerSpec};
