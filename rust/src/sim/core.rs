//! `sim::core` — the single lifecycle engine behind every simulator.
//!
//! Before this module existed the crate carried three hand-synchronized
//! copies of the cold/warm/expire instance lifecycle —
//! [`super::simulator::ServerlessSimulator`],
//! [`super::par_simulator::ParServerlessSimulator`] and
//! `fleet::FunctionEngine` each had their own
//! `handle_arrival`/`handle_departure`/`handle_expiration`, kept
//! RNG-sequence-identical only by regression tests. This module is the one
//! shared implementation: an [`EngineCore`] holding the instance pool, the
//! level accumulators and the event handlers, parameterized by
//!
//! * a [`Scheduler`] — where events land (one of the
//!   [`super::event::EventQueue`] implementations, or the fleet's
//!   function-tagged queue),
//! * a [`LifecycleHooks`] implementation — the three points where the
//!   engines genuinely differ: the keep-alive (expiration-threshold) draw,
//!   fleet-gate admission on cold starts, and per-request observation
//!   (adaptive policies, request logs),
//! * a concurrency value — 1 for scale-per-request routing (sorted idle
//!   pool, newest-first pop), >1 for concurrency-valued routing (newest
//!   instance with spare slots).
//!
//! **Bit-identity contract.** The handlers consume the RNG in exactly the
//! sequence the three pre-refactor engines did (batch draw, per-request
//! service draws, keep-alive draws) and push events in the same order, so
//! every engine built on this core reproduces its pre-refactor outputs
//! bit-for-bit on the same seed. `tests/engine_unification.rs` pins this
//! with exactly-computable deterministic fixtures and cross-engine digest
//! equality for all five pre-refactor configurations (steady, par,
//! temporal, 1-function fleet, capped fleet).
//!
//! **Prewarm (provisioning-lead) events.** The core also implements the
//! ROADMAP's prewarm model once, behind the same seam: when a configured
//! provisioning lead time is positive and the idle pool drains, the hooks
//! are asked for a predicted next arrival
//! ([`LifecycleHooks::prewarm_ready_at`], the hybrid-histogram policy's
//! head-percentile arm in the fleet) and the core schedules an
//! [`Event::Provision`] one lead ahead of it; the instance becomes warm at
//! [`Event::ProvisioningDone`]. A lead of `0.0` disables the feature
//! entirely — no `Provision` event is ever scheduled, which is what makes
//! prewarm-off runs bit-identical to the pre-prewarm engines. Provisioning
//! instances count toward the live server level (provider footprint) but
//! are neither running nor billed; a prewarmed instance that expires
//! without serving a single request adds its whole lifespan to
//! `wasted_prewarm_seconds`.
//!
//! **Reliability layer (fault injection + retries).** The core also
//! interprets a [`FaultProfile`] and [`RetryPolicy`] pair behind the same
//! seams (DESIGN.md §Reliability): fault outcomes are resolved at dispatch
//! time (the busy period is known then), timed-out executions become
//! [`Event::RequestTimeout`] / truncated departures, failed requests
//! re-enter as [`Event::RetryArrival`] after a backoff delay, and
//! scheduled degradation windows shrink the effective concurrency cap via
//! [`Event::DegradationStart`]/[`Event::DegradationEnd`]. Every fault and
//! jitter decision draws from a **dedicated RNG lane** (the engine seed
//! run through one extra SplitMix64 scramble with a fixed salt), and only
//! when the specific mechanism can fire — so a
//! [`FaultProfile::disabled`]+[`RetryPolicy::none`] core draws nothing and
//! is bit-identical to the pre-fault engines (pinned in
//! `tests/engine_unification.rs`).
#![warn(missing_docs)]

use super::arena::InstanceArena;
use super::event::{CalendarEventQueue, Event, HeapEventQueue};
use super::fault::{FaultProfile, TimeoutAction};
use super::hist::CountDistribution;
use super::instance::{FunctionInstance, InstanceId, InstanceState};
use super::metrics::{OnlineStats, P2Quantile, TimeWeighted};
use super::process::Process;
use super::results::SimResults;
use super::retry::RetryPolicy;
use super::rng::{Rng, SplitMix64};
use super::time::SimTime;
use crate::telemetry::{Observer, SpanOutcome, SpanRecord, SpanVerdict, StateSample};
use crate::workload::stream::ArrivalSource;
use std::collections::BTreeMap;

/// Outcome of a single request, reported to [`LifecycleHooks::on_request`]
/// (and recorded in the optional per-request trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Served by a freshly cold-started instance.
    Cold,
    /// Served by a warm (idle or spare-slot) instance.
    Warm,
    /// Rejected at the maximum concurrency level (or the fleet gate).
    Rejected,
}

/// Fault outcome of one dispatched request, resolved at dispatch time
/// (the busy period is known then, so the whole completion — including a
/// truncation at the timeout — can be scheduled immediately).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Verdict {
    /// The execution completes normally.
    Success,
    /// The execution runs to completion but returns a transient error.
    Fail,
    /// The execution exceeds the profile's timeout and is cut off.
    Timeout,
}

impl Verdict {
    /// Public telemetry form of this verdict.
    fn as_span(self) -> SpanVerdict {
        match self {
            Verdict::Success => SpanVerdict::Ok,
            Verdict::Fail => SpanVerdict::Failed,
            Verdict::Timeout => SpanVerdict::Timeout,
        }
    }
}

/// Destination for scheduled events. The core never owns the future event
/// list: the scale-per-request and concurrency-value simulators drive a
/// [`CalendarEventQueue`] (or the reference [`HeapEventQueue`]), while the
/// fleet interleaves many engines on one function-tagged queue behind a
/// per-call adapter.
pub trait Scheduler {
    /// Schedule `event` at absolute simulation time `at`.
    fn schedule(&mut self, at: SimTime, event: Event);
}

impl Scheduler for HeapEventQueue {
    #[inline]
    fn schedule(&mut self, at: SimTime, event: Event) {
        HeapEventQueue::schedule(self, at, event);
    }
}

impl Scheduler for CalendarEventQueue {
    #[inline]
    fn schedule(&mut self, at: SimTime, event: Event) {
        CalendarEventQueue::schedule(self, at, event);
    }
}

/// The per-engine policy surface of the lifecycle core — everything the
/// three pre-refactor engines did differently, as overridable hooks.
///
/// | Hook | `ServerlessSimulator` | `ParServerlessSimulator` | `fleet::FunctionEngine` |
/// |---|---|---|---|
/// | [`keep_alive`](Self::keep_alive) | config threshold / stochastic draw | config threshold | pluggable `KeepAlivePolicy` |
/// | [`on_arrival_epoch`](Self::on_arrival_epoch) | — | — | policy observes arrivals |
/// | [`admit_cold`](Self::admit_cold) + gate callbacks | always admit | always admit | fleet-wide concurrency gate |
/// | [`on_request`](Self::on_request) | optional request log | — | — |
/// | prewarm hooks | — | — | policy head-percentile arm |
///
/// Implementations must be deterministic given the same call sequence and
/// RNG state; hooks that draw randomness must use the `rng` they are
/// handed (the engine's own stream) so bit-reproducibility survives.
pub trait LifecycleHooks {
    /// Keep-alive window in seconds for an instance going idle at `now`
    /// (one consultation — and at most one RNG draw — per idle period).
    fn keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64;

    /// Observe a request-arrival epoch at `now`, before any routing.
    /// Adaptive keep-alive policies learn inter-arrival histograms here.
    fn on_arrival_epoch(&mut self, _now: f64) {}

    /// Gate check for admitting a cold start beyond the engine's own
    /// maximum-concurrency test (the fleet-wide cap). Must not mutate
    /// shared state: the core calls [`on_cold_start`](Self::on_cold_start)
    /// on actual admission.
    fn admit_cold(&mut self) -> bool {
        true
    }

    /// A cold start (or prewarm provisioning) was admitted; charge any
    /// shared capacity gate.
    fn on_cold_start(&mut self) {}

    /// An instance expired; release any shared capacity gate.
    fn on_expire(&mut self) {}

    /// A request was rejected although the engine's own concurrency limit
    /// had room — i.e. only the shared gate blocked it.
    fn on_gate_only_rejection(&mut self) {}

    /// A request finished routing (only invoked once statistics are being
    /// collected). `rt` is the response time (0 for rejected requests);
    /// `instance` is the serving instance (None for rejected).
    fn on_request(
        &mut self,
        _now: f64,
        _outcome: RequestOutcome,
        _rt: f64,
        _instance: Option<InstanceId>,
    ) {
    }

    /// Predicted absolute time a warm instance should be ready (the
    /// prewarm arm). Consulted only when the provisioning lead is positive
    /// and the idle pool just drained; `None` (the default) means no
    /// prediction, so no prewarm is scheduled.
    fn prewarm_ready_at(&mut self, _now: f64) -> Option<f64> {
        None
    }

    /// Keep-alive window for a freshly prewarmed (never-used) instance.
    /// Defaults to the ordinary [`keep_alive`](Self::keep_alive) window.
    fn prewarm_keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        self.keep_alive(now, rng)
    }
}

/// The paper's configuration-driven expiration rule as a hook set: a fixed
/// threshold, optionally overridden by a stochastic threshold process —
/// exactly `SimConfig::{expiration_threshold, expiration_process}`. Used by
/// both single-function simulators.
#[derive(Clone)]
pub struct ConfigExpiration {
    /// Constant idle-expiration threshold in seconds.
    pub threshold: f64,
    /// Optional stochastic threshold (one draw per idle period), overriding
    /// the constant.
    pub process: Option<Process>,
}

impl LifecycleHooks for ConfigExpiration {
    fn keep_alive(&mut self, _now: f64, rng: &mut Rng) -> f64 {
        match &self.process {
            Some(p) => p.sample(rng),
            None => self.threshold,
        }
    }
}

/// Warm-routing structure: which instance absorbs the next request.
///
/// Scale-per-request keeps the idle pool as a Vec sorted ascending by id —
/// the newest idle instance is an O(1) pop off the end (see DESIGN.md
/// §Perf). The concurrency-value engine instead tracks spare slots per
/// instance in a BTreeMap keyed by id, so "newest instance with spare
/// capacity" is `next_back`.
enum Router {
    /// One request per instance (the paper's scale-per-request model).
    PerRequest { idle: Vec<InstanceId> },
    /// Up to `value` concurrent requests per instance (paper §3.1).
    Concurrent {
        available: BTreeMap<InstanceId, u32>,
        value: u32,
    },
}

impl Router {
    fn new(concurrency_value: u32) -> Router {
        if concurrency_value <= 1 {
            Router::PerRequest { idle: Vec::with_capacity(64) }
        } else {
            Router::Concurrent { available: BTreeMap::new(), value: concurrency_value }
        }
    }

    /// Take the newest instance that can absorb one request (consuming one
    /// slot of its capacity).
    fn take_newest(&mut self) -> Option<InstanceId> {
        match self {
            Router::PerRequest { idle } => idle.pop(),
            Router::Concurrent { available, .. } => {
                let (id, slots) = available.iter().next_back().map(|(&id, &s)| (id, s))?;
                if slots <= 1 {
                    available.remove(&id);
                } else {
                    available.insert(id, slots - 1);
                }
                Some(id)
            }
        }
    }

    /// A new instance was cold-started for a request: register any spare
    /// capacity beyond that request.
    fn on_cold_created(&mut self, id: InstanceId) {
        match self {
            Router::PerRequest { .. } => {}
            Router::Concurrent { available, value } => {
                if *value > 1 {
                    available.insert(id, *value - 1);
                }
            }
        }
    }

    /// A request departed from `id`; `became_idle` is true when the
    /// instance now has no request in flight.
    fn release(&mut self, id: InstanceId, became_idle: bool) {
        match self {
            Router::PerRequest { idle } => {
                debug_assert!(became_idle, "scale-per-request departures always idle");
                match idle.binary_search(&id) {
                    Err(pos) => idle.insert(pos, id),
                    Ok(_) => unreachable!("instance already idle"),
                }
            }
            Router::Concurrent { available, value } => {
                let slots = available.get(&id).copied().unwrap_or(0) + 1;
                available.insert(id, slots.min(*value));
            }
        }
    }

    /// Insert a fully idle instance (initial warm pools, prewarm
    /// completion).
    fn insert_idle(&mut self, id: InstanceId) {
        match self {
            Router::PerRequest { idle } => match idle.binary_search(&id) {
                Err(pos) => idle.insert(pos, id),
                Ok(_) => unreachable!("instance already idle"),
            },
            Router::Concurrent { available, value } => {
                available.insert(id, *value);
            }
        }
    }

    /// Pop the oldest fully idle instance (lowest id) for forced
    /// eviction. Only supported for scale-per-request routing, where the
    /// idle pool holds exactly the fully idle instances; a `Concurrent`
    /// pool can contain busy instances, so eviction declines there.
    fn pop_oldest_idle(&mut self) -> Option<InstanceId> {
        match self {
            Router::PerRequest { idle } => {
                if idle.is_empty() {
                    None
                } else {
                    Some(idle.remove(0))
                }
            }
            Router::Concurrent { .. } => None,
        }
    }

    /// Drop an expired instance from the routing structure.
    fn remove(&mut self, id: InstanceId) {
        match self {
            Router::PerRequest { idle } => {
                if let Ok(pos) = idle.binary_search(&id) {
                    idle.remove(pos);
                }
            }
            Router::Concurrent { available, .. } => {
                available.remove(&id);
            }
        }
    }

    /// Whether any instance can absorb a request without a cold start.
    fn has_capacity(&self) -> bool {
        match self {
            Router::PerRequest { idle } => !idle.is_empty(),
            Router::Concurrent { available, .. } => !available.is_empty(),
        }
    }

    /// Number of entries in the warm-routing pool (idle instances for
    /// scale-per-request; instances with any spare slot otherwise).
    fn pool_len(&self) -> usize {
        match self {
            Router::PerRequest { idle } => idle.len(),
            Router::Concurrent { available, .. } => available.len(),
        }
    }
}

/// Construction parameters for an [`EngineCore`].
pub struct CoreParams {
    /// RNG seed; equal seeds give bit-identical runs.
    pub seed: u64,
    /// Warm-start busy-period process (service time).
    pub warm_service: Process,
    /// Cold-start busy-period process (provisioning + service).
    pub cold_service: Process,
    /// Optional batch-size process: each arrival epoch brings
    /// `max(1, round(sample))` simultaneous requests. `None` = single
    /// arrivals (the concurrency-value engine never batches).
    pub batch_size: Option<Process>,
    /// Maximum concurrency level (live-instance cap of this engine).
    pub max_concurrency: usize,
    /// Warm-up window excluded from all statistics.
    pub skip_initial: f64,
    /// Per-instance concurrency value (1 = scale-per-request).
    pub concurrency_value: u32,
    /// Provisioning lead time for prewarm events in seconds; `0.0`
    /// disables prewarming entirely (bit-identical to the pre-prewarm
    /// engines).
    pub prewarm_lead: f64,
    /// Pre-reserved capacity of the instance table (profiling-driven; see
    /// DESIGN.md §Perf).
    pub instance_capacity: usize,
    /// Keep terminated instances resident in the arena. The
    /// single-function simulators set this (their [`EngineCore::instances`]
    /// accessor and tests inspect the full creation history); the fleet's
    /// per-function engines clear it, so terminated slots are recycled and
    /// resident memory is bounded by the peak live count, not total churn.
    pub retain_instances: bool,
    /// Fault-injection profile ([`FaultProfile::disabled`] = the
    /// pre-fault engines, bit-identical).
    pub fault: FaultProfile,
    /// Retry policy for failed / timed-out requests
    /// ([`RetryPolicy::none`] = every failure is final).
    pub retry: RetryPolicy,
}

/// The shared lifecycle engine: instance pool, warm routing, level
/// accumulators and the arrival/departure/expiration/prewarm event
/// handlers. Engines own one core each, plus their event queue and their
/// [`LifecycleHooks`] implementation; the run loop stays engine-side
/// (arrival sources and horizon handling differ per engine).
pub struct EngineCore {
    /// The engine's RNG stream. Exposed because arrival-gap draws belong
    /// to the engine (process arrivals draw here; trace replay does not)
    /// and must interleave with the core's service draws in the historical
    /// order.
    pub rng: Rng,
    now: SimTime,
    instances: InstanceArena,
    router: Router,
    live_count: usize,
    /// Total requests in flight across all instances.
    in_flight: u64,
    /// Instances currently busy (≥1 request in flight or provisioning a
    /// cold-started request).
    busy_instances: usize,
    max_concurrency: usize,
    warm_service: Process,
    cold_service: Process,
    batch_size: Option<Process>,
    prewarm_lead: f64,
    prewarm_pending: u32,
    /// Whether the busy-instance level needs its own accumulator. Only at
    /// concurrency values above 1 can the busy-instance count diverge from
    /// the in-flight count; at 1 the two are equal at every instant
    /// (provisioning instances count in neither), so the scale-per-request
    /// hot path skips the third accumulator update — the optimization the
    /// pre-unification engine documented in DESIGN.md §Perf.
    track_busy_instances: bool,

    // ------------------------ reliability layer (DESIGN.md §Reliability)
    fault: FaultProfile,
    retry: RetryPolicy,
    /// Dedicated RNG lane for fault and backoff-jitter draws; never
    /// touched on the legacy paths, so the arrival/service streams are
    /// bit-identical with faults disabled.
    fault_rng: Rng,
    /// Cached `!fault.is_disabled()` — one branch on the dispatch hot
    /// path.
    faults_enabled: bool,
    /// Remaining run-wide retry budget (`None` = unbounded).
    retry_budget_left: Option<u64>,
    /// Active flags per degradation window (index-aligned with
    /// `fault.degradation`).
    degradation_active: Vec<bool>,
    /// Concurrency cap after degradation: `floor(max * min active
    /// factor)`; equals `max_concurrency` outside every window.
    effective_max_concurrency: usize,

    // ------------------- telemetry layer (DESIGN.md §Observability)
    /// Optional telemetry hook. Capture draws no RNG and schedules no
    /// events, so an attached observer never changes simulation results;
    /// `None` (the default) costs one branch per dispatch.
    observer: Option<Box<Observer>>,

    // -------- statistics (reset at the end of the warm-up skip) ----------
    stats_started: bool,
    stats_start: SimTime,
    total_requests: u64,
    cold_requests: u64,
    warm_requests: u64,
    rejected_requests: u64,
    instances_created: u64,
    instances_expired: u64,
    prewarm_starts: u64,
    wasted_prewarm_seconds: f64,
    failed_requests: u64,
    timeout_requests: u64,
    coldstart_failures: u64,
    retry_attempts: u64,
    retry_exhausted: u64,
    wasted_work_seconds: f64,
    server_count_tw: TimeWeighted,
    /// Time-weighted in-flight request count (the billing-relevant
    /// "running" level; equals the busy-instance count at concurrency 1).
    running_tw: TimeWeighted,
    /// Time-weighted busy-instance count; `idle = total - busy_instances`
    /// derives the idle level exactly for every concurrency value.
    busy_inst_tw: TimeWeighted,
    count_dist: CountDistribution,
    lifespan_stats: OnlineStats,
    response_stats: OnlineStats,
    warm_response_stats: OnlineStats,
    cold_response_stats: OnlineStats,
    response_p50: P2Quantile,
    response_p95: P2Quantile,
    response_p99: P2Quantile,
    billed_seconds: f64,
}

/// Salt XORed into the engine seed before the extra SplitMix64 scramble
/// that seeds the fault RNG lane, decorrelating it from the main stream
/// (which is seeded from the raw seed).
const FAULT_LANE_SALT: u64 = 0x5EED_FA17_0B5E_55ED;

impl EngineCore {
    /// Build a core at simulation time zero.
    pub fn new(p: CoreParams) -> EngineCore {
        let start = SimTime::ZERO;
        let degradation_active = vec![false; p.fault.degradation.len()];
        let retry_budget_left = p.retry.budget;
        EngineCore {
            rng: Rng::new(p.seed),
            fault_rng: Rng::new(SplitMix64::new(p.seed ^ FAULT_LANE_SALT).next_u64()),
            faults_enabled: !p.fault.is_disabled(),
            effective_max_concurrency: p.max_concurrency,
            degradation_active,
            retry_budget_left,
            observer: None,
            fault: p.fault,
            retry: p.retry,
            now: start,
            instances: InstanceArena::with_capacity(p.instance_capacity, p.retain_instances),
            router: Router::new(p.concurrency_value),
            live_count: 0,
            in_flight: 0,
            busy_instances: 0,
            max_concurrency: p.max_concurrency,
            warm_service: p.warm_service,
            cold_service: p.cold_service,
            batch_size: p.batch_size,
            prewarm_lead: p.prewarm_lead,
            prewarm_pending: 0,
            track_busy_instances: p.concurrency_value > 1,
            stats_started: p.skip_initial <= 0.0,
            stats_start: SimTime::from_secs(p.skip_initial.max(0.0)),
            total_requests: 0,
            cold_requests: 0,
            warm_requests: 0,
            rejected_requests: 0,
            instances_created: 0,
            instances_expired: 0,
            prewarm_starts: 0,
            wasted_prewarm_seconds: 0.0,
            failed_requests: 0,
            timeout_requests: 0,
            coldstart_failures: 0,
            retry_attempts: 0,
            retry_exhausted: 0,
            wasted_work_seconds: 0.0,
            server_count_tw: TimeWeighted::new(start, 0.0),
            running_tw: TimeWeighted::new(start, 0.0),
            busy_inst_tw: TimeWeighted::new(start, 0.0),
            count_dist: CountDistribution::new(start, 0),
            lifespan_stats: OnlineStats::new(),
            response_stats: OnlineStats::new(),
            warm_response_stats: OnlineStats::new(),
            cold_response_stats: OnlineStats::new(),
            response_p50: P2Quantile::new(0.5),
            response_p95: P2Quantile::new(0.95),
            response_p99: P2Quantile::new(0.99),
            billed_seconds: 0.0,
        }
    }

    // ------------------------------------------------------------ accessors

    /// Current simulation time.
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Advance the clock to the time of the event being handled.
    #[inline]
    pub fn set_now(&mut self, t: SimTime) {
        self.now = t;
    }

    /// Whether the warm-up skip has ended and statistics are collected.
    #[inline]
    pub fn stats_started(&self) -> bool {
        self.stats_started
    }

    /// Start of the measured window (end of the warm-up skip).
    #[inline]
    pub fn stats_start(&self) -> SimTime {
        self.stats_start
    }

    /// The total-instance-count accumulator (Fig. 4 sampling reads its
    /// running integral).
    #[inline]
    pub fn server_tw(&self) -> &TimeWeighted {
        &self.server_count_tw
    }

    /// Materialized view of the resident instances, in creation order.
    /// With retained storage (the single-function simulators) this is the
    /// complete creation history, indexed by `InstanceId.0`; fleet engines
    /// recycle terminated slots, so only live instances appear there.
    /// Diagnostic / test surface — not the hot path.
    #[inline]
    pub fn instances(&self) -> Vec<FunctionInstance> {
        self.instances.materialize()
    }

    /// Current (live, busy-instance, warm-pool) counts — for invariant
    /// tests.
    #[inline]
    pub fn live_counts(&self) -> (usize, usize, usize) {
        (self.live_count, self.busy_instances, self.router.pool_len())
    }

    // ----------------------------------------------------------- telemetry

    /// Attach a telemetry observer (DESIGN.md §Observability). Capture
    /// never perturbs the simulation: it draws no RNG and schedules no
    /// events, so results are bit-identical with or without one.
    pub fn set_observer(&mut self, observer: Observer) {
        self.observer = Some(Box::new(observer));
    }

    /// Detach the observer (call after the run to recover its records).
    pub fn take_observer(&mut self) -> Option<Observer> {
        self.observer.take().map(|b| *b)
    }

    /// Emit every due internal-state sample up to the current clock.
    /// Engines call this once per event (after advancing the clock, before
    /// handling the event — state only changes at events, so the current
    /// levels are exactly the levels at every due tick) and once after
    /// [`close`](Self::close). `cap_headroom` is the remaining shared-gate
    /// capacity (capped fleets); `None` when the engine runs uncapped.
    pub fn sample_tick(&mut self, cap_headroom: Option<u64>) {
        if self.observer.is_none() || !self.stats_started {
            return;
        }
        let now = self.now.as_secs();
        let stats_start = self.stats_start.as_secs();
        let (live, busy) = (self.live_count, self.busy_instances);
        let in_flight = self.in_flight;
        let (total, cold, warm) = (self.total_requests, self.cold_requests, self.warm_requests);
        let degradation = self.degradation_active.iter().filter(|a| **a).count() as u32;
        let obs = self.observer.as_mut().expect("checked above");
        let interval = obs.sample_interval();
        if interval <= 0.0 {
            return;
        }
        let function = obs.function();
        let mut next = obs.next_sample_at().unwrap_or(stats_start);
        while next <= now {
            obs.record_sample(StateSample {
                function,
                t: next,
                live_instances: live,
                busy_instances: busy,
                idle_instances: live.saturating_sub(busy),
                in_flight,
                total_requests: total,
                cold_requests: cold,
                warm_requests: warm,
                degradation_active: degradation,
                cap_headroom,
            });
            next += interval;
        }
        obs.set_next_sample_at(next);
    }

    /// Record one dispatch span (no-op without an observer; spans start at
    /// the end of the warm-up skip, like every other statistic).
    #[inline]
    fn emit_span(
        &mut self,
        prev_delay: f64,
        rt: f64,
        outcome: SpanOutcome,
        verdict: SpanVerdict,
        instance: Option<InstanceId>,
        attempt: u32,
    ) {
        if let Some(obs) = self.observer.as_mut() {
            let started_at = self.now.as_secs();
            let function = obs.function();
            obs.record_span(SpanRecord {
                function,
                queued_at: started_at - prev_delay,
                started_at,
                response_time: rt,
                outcome,
                verdict,
                instance: instance.map(|id| id.0),
                attempt,
            });
        }
    }

    // ------------------------------------------------------------ internals

    fn alloc_instance(&mut self, prewarmed: bool) -> InstanceId {
        self.instances.alloc(self.now, prewarmed)
    }

    /// Push the current levels into the time-weighted accumulators.
    fn sync_levels(&mut self) {
        self.server_count_tw.update(self.now, self.live_count as f64);
        self.running_tw.update(self.now, self.in_flight as f64);
        if self.track_busy_instances {
            self.busy_inst_tw.update(self.now, self.busy_instances as f64);
        }
        self.count_dist.update(self.now, self.live_count);
    }

    fn record_response(&mut self, rt: f64, cold: bool) {
        if !self.stats_started {
            return;
        }
        self.response_stats.push(rt);
        if cold {
            self.cold_response_stats.push(rt);
        } else {
            self.warm_response_stats.push(rt);
        }
        self.response_p50.push(rt);
        self.response_p95.push(rt);
        self.response_p99.push(rt);
    }

    /// On the first event at or past the skip boundary: advance the level
    /// accumulators to the boundary, then reset them so the measured
    /// window starts clean.
    pub fn maybe_start_stats(&mut self, event_time: SimTime) {
        if self.stats_started || event_time < self.stats_start {
            return;
        }
        let boundary = self.stats_start;
        self.server_count_tw.advance(boundary);
        self.running_tw.advance(boundary);
        self.busy_inst_tw.advance(boundary);
        self.count_dist.finish(boundary);
        self.server_count_tw.reset_at(boundary);
        self.running_tw.reset_at(boundary);
        self.busy_inst_tw.reset_at(boundary);
        self.count_dist.reset_at(boundary);
        self.stats_started = true;
    }

    // --------------------------------------------------------- event logic

    /// Handle one arrival epoch: draw the batch size (when configured),
    /// route every request, and lazily sync the level accumulators. The
    /// caller schedules the next arrival afterwards — arrival sources
    /// (process vs trace replay) are engine-specific, and the historical
    /// draw order is service draws first, next-arrival gap last.
    pub fn handle_arrival<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
    ) {
        // Adaptive policies observe every arrival epoch (no RNG use, so
        // fixed-policy bit-identity is unaffected).
        hooks.on_arrival_epoch(self.now.as_secs());
        let batch = match &self.batch_size {
            None => 1,
            Some(p) => {
                let k = p.sample(&mut self.rng).round();
                if k < 1.0 {
                    1
                } else {
                    k as u64
                }
            }
        };
        let (live0, flight0) = (self.live_count, self.in_flight);
        for _ in 0..batch {
            self.route_one_request(sched, hooks, 1, 0.0);
        }
        // Lazy sync: a fully-rejected epoch changes no level, so skip the
        // accumulator updates entirely (they stay correct because the
        // level is unchanged since the last sync).
        if self.live_count != live0 || self.in_flight != flight0 {
            self.sync_levels();
        }
    }

    /// Pull the next arrival from `src` and schedule it — the one arrival
    /// seam shared by every engine (scale-per-request, concurrency-value,
    /// fleet). Process sources draw the inter-arrival gap from the
    /// engine's RNG here, preserving the historical draw order (service
    /// draws first, next-arrival gap last); replay and streaming sources
    /// consume nothing from the engine stream. Exhausted sources schedule
    /// nothing.
    pub fn schedule_next_arrival<S: Scheduler>(
        &mut self,
        sched: &mut S,
        src: &mut ArrivalSource,
    ) {
        if let Some(at) = src.next_after(self.now, &mut self.rng) {
            sched.schedule(at, Event::Arrival);
        }
    }

    /// Route a single request at the current instant. `attempt` is the
    /// dispatch attempt number (1 for fresh arrivals) and `prev_delay` the
    /// previous backoff delay (the decorrelated-jitter state) — both are
    /// only consulted when the fault layer is active.
    fn route_one_request<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        attempt: u32,
        prev_delay: f64,
    ) {
        if self.stats_started {
            self.total_requests += 1;
        }
        let now_s = self.now.as_secs();
        if let Some(id) = self.router.take_newest() {
            // Warm start: newest instance with capacity.
            {
                let in_flight = self.instances.in_flight(id);
                if in_flight == 0 {
                    self.instances.start_warm(id, self.now);
                    self.busy_instances += 1;
                }
                self.instances.set_in_flight(id, in_flight + 1);
            }
            self.in_flight += 1;
            let service = self.warm_service.sample(&mut self.rng);
            let (busy, verdict) = self.fault_verdict(service);
            self.schedule_completion(sched, id, busy, verdict);
            if self.stats_started {
                self.warm_requests += 1;
                self.count_verdict(verdict, busy);
                self.record_response(busy, false);
                hooks.on_request(now_s, RequestOutcome::Warm, busy, Some(id));
                self.emit_span(
                    prev_delay,
                    busy,
                    SpanOutcome::Warm,
                    verdict.as_span(),
                    Some(id),
                    attempt,
                );
            }
            if verdict != Verdict::Success {
                self.schedule_retry(sched, attempt, prev_delay, self.now.after(busy));
            }
        } else if self.live_count < self.effective_max_concurrency && hooks.admit_cold() {
            // Provisioning (cold-start) failures resolve before any
            // instance materializes — and before the main-RNG cold service
            // draw, so the legacy stream stays untouched for the requests
            // that do dispatch.
            if self.faults_enabled
                && self.fault.coldstart_failure_prob > 0.0
                && self.fault_rng.uniform() < self.fault.coldstart_failure_prob
            {
                if self.stats_started {
                    self.coldstart_failures += 1;
                    self.emit_span(
                        prev_delay,
                        0.0,
                        SpanOutcome::ColdStartFailed,
                        SpanVerdict::Failed,
                        None,
                        attempt,
                    );
                }
                self.schedule_retry(sched, attempt, prev_delay, self.now);
                return;
            }
            // Cold start: admitted by both the engine's concurrency limit
            // and the hooks' shared gate; its busy period is one draw of
            // the cold service process (provisioning + service).
            hooks.on_cold_start();
            let id = self.alloc_instance(false);
            self.instances.set_in_flight(id, 1);
            self.live_count += 1;
            self.in_flight += 1;
            self.busy_instances += 1;
            self.router.on_cold_created(id);
            if self.stats_started {
                self.instances_created += 1;
            }
            let service = self.cold_service.sample(&mut self.rng);
            let (busy, verdict) = self.fault_verdict(service);
            self.schedule_completion(sched, id, busy, verdict);
            if self.stats_started {
                self.cold_requests += 1;
                self.count_verdict(verdict, busy);
                self.record_response(busy, true);
                hooks.on_request(now_s, RequestOutcome::Cold, busy, Some(id));
                self.emit_span(
                    prev_delay,
                    busy,
                    SpanOutcome::Cold,
                    verdict.as_span(),
                    Some(id),
                    attempt,
                );
            }
            if verdict != Verdict::Success {
                self.schedule_retry(sched, attempt, prev_delay, self.now.after(busy));
            }
        } else {
            if self.stats_started {
                // Concurrency level reached and nothing warm: reject.
                self.rejected_requests += 1;
                if self.live_count < self.effective_max_concurrency {
                    // Only the shared gate blocked this request.
                    hooks.on_gate_only_rejection();
                }
                hooks.on_request(now_s, RequestOutcome::Rejected, 0.0, None);
                self.emit_span(
                    prev_delay,
                    0.0,
                    SpanOutcome::Rejected,
                    SpanVerdict::Ok,
                    None,
                    attempt,
                );
            }
            // Degradation-window rejections retry like any other failure
            // (rejections at full capacity do too, if a policy is set:
            // client-side retries don't know why the platform said no).
            if self.faults_enabled {
                self.schedule_retry(sched, attempt, prev_delay, self.now);
            }
        }
    }

    /// Resolve the fault outcome of a dispatched request whose drawn busy
    /// period is `service`; returns the actual busy period (truncated at
    /// the timeout) and the verdict. Timed-out requests are resolved
    /// before — and never consume — the transient-failure draw, so each
    /// mechanism's fault-lane stream is stable under changes to the other.
    fn fault_verdict(&mut self, service: f64) -> (f64, Verdict) {
        if !self.faults_enabled {
            return (service, Verdict::Success);
        }
        if let Some(t) = self.fault.timeout {
            if service > t {
                return (t, Verdict::Timeout);
            }
        }
        let p = self.fault.invocation_failure_prob;
        if p > 0.0 && self.fault_rng.uniform() < p {
            return (service, Verdict::Fail);
        }
        (service, Verdict::Success)
    }

    /// Schedule the completion event for a dispatched request: a normal
    /// departure, or a [`Event::RequestTimeout`] when the timeout fired
    /// with kill semantics (scheduled *instead of* the departure).
    fn schedule_completion<S: Scheduler>(
        &mut self,
        sched: &mut S,
        id: InstanceId,
        busy: f64,
        verdict: Verdict,
    ) {
        let ev = if verdict == Verdict::Timeout
            && self.fault.timeout_action == TimeoutAction::KillInstance
        {
            Event::RequestTimeout(id)
        } else {
            Event::Departure(id)
        };
        sched.schedule(self.now.after(busy), ev);
    }

    /// Update the failure counters for a dispatched request's verdict
    /// (call only once statistics are collected). A failed or timed-out
    /// execution's whole busy period is wasted work — it was billed but
    /// produced no successful response.
    fn count_verdict(&mut self, verdict: Verdict, busy: f64) {
        match verdict {
            Verdict::Success => {}
            Verdict::Fail => {
                self.failed_requests += 1;
                self.wasted_work_seconds += busy;
            }
            Verdict::Timeout => {
                self.timeout_requests += 1;
                self.wasted_work_seconds += busy;
            }
        }
    }

    /// Re-enqueue a failed request as a [`Event::RetryArrival`] after its
    /// backoff delay, respecting max-attempts and the run-wide retry
    /// budget. `fail_at` is when the client observes the failure (the end
    /// of the failed busy period; the rejection instant for drops).
    fn schedule_retry<S: Scheduler>(
        &mut self,
        sched: &mut S,
        attempt: u32,
        prev_delay: f64,
        fail_at: SimTime,
    ) {
        if self.retry.is_none() {
            return;
        }
        if attempt >= self.retry.max_attempts {
            if self.stats_started {
                self.retry_exhausted += 1;
            }
            return;
        }
        if let Some(left) = &mut self.retry_budget_left {
            if *left == 0 {
                if self.stats_started {
                    self.retry_exhausted += 1;
                }
                return;
            }
            *left -= 1;
        }
        let delay = self.retry.next_delay(prev_delay, &mut self.fault_rng);
        sched.schedule(
            fail_at.after(delay),
            Event::RetryArrival { attempt: attempt + 1, prev_delay_bits: delay.to_bits() },
        );
    }

    /// Handle a [`Event::RetryArrival`]: one failed request re-enters the
    /// platform. It counts as a fresh request (`total_requests` — and thus
    /// the observed arrival rate — includes retry amplification), adaptive
    /// policies observe the epoch like any arrival, and no batch draw is
    /// made (a retry is always a single request).
    pub fn handle_retry_arrival<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        attempt: u32,
        prev_delay: f64,
    ) {
        if self.stats_started {
            self.retry_attempts += 1;
        }
        hooks.on_arrival_epoch(self.now.as_secs());
        let (live0, flight0) = (self.live_count, self.in_flight);
        self.route_one_request(sched, hooks, attempt, prev_delay);
        if self.live_count != live0 || self.in_flight != flight0 {
            self.sync_levels();
        }
    }

    /// Handle a [`Event::RequestTimeout`] with kill semantics: the
    /// execution is cut off at the deadline and its instance torn down
    /// with it — no return to the warm pool, no keep-alive draw. The
    /// truncated busy period is billed (the provider ran the sandbox that
    /// long). On a concurrency-valued instance with other requests still
    /// in flight the slot is released but the teardown is skipped — the
    /// survivors drain first (documented simplification: their departures
    /// stay scheduled, so the instance dies via its normal idle path).
    pub fn handle_request_timeout<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        id: InstanceId,
    ) {
        let became_idle;
        {
            let in_flight = self.instances.in_flight(id);
            debug_assert!(in_flight > 0);
            self.instances.set_in_flight(id, in_flight - 1);
            became_idle = in_flight == 1;
            if became_idle {
                let busy = self.now.since(self.instances.busy_since(id)).max(0.0);
                self.instances.finish_request(id, self.now, busy);
                if self.stats_started {
                    self.billed_seconds += busy;
                }
                self.busy_instances -= 1;
            }
        }
        self.in_flight -= 1;
        if became_idle {
            self.instances.terminate(id, self.now);
            let lifespan = self.instances.lifespan(id, self.now);
            self.router.remove(id);
            self.live_count -= 1;
            hooks.on_expire();
            if self.stats_started {
                self.instances_expired += 1;
                self.lifespan_stats.push(lifespan);
            }
            self.instances.release_slot(id);
        } else {
            self.router.release(id, false);
        }
        self.sync_levels();
        self.maybe_request_prewarm(sched, hooks);
    }

    /// Schedule the fault profile's degradation timeline. Engines call
    /// this once at run start; a profile with no windows schedules nothing,
    /// so the event sequence of fault-free runs is untouched.
    pub fn schedule_fault_timeline<S: Scheduler>(&mut self, sched: &mut S) {
        for (i, w) in self.fault.degradation.iter().enumerate() {
            sched
                .schedule(SimTime::from_secs(w.start), Event::DegradationStart { window: i as u32 });
            sched.schedule(SimTime::from_secs(w.end), Event::DegradationEnd { window: i as u32 });
        }
    }

    /// Handle a [`Event::DegradationStart`]: the window's capacity factor
    /// applies (overlapping windows compose by minimum).
    pub fn handle_degradation_start(&mut self, window: u32) {
        self.degradation_active[window as usize] = true;
        self.recompute_effective_cap();
    }

    /// Handle a [`Event::DegradationEnd`]: the window's factor lifts.
    pub fn handle_degradation_end(&mut self, window: u32) {
        self.degradation_active[window as usize] = false;
        self.recompute_effective_cap();
    }

    fn recompute_effective_cap(&mut self) {
        let mut factor: f64 = 1.0;
        for (w, active) in self.fault.degradation.iter().zip(&self.degradation_active) {
            if *active {
                factor = factor.min(w.capacity_factor);
            }
        }
        // Degradation only ever shrinks the cap; live instances above the
        // shrunken cap are not evicted — they drain and are not replaced.
        self.effective_max_concurrency = if factor >= 1.0 {
            self.max_concurrency
        } else {
            ((self.max_concurrency as f64) * factor).floor() as usize
        };
    }

    /// Handle a request departure from `id`: bill the busy period when the
    /// instance drains, return it to the warm pool, and schedule its
    /// idle-expiration via the hooks' keep-alive window.
    pub fn handle_departure<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        id: InstanceId,
    ) {
        let became_idle;
        let gen;
        {
            let in_flight = self.instances.in_flight(id);
            debug_assert!(in_flight > 0);
            self.instances.set_in_flight(id, in_flight - 1);
            became_idle = in_flight == 1;
            if became_idle {
                // The whole busy period is billed (the paper notes app
                // init — included in the cold busy period here — is
                // billed; slots of a concurrency-valued instance share the
                // one period).
                let busy = self.now.since(self.instances.busy_since(id)).max(0.0);
                gen = self.instances.finish_request(id, self.now, busy);
                if self.stats_started {
                    self.billed_seconds += busy;
                }
                self.busy_instances -= 1;
            } else {
                gen = self.instances.generation(id);
            }
        }
        self.in_flight -= 1;
        self.router.release(id, became_idle);
        if became_idle {
            let threshold = hooks.keep_alive(self.now.as_secs(), &mut self.rng);
            sched.schedule(self.now.after(threshold), Event::Expiration { id, gen });
        }
        self.sync_levels();
    }

    /// Handle an idle-expiration event (generation-guarded lazy
    /// cancellation: stale events — the instance was reused — are dropped).
    pub fn handle_expiration<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        id: InstanceId,
        gen: u64,
    ) {
        // A recycled slot means the instance terminated long ago — the
        // same verdict the old terminated-state check delivered.
        if !self.instances.is_resident(id)
            || self.instances.generation(id) != gen
            || self.instances.state(id) != InstanceState::Idle
        {
            return; // stale event (instance reused or already busy)
        }
        self.instances.terminate(id, self.now);
        let lifespan = self.instances.lifespan(id, self.now);
        let wasted_prewarm =
            self.instances.prewarmed(id) && self.instances.requests_served(id) == 0;
        self.router.remove(id);
        self.live_count -= 1;
        hooks.on_expire();
        if self.stats_started {
            self.instances_expired += 1;
            self.lifespan_stats.push(lifespan);
            if wasted_prewarm {
                self.wasted_prewarm_seconds += lifespan;
            }
        }
        self.instances.release_slot(id);
        self.sync_levels();
        self.maybe_request_prewarm(sched, hooks);
    }

    /// Force-evict up to `n` idle instances, oldest first, returning how
    /// many were evicted. Used by the cluster layer for memory-pressure
    /// and host-drain eviction; busy instances are never touched (they
    /// drain naturally, mirroring degradation semantics). Each victim is
    /// terminated exactly as an idle expiration would terminate it —
    /// its pending [`Event::Expiration`] becomes stale and is dropped by
    /// the generation/state guard — except that no replacement prewarm
    /// is requested (eviction means resources are scarce). Only
    /// scale-per-request engines evict; concurrent-routing pools decline
    /// and return 0.
    pub fn evict_idle<H: LifecycleHooks>(&mut self, hooks: &mut H, n: usize) -> usize {
        let mut evicted = 0;
        while evicted < n {
            let Some(id) = self.router.pop_oldest_idle() else {
                break;
            };
            self.instances.terminate(id, self.now);
            let lifespan = self.instances.lifespan(id, self.now);
            let wasted_prewarm =
                self.instances.prewarmed(id) && self.instances.requests_served(id) == 0;
            self.live_count -= 1;
            hooks.on_expire();
            if self.stats_started {
                self.instances_expired += 1;
                self.lifespan_stats.push(lifespan);
                if wasted_prewarm {
                    self.wasted_prewarm_seconds += lifespan;
                }
            }
            self.instances.release_slot(id);
            evicted += 1;
        }
        if evicted > 0 {
            self.sync_levels();
        }
        evicted
    }

    /// If prewarming is enabled and the warm pool just drained, ask the
    /// hooks for a predicted next arrival and schedule provisioning one
    /// lead ahead of it. At most one prewarm is in flight at a time.
    fn maybe_request_prewarm<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
    ) {
        if self.prewarm_lead <= 0.0 || self.prewarm_pending > 0 || self.router.has_capacity() {
            return;
        }
        if let Some(ready_at) = hooks.prewarm_ready_at(self.now.as_secs()) {
            if ready_at > self.now.as_secs() {
                let start = (ready_at - self.prewarm_lead).max(self.now.as_secs());
                sched.schedule(SimTime::from_secs(start), Event::Provision);
                self.prewarm_pending += 1;
            }
        }
    }

    /// Handle a [`Event::Provision`] trigger: start provisioning a fresh
    /// instance unless the pool recovered or admission fails. The instance
    /// occupies a server — and a `max_concurrency` slot — for the whole
    /// lead (speculation consumes real capacity, so at tight concurrency
    /// caps prewarming can turn would-be cold starts into rejections; that
    /// is the modeled cost of the speculation). It serves nothing until
    /// [`Event::ProvisioningDone`] one lead later; provisioning time is
    /// provider-initiated and not billed to the developer.
    pub fn handle_provision<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
    ) {
        if self.router.has_capacity()
            || self.live_count >= self.effective_max_concurrency
            || !hooks.admit_cold()
        {
            // Pool recovered, or no capacity for a speculative instance:
            // this prewarm is abandoned and a later drain may request a
            // fresh one.
            self.prewarm_pending = self.prewarm_pending.saturating_sub(1);
            return;
        }
        hooks.on_cold_start();
        let id = self.alloc_instance(true);
        self.live_count += 1;
        if self.stats_started {
            self.prewarm_starts += 1;
        }
        // `prewarm_pending` stays raised until ProvisioningDone: the
        // provisioning instance *is* the one prewarm in flight, so pool
        // drains during the lead window cannot spawn a second speculative
        // instance for the same predicted arrival.
        sched.schedule(self.now.after(self.prewarm_lead), Event::ProvisioningDone(id));
        self.sync_levels();
    }

    /// Handle provisioning completion: the prewarmed instance joins the
    /// warm pool and gets an idle-expiration window from the hooks.
    pub fn handle_provisioning_done<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        id: InstanceId,
    ) {
        self.prewarm_pending = self.prewarm_pending.saturating_sub(1);
        let gen = self.instances.provisioning_done(id, self.now);
        self.router.insert_idle(id);
        let threshold = hooks.prewarm_keep_alive(self.now.as_secs(), &mut self.rng);
        sched.schedule(self.now.after(threshold), Event::Expiration { id, gen });
        // No level changed (the instance was already live); accumulators
        // stay in sync without an update.
    }

    /// Seed a custom initial state before the run: `idle_ages[i]` idle
    /// instances already idle that long, and running instances with
    /// `running_remaining[i]` seconds of service left (the temporal
    /// simulator's warm pools, paper §4.2).
    pub fn seed_initial_state<S: Scheduler, H: LifecycleHooks>(
        &mut self,
        sched: &mut S,
        hooks: &mut H,
        idle_ages: &[f64],
        running_remaining: &[f64],
    ) {
        assert_eq!(self.now, SimTime::ZERO, "initial state must be set before run()");
        for &age in idle_ages {
            let id = self.alloc_instance(false);
            // Created in the past; approximate lifespan bookkeeping.
            self.instances.seed_idle(id, SimTime::ZERO);
            let gen = self.instances.generation(id);
            let threshold = hooks.keep_alive(0.0, &mut self.rng);
            let remaining = (threshold - age).max(0.0);
            self.router.insert_idle(id);
            self.live_count += 1;
            sched.schedule(SimTime::from_secs(remaining), Event::Expiration { id, gen });
        }
        for &rem in running_remaining {
            let id = self.alloc_instance(false);
            self.instances.seed_running(id);
            self.live_count += 1;
            self.in_flight += 1;
            self.busy_instances += 1;
            sched.schedule(SimTime::from_secs(rem.max(0.0)), Event::Departure(id));
        }
        self.sync_levels();
    }

    // ------------------------------------------------------------- results

    /// Close every accumulator at the horizon. Call once, after the event
    /// loop, before [`results`](Self::results).
    pub fn close(&mut self, horizon: SimTime) {
        self.now = horizon;
        self.server_count_tw.advance(horizon);
        self.running_tw.advance(horizon);
        self.busy_inst_tw.advance(horizon);
        self.count_dist.finish(horizon);
    }

    /// Produce the run's [`SimResults`] (after [`close`](Self::close)).
    pub fn results(&self) -> SimResults {
        let measured = self.now.since(self.stats_start).max(0.0);
        let served = self.cold_requests + self.warm_requests;
        let avg_server = self.server_count_tw.average();
        let avg_running = self.running_tw.average();
        // idle(t) = total(t) - busy_instances(t) at every instant, so the
        // idle average derives exactly. At concurrency 1 the busy-instance
        // level equals the in-flight level at all times, so the running
        // accumulator stands in for it (no third accumulator on the
        // scale-per-request hot path — bit-identical to the pre-core
        // engine, which derived idle from the running level).
        let avg_idle = avg_server
            - if self.track_busy_instances {
                self.busy_inst_tw.average()
            } else {
                avg_running
            };
        SimResults {
            measured_time: measured,
            total_requests: self.total_requests,
            cold_requests: self.cold_requests,
            warm_requests: self.warm_requests,
            rejected_requests: self.rejected_requests,
            cold_start_prob: if served > 0 {
                self.cold_requests as f64 / served as f64
            } else {
                0.0
            },
            rejection_prob: if self.total_requests > 0 {
                self.rejected_requests as f64 / self.total_requests as f64
            } else {
                0.0
            },
            avg_lifespan: self.lifespan_stats.mean(),
            instances_created: self.instances_created,
            instances_expired: self.instances_expired,
            avg_server_count: avg_server,
            avg_running_count: avg_running,
            avg_idle_count: avg_idle,
            max_server_count: self.server_count_tw.max_level(),
            wasted_capacity: if avg_server > 0.0 { avg_idle / avg_server } else { 0.0 },
            avg_response_time: self.response_stats.mean(),
            avg_warm_response_time: self.warm_response_stats.mean(),
            avg_cold_response_time: self.cold_response_stats.mean(),
            response_p50: self.response_p50.quantile(),
            response_p95: self.response_p95.quantile(),
            response_p99: self.response_p99.quantile(),
            billed_instance_seconds: self.billed_seconds,
            observed_arrival_rate: if measured > 0.0 {
                self.total_requests as f64 / measured
            } else {
                0.0
            },
            instance_count_pmf: self.count_dist.pmf(),
            prewarm_starts: self.prewarm_starts,
            wasted_prewarm_seconds: self.wasted_prewarm_seconds,
            failed_requests: self.failed_requests,
            timeout_requests: self.timeout_requests,
            coldstart_failures: self.coldstart_failures,
            retry_attempts: self.retry_attempts,
            retry_exhausted: self.retry_exhausted,
            wasted_work_seconds: self.wasted_work_seconds,
            goodput: if measured > 0.0 {
                served.saturating_sub(self.failed_requests + self.timeout_requests) as f64
                    / measured
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_core(concurrency: u32, prewarm_lead: f64) -> EngineCore {
        EngineCore::new(CoreParams {
            seed: 1,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            batch_size: None,
            max_concurrency: 1000,
            skip_initial: 0.0,
            concurrency_value: concurrency,
            prewarm_lead,
            instance_capacity: 16,
            retain_instances: true,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
        })
    }

    struct Fixed(f64);
    impl LifecycleHooks for Fixed {
        fn keep_alive(&mut self, _now: f64, _rng: &mut Rng) -> f64 {
            self.0
        }
    }

    #[test]
    fn per_request_router_pops_newest_and_reinserts_sorted() {
        let mut r = Router::new(1);
        r.insert_idle(InstanceId(0));
        r.insert_idle(InstanceId(2));
        r.insert_idle(InstanceId(1));
        assert_eq!(r.take_newest(), Some(InstanceId(2)));
        r.release(InstanceId(2), true);
        assert_eq!(r.pool_len(), 3);
        r.remove(InstanceId(1));
        assert_eq!(r.take_newest(), Some(InstanceId(2)));
        assert_eq!(r.take_newest(), Some(InstanceId(0)));
        assert_eq!(r.take_newest(), None);
        assert!(!r.has_capacity());
    }

    #[test]
    fn concurrent_router_tracks_slots() {
        let mut r = Router::new(3);
        r.on_cold_created(InstanceId(0)); // 2 spare slots
        assert_eq!(r.take_newest(), Some(InstanceId(0)));
        assert_eq!(r.take_newest(), Some(InstanceId(0)));
        assert_eq!(r.take_newest(), None);
        r.release(InstanceId(0), false);
        assert!(r.has_capacity());
        assert_eq!(r.take_newest(), Some(InstanceId(0)));
    }

    #[test]
    fn config_expiration_matches_simconfig_semantics() {
        let mut rng = Rng::new(2);
        let mut fixed = ConfigExpiration { threshold: 600.0, process: None };
        let before = rng.clone().next_u64();
        assert_eq!(fixed.keep_alive(0.0, &mut rng), 600.0);
        // Constant thresholds draw nothing — the bit-identity contract.
        assert_eq!(rng.next_u64(), before);
        let mut stochastic =
            ConfigExpiration { threshold: 600.0, process: Some(Process::exp_mean(100.0)) };
        let draws: Vec<f64> = (0..1000).map(|_| stochastic.keep_alive(0.0, &mut rng)).collect();
        let mean = draws.iter().sum::<f64>() / draws.len() as f64;
        assert!((mean - 100.0).abs() < 15.0, "mean={mean}");
    }

    #[test]
    fn cold_warm_expire_lifecycle_with_direct_core() {
        let mut core = mk_core(1, 0.0);
        let mut q = CalendarEventQueue::new();
        let mut hooks = Fixed(10.0);
        // Arrival at t=5: cold start (service 2 s), departs at 7, expires
        // at 17.
        core.set_now(SimTime::from_secs(5.0));
        core.handle_arrival(&mut q, &mut hooks);
        assert_eq!(core.live_counts(), (1, 1, 0));
        let (t, ev) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 7.0);
        let id = match ev {
            Event::Departure(id) => id,
            other => panic!("expected departure, got {other:?}"),
        };
        core.set_now(t);
        core.handle_departure(&mut q, &mut hooks, id);
        assert_eq!(core.live_counts(), (1, 0, 1));
        let (t, ev) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 17.0);
        match ev {
            Event::Expiration { id, gen } => {
                core.set_now(t);
                core.handle_expiration(&mut q, &mut hooks, id, gen);
            }
            other => panic!("expected expiration, got {other:?}"),
        }
        assert_eq!(core.live_counts(), (0, 0, 0));
        core.close(SimTime::from_secs(20.0));
        let r = core.results();
        assert_eq!((r.total_requests, r.cold_requests, r.instances_expired), (1, 1, 1));
        assert!((r.billed_instance_seconds - 2.0).abs() < 1e-12);
        assert!((r.avg_lifespan - 12.0).abs() < 1e-12);
    }

    struct PredictAt(f64);
    impl LifecycleHooks for PredictAt {
        fn keep_alive(&mut self, _now: f64, _rng: &mut Rng) -> f64 {
            1.0
        }
        fn prewarm_ready_at(&mut self, now: f64) -> Option<f64> {
            (self.0 > now).then_some(self.0)
        }
    }

    #[test]
    fn prewarm_provisions_ahead_of_prediction() {
        let mut core = mk_core(1, 3.0);
        let mut q = CalendarEventQueue::new();
        let mut hooks = PredictAt(30.0);
        // Cold start at t=5 -> departs 7 -> expires 8 (keep-alive 1 s) ->
        // predicted arrival 30 -> Provision at 27 -> done at 30.
        core.set_now(SimTime::from_secs(5.0));
        core.handle_arrival(&mut q, &mut hooks);
        let (t, ev) = q.pop().unwrap();
        let id = match ev {
            Event::Departure(id) => id,
            other => panic!("{other:?}"),
        };
        core.set_now(t);
        core.handle_departure(&mut q, &mut hooks, id);
        let (t, ev) = q.pop().unwrap();
        match ev {
            Event::Expiration { id, gen } => {
                core.set_now(t);
                core.handle_expiration(&mut q, &mut hooks, id, gen);
            }
            other => panic!("{other:?}"),
        }
        let (t, ev) = q.pop().unwrap();
        assert_eq!((t.as_secs(), ev), (27.0, Event::Provision));
        core.set_now(t);
        core.handle_provision(&mut q, &mut hooks);
        assert_eq!(core.live_counts(), (1, 0, 0));
        let (t, ev) = q.pop().unwrap();
        assert_eq!(t.as_secs(), 30.0);
        match ev {
            Event::ProvisioningDone(id) => {
                core.set_now(t);
                core.handle_provisioning_done(&mut q, &mut hooks, id);
            }
            other => panic!("{other:?}"),
        }
        // The prewarmed instance is warm and idle now.
        assert_eq!(core.live_counts(), (1, 0, 1));
        // It expires unused at 31 (prewarm keep-alive defaults to
        // keep_alive = 1 s): its whole lifespan is wasted prewarm time.
        let (t, ev) = q.pop().unwrap();
        match ev {
            Event::Expiration { id, gen } => {
                core.set_now(t);
                core.handle_expiration(&mut q, &mut hooks, id, gen);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(t.as_secs(), 31.0);
        core.close(SimTime::from_secs(40.0));
        let r = core.results();
        assert_eq!(r.prewarm_starts, 1);
        assert!((r.wasted_prewarm_seconds - 4.0).abs() < 1e-12, "{}", r.wasted_prewarm_seconds);
    }

    #[test]
    fn observer_records_spans_and_samples_without_perturbing_results() {
        use crate::telemetry::{Observer, SpanOutcome};
        let run = |observe: bool| {
            let mut core = mk_core(1, 0.0);
            if observe {
                core.set_observer(Observer::recording(0, 5.0));
            }
            let mut q = CalendarEventQueue::new();
            let mut hooks = Fixed(10.0);
            core.set_now(SimTime::from_secs(5.0));
            core.sample_tick(None);
            core.handle_arrival(&mut q, &mut hooks);
            while let Some((t, ev)) = q.pop() {
                core.set_now(t);
                core.sample_tick(None);
                match ev {
                    Event::Departure(id) => core.handle_departure(&mut q, &mut hooks, id),
                    Event::Expiration { id, gen } => {
                        core.handle_expiration(&mut q, &mut hooks, id, gen)
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            core.close(SimTime::from_secs(20.0));
            core.sample_tick(None);
            let rec = core.take_observer().and_then(Observer::into_recorder);
            (core.results(), rec)
        };
        let (base, no_rec) = run(false);
        let (observed, rec) = run(true);
        assert!(no_rec.is_none());
        // Attaching the observer changes nothing in the results.
        assert_eq!(format!("{base:?}"), format!("{observed:?}"));
        let rec = rec.unwrap();
        assert_eq!(rec.spans.len(), 1);
        assert_eq!(rec.spans[0].outcome, SpanOutcome::Cold);
        assert_eq!(rec.spans[0].instance, Some(0));
        assert_eq!((rec.spans[0].started_at, rec.spans[0].queued_at), (5.0, 5.0));
        // Ticks 0 and 5 fire at the first sampled event (t=5); 10 and 15
        // at the expiration (t=17); the close at 20 flushes the last one.
        let ticks: Vec<f64> = rec.samples.iter().map(|s| s.t).collect();
        assert_eq!(ticks, [0.0, 5.0, 10.0, 15.0, 20.0]);
        assert_eq!(rec.samples[2].live_instances, 1);
        assert_eq!(rec.samples[2].in_flight, 0);
        assert_eq!(rec.samples.last().unwrap().total_requests, 1);
    }

    #[test]
    fn prewarm_disabled_at_zero_lead() {
        let mut core = mk_core(1, 0.0);
        let mut q = CalendarEventQueue::new();
        let mut hooks = PredictAt(30.0);
        core.set_now(SimTime::from_secs(5.0));
        core.handle_arrival(&mut q, &mut hooks);
        let (t, Event::Departure(id)) = q.pop().unwrap() else { panic!() };
        core.set_now(t);
        core.handle_departure(&mut q, &mut hooks, id);
        let (t, Event::Expiration { id, gen }) = q.pop().unwrap() else { panic!() };
        core.set_now(t);
        core.handle_expiration(&mut q, &mut hooks, id, gen);
        // Lead 0: no Provision event despite the hook predicting one.
        assert!(q.pop().is_none());
    }
}
