//! Markovian transient model (Mahmoudi & Khazaei, "Temporal Performance
//! Modelling of Serverless Computing Platforms", WOSC 2020b): the
//! uniformization-based transient solution of the steady-state CTMC,
//! yielding time-bounded metrics from a custom initial state — the
//! analytical counterpart of `sim::ServerlessTemporalSimulator`.

use super::ctmc::Ctmc;
use super::steady_state::SteadyStateModel;

/// Transient metrics at a single time point.
#[derive(Debug, Clone, Copy)]
pub struct TransientMetrics {
    pub t: f64,
    pub avg_server_count: f64,
    pub avg_running_count: f64,
    pub avg_idle_count: f64,
    /// Probability an arrival at `t` would be a cold start (PASTA).
    pub cold_start_prob: f64,
}

/// Transient solver wrapping a [`SteadyStateModel`]'s CTMC.
pub struct TransientModel {
    pub model: SteadyStateModel,
    ctmc: Ctmc,
}

impl TransientModel {
    pub fn new(model: SteadyStateModel) -> Self {
        let ctmc = model.build_ctmc();
        TransientModel { model, ctmc }
    }

    /// Initial distribution concentrated at `(busy, idle)`.
    pub fn point_initial(&self, busy: usize, idle: usize) -> Vec<f64> {
        let ni = self.model.max_idle + 1;
        let nb = self.model.max_busy + 1;
        assert!(busy < nb && idle < ni, "initial state outside truncation");
        let mut v = vec![0.0; nb * ni];
        v[busy * ni + idle] = 1.0;
        v
    }

    /// Metrics of a distribution over states.
    fn metrics_of(&self, t: f64, pi: &[f64]) -> TransientMetrics {
        let ni = self.model.max_idle + 1;
        let cap = self.model.max_concurrency.min(self.model.max_busy);
        let mut busy = 0.0;
        let mut idle = 0.0;
        let mut p_cold = 0.0;
        let mut p_reject = 0.0;
        for (s, &p) in pi.iter().enumerate() {
            let b = s / ni;
            let i = s % ni;
            busy += p * b as f64;
            idle += p * i as f64;
            if i == 0 {
                if b < cap {
                    p_cold += p;
                } else {
                    p_reject += p;
                }
            }
        }
        TransientMetrics {
            t,
            avg_server_count: busy + idle,
            avg_running_count: busy,
            avg_idle_count: idle,
            cold_start_prob: p_cold / (1.0 - p_reject).max(1e-300),
        }
    }

    /// Evaluate metrics at each requested time (each solved from t=0; the
    /// chain is re-propagated incrementally between sorted time points).
    pub fn evaluate(&self, initial: &[f64], times: &[f64]) -> Vec<TransientMetrics> {
        let mut out = Vec::with_capacity(times.len());
        let mut sorted: Vec<f64> = times.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut current = initial.to_vec();
        let mut t_now = 0.0;
        for &t in &sorted {
            let dt = (t - t_now).max(0.0);
            if dt > 0.0 {
                current = self.ctmc.transient(&current, dt);
                t_now = t;
            }
            out.push(self.metrics_of(t, &current));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_approaches_steady_state() {
        let model = SteadyStateModel::new(0.9, 1.991, 120.0);
        let steady = model.solve();
        let tm = TransientModel::new(model);
        let init = tm.point_initial(0, 0);
        let ms = tm.evaluate(&init, &[2000.0]);
        let m = ms[0];
        assert!(
            (m.avg_server_count - steady.avg_server_count).abs()
                / steady.avg_server_count
                < 0.02,
            "transient {} vs steady {}",
            m.avg_server_count,
            steady.avg_server_count
        );
    }

    #[test]
    fn cold_pool_warms_up_over_time() {
        let model = SteadyStateModel::new(0.9, 1.991, 600.0);
        let tm = TransientModel::new(model);
        let init = tm.point_initial(0, 0);
        let ms = tm.evaluate(&init, &[1.0, 30.0, 300.0, 3000.0]);
        // Server count grows monotonically toward steady state from empty.
        assert!(ms[0].avg_server_count < ms[1].avg_server_count);
        assert!(ms[1].avg_server_count < ms[2].avg_server_count);
        // Cold start probability decays as the pool warms.
        assert!(ms[3].cold_start_prob < ms[0].cold_start_prob);
    }

    #[test]
    fn warm_initial_state_starts_high() {
        let model = SteadyStateModel::new(0.9, 1.991, 600.0);
        let tm = TransientModel::new(model);
        let init = tm.point_initial(0, 10);
        let ms = tm.evaluate(&init, &[0.5]);
        assert!(ms[0].avg_server_count > 9.0);
        assert!(ms[0].cold_start_prob < 0.05);
    }
}
