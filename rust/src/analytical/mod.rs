//! Markovian analytical performance models for scale-per-request serverless
//! platforms — the baseline SimFaaS supersedes (Mahmoudi & Khazaei 2020a/b)
//! and the cross-validation oracle for the simulator:
//!
//! * [`ctmc`] — sparse CTMC steady-state (Gauss–Seidel) and transient
//!   (uniformization) solvers.
//! * [`steady_state`] — the `(busy, idle)` birth–death model with
//!   exponential-expiration approximation.
//! * [`transient`] — time-bounded metrics from a custom initial state.
//! * [`compare`] — side-by-side model-vs-simulator reports (the
//!   model-validation workflow the paper describes in §3).

pub mod compare;
pub mod ctmc;
pub mod steady_state;
pub mod transient;

pub use compare::{compare_steady_state, compare_steady_state_markovian, ComparisonReport};
pub use ctmc::Ctmc;
pub use steady_state::{SteadyStateMetrics, SteadyStateModel};
pub use transient::{TransientMetrics, TransientModel};
