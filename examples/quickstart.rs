//! Quickstart: reproduce the paper's Table 1 steady-state analysis.
//!
//! Runs the `ServerlessSimulator` with the paper's example parameters
//! (Poisson(0.9/s) arrivals, exp warm/cold service with means 1.991 s /
//! 2.244 s, a 10-minute expiration threshold, a 1e6 s horizon and a 100 s
//! warm-up skip) and prints the Table-1 output rows next to the values the
//! paper reports.
//!
//! Run with: `cargo run --release --example quickstart`

use simfaas::sim::{ServerlessSimulator, SimConfig};

fn main() {
    let cfg = SimConfig::table1();
    println!("== SimFaaS quickstart: paper Table 1 ==");
    println!("Arrival Rate            0.9 req/s (Poisson)");
    println!("Warm Service Time       1.991 s (exponential)");
    println!("Cold Service Time       2.244 s (exponential)");
    println!("Expiration Threshold    600 s");
    println!("Simulation Time         1e6 s   Skip Initial: 100 s");
    println!();

    let t0 = std::time::Instant::now();
    let results = ServerlessSimulator::new(cfg).run();
    let wall = t0.elapsed();

    println!("{results}");
    println!("-- paper reference values --");
    println!("Cold Start Probability    0.14 %");
    println!("Rejection Probability     0 %");
    println!("Average Instance Lifespan 6307.7389 s");
    println!("Average Server Count      7.6795");
    println!("Average Running Servers   1.7902");
    println!("Average Idle Count        5.8893");
    println!();
    println!(
        "simulated 1e6 s ({} requests) in {:.3} s wall clock",
        results.total_requests,
        wall.as_secs_f64()
    );
}
