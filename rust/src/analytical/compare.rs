//! Model-vs-simulation comparison tooling (paper §3: SimFaaS was "created
//! ... for simplifying the process of validating a developed performance
//! model"). Runs the Markovian analytical model and the discrete-event
//! simulator on the same workload and reports side-by-side metrics with
//! percentage gaps — the workflow a performance-modelling researcher uses
//! SimFaaS for.

use super::steady_state::{SteadyStateMetrics, SteadyStateModel};
use crate::sim::{ServerlessSimulator, SimConfig, SimResults};

/// One compared metric.
#[derive(Debug, Clone)]
pub struct MetricComparison {
    pub name: &'static str,
    pub analytical: f64,
    pub simulated: f64,
}

impl MetricComparison {
    /// Percent gap of the analytical prediction vs the simulation.
    pub fn pct_error(&self) -> f64 {
        if self.simulated == 0.0 {
            if self.analytical == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            100.0 * ((self.analytical - self.simulated) / self.simulated).abs()
        }
    }
}

/// Full comparison report.
#[derive(Debug, Clone)]
pub struct ComparisonReport {
    pub rows: Vec<MetricComparison>,
}

impl ComparisonReport {
    pub fn build(a: &SteadyStateMetrics, s: &SimResults) -> Self {
        let rows = vec![
            MetricComparison {
                name: "cold_start_prob",
                analytical: a.cold_start_prob,
                simulated: s.cold_start_prob,
            },
            MetricComparison {
                name: "rejection_prob",
                analytical: a.rejection_prob,
                simulated: s.rejection_prob,
            },
            MetricComparison {
                name: "avg_server_count",
                analytical: a.avg_server_count,
                simulated: s.avg_server_count,
            },
            MetricComparison {
                name: "avg_running_count",
                analytical: a.avg_running_count,
                simulated: s.avg_running_count,
            },
            MetricComparison {
                name: "avg_idle_count",
                analytical: a.avg_idle_count,
                simulated: s.avg_idle_count,
            },
            MetricComparison {
                name: "wasted_capacity",
                analytical: a.wasted_capacity,
                simulated: s.wasted_capacity,
            },
            MetricComparison {
                name: "avg_lifespan",
                analytical: a.avg_lifespan,
                simulated: s.avg_lifespan,
            },
        ];
        ComparisonReport { rows }
    }

    pub fn to_table(&self) -> String {
        let mut out = String::from(
            "metric              analytical    simulated     |err|%\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{:<19} {:<13.6} {:<13.6} {:.2}%\n",
                r.name,
                r.analytical,
                r.simulated,
                r.pct_error()
            ));
        }
        out
    }
}

/// Run both the Markovian model and the simulator for an M/M workload and
/// produce the comparison. `sim_cfg` must use exponential arrival/service
/// for the comparison to be apples-to-apples; the expiration threshold in
/// the simulator stays deterministic (platform behaviour), exposing the
/// Markovian expiration approximation error.
pub fn compare_steady_state(sim_cfg: &SimConfig, mean_service: f64) -> ComparisonReport {
    let lambda = 1.0
        / sim_cfg
            .arrival
            .mean()
            .expect("arrival process must have a known mean");
    let mut model = SteadyStateModel::new(lambda, mean_service, sim_cfg.expiration_threshold);
    model.max_concurrency = sim_cfg.max_concurrency;
    let analytical = model.solve();
    let simulated = ServerlessSimulator::new(sim_cfg.clone()).run();
    ComparisonReport::build(&analytical, &simulated)
}

/// Same comparison but with the simulator *also* using exponential
/// expiration — the pure-Markovian cross-check where both sides should agree
/// tightly (validates both implementations).
pub fn compare_steady_state_markovian(
    sim_cfg: &SimConfig,
    mean_service: f64,
) -> ComparisonReport {
    use crate::sim::Process;
    let mut cfg = sim_cfg.clone();
    cfg.expiration_process = Some(Process::exp_mean(cfg.expiration_threshold));
    compare_steady_state(&cfg, mean_service)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Process;

    fn cfg() -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(0.9),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(1.991), // model has one mu
            expiration_threshold: 120.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 300_000.0,
            skip_initial: 500.0,
            seed: 77,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: crate::sim::fault::FaultProfile::disabled(),
            retry: crate::sim::retry::RetryPolicy::none(),
        }
    }

    #[test]
    fn markovian_cross_check_agrees() {
        // Exponential expiration on both sides: model and simulator are the
        // same stochastic system, so they must agree tightly.
        let report = compare_steady_state_markovian(&cfg(), 1.991);
        for row in &report.rows {
            if row.name == "rejection_prob" {
                continue; // both ~0
            }
            assert!(
                row.pct_error() < 6.0,
                "{} analytical={} simulated={} err={}%",
                row.name,
                row.analytical,
                row.simulated,
                row.pct_error()
            );
        }
    }

    #[test]
    fn deterministic_threshold_exposes_model_gap() {
        // With the real (deterministic) threshold the Markovian expiration
        // approximation misestimates cold-start probability — the gap that
        // motivates SimFaaS. We only assert the comparison runs and the
        // running-count row (insensitive to expiration) still matches.
        let report = compare_steady_state(&cfg(), 1.991);
        let running = report
            .rows
            .iter()
            .find(|r| r.name == "avg_running_count")
            .unwrap();
        assert!(running.pct_error() < 5.0);
        let table = report.to_table();
        assert!(table.contains("cold_start_prob"));
    }
}
