//! Minimal JSON writer (no serde in this environment). Only what the CLI
//! and benches need: objects, arrays, numbers, strings, bools.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (build with the `From` impls and [`JsonValue::object`]).
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn object() -> JsonValue {
        JsonValue::Object(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects).
    pub fn set<K: Into<String>, V: Into<JsonValue>>(&mut self, key: K, value: V) -> &mut Self {
        match self {
            JsonValue::Object(map) => {
                map.insert(key.into(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            JsonValue::String(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            JsonValue::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    JsonValue::String(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact JSON serialization (`value.to_string()` via the blanket
/// `ToString`).
impl std::fmt::Display for JsonValue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Number(v)
    }
}
impl From<u64> for JsonValue {
    fn from(v: u64) -> Self {
        JsonValue::Number(v as f64)
    }
}
impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Number(v as f64)
    }
}
impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        JsonValue::Bool(v)
    }
}
impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::String(v.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        JsonValue::String(v)
    }
}
impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(v: Vec<T>) -> Self {
        JsonValue::Array(v.into_iter().map(Into::into).collect())
    }
}

/// Serialize `SimResults` (used by the CLI's `--json` flag).
pub fn results_to_json(r: &crate::sim::SimResults) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("measured_time", r.measured_time)
        .set("total_requests", r.total_requests)
        .set("cold_requests", r.cold_requests)
        .set("warm_requests", r.warm_requests)
        .set("rejected_requests", r.rejected_requests)
        .set("cold_start_prob", r.cold_start_prob)
        .set("rejection_prob", r.rejection_prob)
        .set("avg_lifespan", r.avg_lifespan)
        .set("avg_server_count", r.avg_server_count)
        .set("avg_running_count", r.avg_running_count)
        .set("avg_idle_count", r.avg_idle_count)
        .set("max_server_count", r.max_server_count)
        .set("wasted_capacity", r.wasted_capacity)
        .set("avg_response_time", r.avg_response_time)
        .set("response_p50", r.response_p50)
        .set("response_p95", r.response_p95)
        .set("response_p99", r.response_p99)
        .set("billed_instance_seconds", r.billed_instance_seconds)
        .set("observed_arrival_rate", r.observed_arrival_rate)
        .set("instance_count_pmf", r.instance_count_pmf.clone());
    o
}

/// Serialize a fleet run (used by `simfaas fleet --json`): the aggregate
/// rollup, a per-function array, and (optionally) the priced cost totals.
pub fn fleet_to_json(
    results: &crate::fleet::FleetResults,
    cost: Option<&crate::fleet::FleetCostReport>,
) -> JsonValue {
    let a = &results.aggregate;
    let mut agg = JsonValue::object();
    agg.set("functions", a.functions)
        .set("measured_time", a.measured_time)
        .set("total_requests", a.total_requests)
        .set("cold_requests", a.cold_requests)
        .set("warm_requests", a.warm_requests)
        .set("rejected_requests", a.rejected_requests)
        .set("cap_rejections", a.cap_rejections)
        .set("cold_start_prob", a.cold_start_prob)
        .set("rejection_prob", a.rejection_prob)
        .set("avg_server_count", a.avg_server_count)
        .set("avg_running_count", a.avg_running_count)
        .set("avg_idle_count", a.avg_idle_count)
        .set("wasted_capacity", a.wasted_capacity)
        .set("avg_response_time", a.avg_response_time)
        .set("response_p50", a.response_p50)
        .set("response_p95", a.response_p95)
        .set("response_p99", a.response_p99)
        .set("billed_instance_seconds", a.billed_instance_seconds)
        .set("observed_arrival_rate", a.observed_arrival_rate);

    let functions: Vec<JsonValue> = results
        .names
        .iter()
        .zip(&results.per_function)
        .map(|(name, r)| {
            let mut f = JsonValue::object();
            f.set("name", name.as_str())
                .set("total_requests", r.total_requests)
                .set("cold_start_prob", r.cold_start_prob)
                .set("rejection_prob", r.rejection_prob)
                .set("avg_server_count", r.avg_server_count)
                .set("avg_response_time", r.avg_response_time)
                .set("billed_instance_seconds", r.billed_instance_seconds);
            f
        })
        .collect();

    let mut o = JsonValue::object();
    o.set("aggregate", agg).set("functions", JsonValue::Array(functions));
    if let Some(c) = cost {
        let mut cj = JsonValue::object();
        cj.set("requests", c.total.requests)
            .set("gb_seconds", c.total.gb_seconds)
            .set("request_charges", c.total.request_charges)
            .set("runtime_charges", c.total.runtime_charges)
            .set("developer_total", c.total.developer_total())
            .set("provider_infra_cost", c.total.provider_infra_cost);
        o.set("cost", cj);
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_encoding() {
        assert_eq!(JsonValue::from(1.5).to_string(), "1.5");
        assert_eq!(JsonValue::from(true).to_string(), "true");
        assert_eq!(JsonValue::Null.to_string(), "null");
        assert_eq!(JsonValue::from("a\"b\n").to_string(), "\"a\\\"b\\n\"");
        assert_eq!(JsonValue::Number(f64::NAN).to_string(), "null");
    }

    #[test]
    fn object_and_array_encoding() {
        let mut o = JsonValue::object();
        o.set("b", 2u64).set("a", vec![1.0, 2.5]);
        // BTreeMap: keys sorted.
        assert_eq!(o.to_string(), r#"{"a":[1,2.5],"b":2}"#);
    }

    #[test]
    fn fleet_json_has_aggregate_and_functions() {
        use crate::fleet::{fleet_cost, FleetConfig, PolicySpec};
        use crate::sim::SimConfig;
        let cfg = FleetConfig::from_sim_configs(
            &[SimConfig::table1().with_horizon(2_000.0)],
            PolicySpec::fixed(600.0),
        );
        let res = cfg.run();
        let cost = fleet_cost(&cfg, &res, &crate::cost::PricingTable::aws_lambda());
        let j = fleet_to_json(&res, Some(&cost)).to_string();
        assert!(j.contains("\"aggregate\":{"));
        assert!(j.contains("\"functions\":["));
        assert!(j.contains("\"cold_start_prob\""));
        assert!(j.contains("\"cost\":{"));
        assert!(j.contains("\"developer_total\""));
    }

    #[test]
    fn results_json_has_key_fields() {
        use crate::sim::{ServerlessSimulator, SimConfig};
        let mut cfg = SimConfig::table1();
        cfg.horizon = 2_000.0;
        let r = ServerlessSimulator::new(cfg).run();
        let j = results_to_json(&r).to_string();
        assert!(j.contains("\"cold_start_prob\""));
        assert!(j.contains("\"instance_count_pmf\":["));
    }
}
