//! Fleet-internal discrete-event machinery: a function-tagged event queue
//! and the per-function engine.
//!
//! [`FunctionEngine`] is the fleet counterpart of
//! [`crate::sim::ServerlessSimulator`]: the same scale-per-request model
//! (newest-first routing, generation-guarded lazy expiration, lazy level
//! sync, O(1) bookkeeping — see DESIGN.md §Perf), restructured as an event
//! *handler* instead of a self-contained loop so that
//!
//! * N engines can interleave on one [`FleetQueue`] when a fleet-wide
//!   concurrency cap couples them through admission ([`FleetGate`]), and
//! * expiration thresholds come from a pluggable
//!   [`super::policy::KeepAlivePolicy`] instead of a fixed config field.
//!
//! **Bit-identity contract**: with a [`super::policy::FixedExpiration`]
//! policy and an unbounded gate, an engine consumes its RNG in exactly the
//! same sequence as `ServerlessSimulator` (first-arrival draw, per-epoch
//! batch/service draws, next-arrival draw) and schedules events in the same
//! order, so a 1-function fleet reproduces the core simulator's
//! [`SimResults`] bit-for-bit on the same seed. `fleet::simulator` pins
//! this with a regression test; any change to the draw order here must keep
//! it green.

use super::policy::KeepAlivePolicy;
use super::simulator::{ArrivalMode, FunctionSpec};
use crate::sim::event::Event;
use crate::sim::hist::CountDistribution;
use crate::sim::instance::{FunctionInstance, InstanceId, InstanceState};
use crate::sim::metrics::{OnlineStats, P2Quantile, TimeWeighted};
use crate::sim::process::Process;
use crate::sim::results::SimResults;
use crate::sim::rng::Rng;
use crate::sim::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

/// A scheduled fleet event: the core [`Event`] plus the index of the
/// function it belongs to.
#[derive(Debug, Clone)]
struct Scheduled {
    at: SimTime,
    seq: u64,
    func: u32,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: reverse for earliest-first, then insertion order among
        // equal times — the same deterministic tie-break as sim::event.
        other.at.cmp(&self.at).then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Future event list shared by every function in a fleet run.
#[derive(Debug, Default)]
pub(crate) struct FleetQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl FleetQueue {
    pub(crate) fn with_capacity(cap: usize) -> Self {
        FleetQueue { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    #[inline]
    pub(crate) fn schedule(&mut self, at: SimTime, func: u32, event: Event) {
        debug_assert!(at.is_finite(), "cannot schedule at infinity");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, func, event });
    }

    #[inline]
    pub(crate) fn pop(&mut self) -> Option<(SimTime, u32, Event)> {
        self.heap.pop().map(|s| (s.at, s.func, s.event))
    }
}

/// Fleet-wide admission state: the shared live-instance count checked (and
/// charged) on every cold start. With `cap = usize::MAX` the gate never
/// binds and functions evolve independently — the sharded runner's case.
#[derive(Debug, Clone)]
pub(crate) struct FleetGate {
    pub live: usize,
    pub cap: usize,
    /// Rejections attributable to the fleet cap alone (the per-function
    /// concurrency limit would have admitted the request).
    pub cap_rejections: u64,
}

impl FleetGate {
    pub(crate) fn unbounded() -> Self {
        FleetGate { live: 0, cap: usize::MAX, cap_rejections: 0 }
    }

    pub(crate) fn capped(cap: usize) -> Self {
        FleetGate { live: 0, cap, cap_rejections: 0 }
    }
}

/// Per-function arrival source.
pub(crate) enum ArrivalRuntime {
    /// Inter-arrival process (the core simulator's model).
    Process(Process),
    /// Replay of pre-materialized absolute arrival times (sorted), e.g. a
    /// diurnal trace from `workload::azure`.
    Trace { times: Arc<Vec<f64>>, next: usize },
}

/// One function's simulation state within a fleet run.
pub(crate) struct FunctionEngine {
    func: u32,
    arrival: ArrivalRuntime,
    batch_size: Option<Process>,
    warm_service: Process,
    cold_service: Process,
    max_concurrency: usize,
    policy: Box<dyn KeepAlivePolicy>,
    rng: Rng,
    now: SimTime,

    instances: Vec<FunctionInstance>,
    idle_pool: Vec<InstanceId>,
    live_count: usize,
    busy_count: usize,

    stats_started: bool,
    stats_start: SimTime,
    total_requests: u64,
    cold_requests: u64,
    warm_requests: u64,
    rejected_requests: u64,
    instances_created: u64,
    instances_expired: u64,
    server_count_tw: TimeWeighted,
    running_tw: TimeWeighted,
    count_dist: CountDistribution,
    lifespan_stats: OnlineStats,
    response_stats: OnlineStats,
    warm_response_stats: OnlineStats,
    cold_response_stats: OnlineStats,
    response_p50: P2Quantile,
    response_p95: P2Quantile,
    response_p99: P2Quantile,
    billed_seconds: f64,
}

impl FunctionEngine {
    pub(crate) fn new(
        func: u32,
        spec: &FunctionSpec,
        policy: Box<dyn KeepAlivePolicy>,
        skip_initial: f64,
    ) -> Self {
        let arrival = match &spec.arrival {
            // Fresh process state per engine (the fleet analogue of
            // `SimConfig::replica_with_seed`): shards never share mutable
            // process state, which the determinism contract requires.
            ArrivalMode::Process(p) => ArrivalRuntime::Process(p.replica()),
            ArrivalMode::Trace(t) => ArrivalRuntime::Trace { times: Arc::clone(t), next: 0 },
        };
        let start = SimTime::ZERO;
        FunctionEngine {
            func,
            arrival,
            batch_size: spec.batch_size.as_ref().map(Process::replica),
            warm_service: spec.warm_service.replica(),
            cold_service: spec.cold_service.replica(),
            max_concurrency: spec.max_concurrency,
            policy,
            rng: Rng::new(spec.seed),
            now: start,
            instances: Vec::with_capacity(64),
            idle_pool: Vec::with_capacity(16),
            live_count: 0,
            busy_count: 0,
            stats_started: skip_initial <= 0.0,
            stats_start: SimTime::from_secs(skip_initial.max(0.0)),
            total_requests: 0,
            cold_requests: 0,
            warm_requests: 0,
            rejected_requests: 0,
            instances_created: 0,
            instances_expired: 0,
            server_count_tw: TimeWeighted::new(start, 0.0),
            running_tw: TimeWeighted::new(start, 0.0),
            count_dist: CountDistribution::new(start, 0),
            lifespan_stats: OnlineStats::new(),
            response_stats: OnlineStats::new(),
            warm_response_stats: OnlineStats::new(),
            cold_response_stats: OnlineStats::new(),
            response_p50: P2Quantile::new(0.5),
            response_p95: P2Quantile::new(0.95),
            response_p99: P2Quantile::new(0.99),
            billed_seconds: 0.0,
        }
    }

    /// Schedule this function's first arrival. For process arrivals this
    /// consumes one draw — the same first draw `ServerlessSimulator::run`
    /// makes before entering its loop.
    pub(crate) fn schedule_first_arrival(&mut self, queue: &mut FleetQueue) {
        match &mut self.arrival {
            ArrivalRuntime::Process(p) => {
                let first = p.sample(&mut self.rng);
                queue.schedule(SimTime::from_secs(first), self.func, Event::Arrival);
            }
            ArrivalRuntime::Trace { times, next } => {
                if let Some(&t) = times.first() {
                    queue.schedule(SimTime::from_secs(t), self.func, Event::Arrival);
                    *next = 1;
                }
            }
        }
    }

    #[inline]
    pub(crate) fn set_now(&mut self, t: SimTime) {
        self.now = t;
    }

    pub(crate) fn maybe_start_stats(&mut self, event_time: SimTime) {
        if self.stats_started || event_time < self.stats_start {
            return;
        }
        let boundary = self.stats_start;
        self.server_count_tw.advance(boundary);
        self.running_tw.advance(boundary);
        self.count_dist.finish(boundary);
        self.server_count_tw.reset_at(boundary);
        self.running_tw.reset_at(boundary);
        self.count_dist.reset_at(boundary);
        self.stats_started = true;
    }

    fn sync_levels(&mut self) {
        self.server_count_tw.update(self.now, self.live_count as f64);
        self.running_tw.update(self.now, self.busy_count as f64);
        self.count_dist.update(self.now, self.live_count);
    }

    fn record_response(&mut self, rt: f64, cold: bool) {
        if !self.stats_started {
            return;
        }
        self.response_stats.push(rt);
        if cold {
            self.cold_response_stats.push(rt);
        } else {
            self.warm_response_stats.push(rt);
        }
        self.response_p50.push(rt);
        self.response_p95.push(rt);
        self.response_p99.push(rt);
    }

    fn alloc_instance(&mut self) -> InstanceId {
        let id = InstanceId(self.instances.len() as u64);
        self.instances.push(FunctionInstance::cold_start(id, self.now));
        id
    }

    pub(crate) fn handle_arrival(&mut self, queue: &mut FleetQueue, gate: &mut FleetGate) {
        // Adaptive policies observe every arrival epoch (no RNG use, so the
        // FixedExpiration bit-identity contract is unaffected).
        self.policy.on_arrival(self.now.as_secs());
        let batch = match &self.batch_size {
            None => 1,
            Some(p) => {
                let k = p.sample(&mut self.rng).round();
                if k < 1.0 {
                    1
                } else {
                    k as u64
                }
            }
        };
        let (live0, busy0) = (self.live_count, self.busy_count);
        for _ in 0..batch {
            self.route_one_request(queue, gate);
        }
        if self.live_count != live0 || self.busy_count != busy0 {
            self.sync_levels();
        }
        // Schedule the next arrival epoch.
        match &mut self.arrival {
            ArrivalRuntime::Process(p) => {
                let gap = p.sample(&mut self.rng);
                queue.schedule(self.now.after(gap), self.func, Event::Arrival);
            }
            ArrivalRuntime::Trace { times, next } => {
                if let Some(&t) = times.get(*next) {
                    queue.schedule(SimTime::from_secs(t), self.func, Event::Arrival);
                    *next += 1;
                }
            }
        }
    }

    fn route_one_request(&mut self, queue: &mut FleetQueue, gate: &mut FleetGate) {
        if self.stats_started {
            self.total_requests += 1;
        }
        if let Some(id) = self.idle_pool.pop() {
            // Warm start: newest idle instance.
            let inst = &mut self.instances[id.0 as usize];
            inst.start_warm(self.now);
            self.busy_count += 1;
            let service = self.warm_service.sample(&mut self.rng);
            queue.schedule(self.now.after(service), self.func, Event::Departure(id));
            if self.stats_started {
                self.warm_requests += 1;
                self.record_response(service, false);
            }
        } else if self.live_count < self.max_concurrency && gate.live < gate.cap {
            // Cold start: admit against both the per-function concurrency
            // limit and the fleet-wide cap.
            gate.live += 1;
            let id = self.alloc_instance();
            self.live_count += 1;
            self.busy_count += 1;
            if self.stats_started {
                self.instances_created += 1;
            }
            let service = self.cold_service.sample(&mut self.rng);
            queue.schedule(self.now.after(service), self.func, Event::Departure(id));
            if self.stats_started {
                self.cold_requests += 1;
                self.record_response(service, true);
            }
        } else if self.stats_started {
            self.rejected_requests += 1;
            if self.live_count < self.max_concurrency {
                // Only the shared cap blocked this request — the coupling
                // the fleet aggregate reports separately.
                gate.cap_rejections += 1;
            }
        }
    }

    pub(crate) fn handle_departure(&mut self, queue: &mut FleetQueue, id: InstanceId) {
        let gen;
        {
            let inst = &mut self.instances[id.0 as usize];
            let busy = self.now.since(inst.busy_since).max(0.0);
            gen = inst.finish_request(self.now, busy);
            if self.stats_started {
                self.billed_seconds += busy;
            }
        }
        self.busy_count -= 1;
        match self.idle_pool.binary_search(&id) {
            Err(pos) => self.idle_pool.insert(pos, id),
            Ok(_) => unreachable!("instance already idle"),
        }
        let threshold = self.policy.keep_alive(self.now.as_secs(), &mut self.rng);
        queue.schedule(self.now.after(threshold), self.func, Event::Expiration { id, gen });
        self.sync_levels();
    }

    pub(crate) fn handle_expiration(&mut self, id: InstanceId, gen: u64, gate: &mut FleetGate) {
        let inst = &mut self.instances[id.0 as usize];
        if inst.generation != gen || inst.state != InstanceState::Idle {
            return; // stale event (instance reused or already busy)
        }
        inst.terminate(self.now);
        let lifespan = inst.lifespan(self.now);
        if let Ok(pos) = self.idle_pool.binary_search(&id) {
            self.idle_pool.remove(pos);
        }
        self.live_count -= 1;
        gate.live -= 1;
        if self.stats_started {
            self.instances_expired += 1;
            self.lifespan_stats.push(lifespan);
        }
        self.sync_levels();
    }

    /// Close accumulators at the horizon and produce this function's
    /// results (field-for-field the computation in
    /// `ServerlessSimulator::finish`).
    pub(crate) fn finish(&mut self, horizon: SimTime) -> SimResults {
        self.now = horizon;
        self.server_count_tw.advance(horizon);
        self.running_tw.advance(horizon);
        self.count_dist.finish(horizon);

        let measured = horizon.since(self.stats_start).max(0.0);
        let served = self.cold_requests + self.warm_requests;
        let avg_server = self.server_count_tw.average();
        let avg_running = self.running_tw.average();
        let avg_idle = avg_server - avg_running;
        SimResults {
            measured_time: measured,
            total_requests: self.total_requests,
            cold_requests: self.cold_requests,
            warm_requests: self.warm_requests,
            rejected_requests: self.rejected_requests,
            cold_start_prob: if served > 0 {
                self.cold_requests as f64 / served as f64
            } else {
                0.0
            },
            rejection_prob: if self.total_requests > 0 {
                self.rejected_requests as f64 / self.total_requests as f64
            } else {
                0.0
            },
            avg_lifespan: self.lifespan_stats.mean(),
            instances_created: self.instances_created,
            instances_expired: self.instances_expired,
            avg_server_count: avg_server,
            avg_running_count: avg_running,
            avg_idle_count: avg_idle,
            max_server_count: self.server_count_tw.max_level(),
            wasted_capacity: if avg_server > 0.0 { avg_idle / avg_server } else { 0.0 },
            avg_response_time: self.response_stats.mean(),
            avg_warm_response_time: self.warm_response_stats.mean(),
            avg_cold_response_time: self.cold_response_stats.mean(),
            response_p50: self.response_p50.quantile(),
            response_p95: self.response_p95.quantile(),
            response_p99: self.response_p99.quantile(),
            billed_instance_seconds: self.billed_seconds,
            observed_arrival_rate: if measured > 0.0 {
                self.total_requests as f64 / measured
            } else {
                0.0
            },
            instance_count_pmf: self.count_dist.pmf(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_queue_orders_by_time_then_insertion() {
        let mut q = FleetQueue::with_capacity(8);
        q.schedule(SimTime::from_secs(2.0), 0, Event::Arrival);
        q.schedule(SimTime::from_secs(1.0), 1, Event::Arrival);
        q.schedule(SimTime::from_secs(1.0), 2, Event::Arrival);
        let (t1, f1, _) = q.pop().unwrap();
        let (t2, f2, _) = q.pop().unwrap();
        let (t3, f3, _) = q.pop().unwrap();
        assert_eq!((t1.as_secs(), f1), (1.0, 1));
        assert_eq!((t2.as_secs(), f2), (1.0, 2)); // insertion order on tie
        assert_eq!((t3.as_secs(), f3), (2.0, 0));
        assert!(q.pop().is_none());
    }

    #[test]
    fn gate_defaults() {
        let g = FleetGate::unbounded();
        assert_eq!(g.cap, usize::MAX);
        let g = FleetGate::capped(5);
        assert_eq!(g.cap, 5);
        assert_eq!(g.live, 0);
    }
}
