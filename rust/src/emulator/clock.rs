//! Virtual (scaled) clock for the platform emulator.
//!
//! The paper's validation experiments span 28-hour windows; the emulator
//! compresses them by running on a virtual clock that advances `scale`
//! seconds per wall-clock second. All platform timings (arrival schedules,
//! provisioning delays, expiration thresholds, IO sleeps) are expressed in
//! *virtual* seconds and converted at the sleep sites; compute payload
//! executions take the wall time they take, and their duration is measured
//! and reported in virtual seconds — so PJRT execution time becomes a
//! realistic, noisy service-time component, exactly the role real Lambda
//! function bodies play in the paper's testbed.

use std::time::{Duration, Instant};

/// A monotone scaled clock. Cheap to clone (copies the epoch).
#[derive(Debug, Clone, Copy)]
pub struct VirtualClock {
    epoch: Instant,
    scale: f64,
}

impl VirtualClock {
    /// `scale` = virtual seconds per wall second (e.g. 1000.0).
    pub fn new(scale: f64) -> Self {
        assert!(scale > 0.0);
        VirtualClock { epoch: Instant::now(), scale }
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Current virtual time (seconds since construction).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * self.scale
    }

    /// Sleep until virtual time `t` (no-op if already past).
    pub fn sleep_until(&self, t: f64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_secs_f64((t - now) / self.scale));
        }
    }

    /// Sleep for `dt` virtual seconds.
    pub fn sleep(&self, dt: f64) {
        if dt > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(dt / self.scale));
        }
    }

    /// Convert a virtual duration to wall-clock.
    pub fn to_wall(&self, dt_virtual: f64) -> Duration {
        Duration::from_secs_f64((dt_virtual / self.scale).max(0.0))
    }

    /// Convert a wall duration to virtual seconds.
    pub fn to_virtual(&self, wall: Duration) -> f64 {
        wall.as_secs_f64() * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_scaled() {
        let c = VirtualClock::new(1000.0);
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(20));
        let t1 = c.now();
        // 20 ms wall = 20 virtual seconds (generous jitter bounds for CI).
        assert!(t1 - t0 >= 15.0 && t1 - t0 < 200.0, "dt={}", t1 - t0);
    }

    #[test]
    fn sleep_until_past_is_noop() {
        let c = VirtualClock::new(100.0);
        let before = Instant::now();
        c.sleep_until(0.0);
        assert!(before.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn conversions_roundtrip() {
        let c = VirtualClock::new(250.0);
        let wall = c.to_wall(500.0);
        assert!((wall.as_secs_f64() - 2.0).abs() < 1e-9);
        assert!((c.to_virtual(wall) - 500.0).abs() < 1e-9);
    }
}
