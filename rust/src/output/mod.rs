//! Terminal/file output substrate: ASCII tables, terminal plots (the
//! figures render as text series so every paper figure regenerates without a
//! plotting stack), CSV and a minimal JSON writer (no external
//! serialization crates are available in this environment).

pub mod json;
pub mod plot;
pub mod table;

pub use json::JsonValue;
pub use plot::{ascii_histogram, ascii_lines, Series};
pub use table::Table;

use anyhow::Result;
use std::io::Write;
use std::path::Path;

/// Write rows of floats as CSV with a header.
pub fn write_csv_rows<P: AsRef<Path>>(path: P, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_rows_roundtrip_textually() {
        let dir = std::env::temp_dir().join(format!("simfaas-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rows.csv");
        write_csv_rows(&path, &["a", "b"], &[vec![1.0, 2.0], vec![3.5, 4.25]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3.5,4.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
