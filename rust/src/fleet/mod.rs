//! Fleet-level simulation: an entire serverless tenant mix in one run.
//!
//! The paper's `ServerlessSimulator` models a single function; providers
//! tune their platform against a *mix* of tenants (the paper's own framing:
//! "tailor their platforms to be workload-aware"). This subsystem simulates
//! N heterogeneous functions — from any [`crate::workload::TraceSource`]:
//! an Azure-style [`crate::workload::SyntheticTrace`], a real ingested
//! [`crate::workload::AzureDataset`], explicit per-function
//! [`crate::sim::SimConfig`]s, or a recorded workload — under a pluggable
//! keep-alive policy ([`KeepAlivePolicy`]), with an optional fleet-wide
//! concurrent-instance cap that couples functions through
//! admission/rejection.
//!
//! * [`policy`] — the [`KeepAlivePolicy`] trait, the paper's
//!   [`FixedExpiration`] model, and the Azure-style
//!   [`HybridHistogramPolicy`] with its head-percentile prewarm arm.
//! * [`simulator`] — [`FleetConfig`] / [`FleetResults`]: sharded execution
//!   for independent functions (bit-identical for any thread count),
//!   single-queue coupled execution when the fleet cap binds, per-function
//!   and aggregate metrics (including prewarm starts / wasted-prewarm time
//!   when `FleetConfig::prewarm_lead` is set), and the [`fleet_cost`]
//!   pricing rollup. With `FleetConfig::controller` set, an autoscaling
//!   controller ([`crate::control`]) moves the fleet cap or the cluster
//!   host set on a fixed simulated-time tick through the engine's
//!   `ScalableCapacity` seam.
//!
//! The per-function engine itself is a configuration of the unified
//! lifecycle core ([`crate::sim::core`]): policy-driven keep-alive,
//! gate-checked admission and prewarm events all plug in through
//! [`crate::sim::core::LifecycleHooks`].
//!
//! `whatif::keepalive_policy_comparison` sweeps a fixed-threshold grid
//! against adaptive policies on the same mix; the `fleet` CLI subcommand
//! and the `fleet/500_functions` bench case in `benches/engine_throughput`
//! drive it end to end.

mod engine;
pub mod policy;
pub mod simulator;

pub use policy::{
    FixedExpiration, HybridHistogramPolicy, KeepAlivePolicy, PolicyKind, PolicySpec,
    StochasticExpiration,
};
pub use simulator::{
    fleet_cost, ArrivalMode, FleetAggregate, FleetConfig, FleetCostReport, FleetResults,
    FunctionSpec,
};
