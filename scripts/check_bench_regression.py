#!/usr/bin/env python3
"""Fail CI when the quick engine bench regresses against the committed
baseline.

Usage: check_bench_regression.py BASELINE.json FRESH.json [--tolerance 0.25]
       [--require CASE ...]

Both files are BENCH_engine.json records written by
`benches/engine_throughput.rs` ({"events_per_sec": {case: rate, ...}}).
Every case present in the baseline must exist in the fresh record and reach
at least (1 - tolerance) x the baseline rate. Cases only present in the
fresh record are reported but never fail (new bench cases land before their
baseline does).

--require CASE (repeatable) additionally fails the gate when CASE is absent
from the fresh record even if the baseline no longer lists it — use it to
pin cases that must keep being measured (a bench refactor that silently
drops a case would otherwise pass once its baseline entry is pruned).
"""

import argparse
import json
import sys


def load_metrics(path: str) -> dict:
    """Load a bench record and return its events_per_sec map.

    A record without the key fails with a message naming the key and the
    file (a renamed or half-written record must not silently pass the
    gate as "no cases to compare").
    """
    with open(path) as f:
        record = json.load(f)
    if "events_per_sec" not in record:
        print(
            f"error: {path} is missing the 'events_per_sec' key "
            f"(top-level keys: {', '.join(sorted(record)) or 'none'})",
            file=sys.stderr,
        )
        sys.exit(2)
    return record["events_per_sec"]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed fractional regression vs the baseline (default 0.25)",
    )
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="CASE",
        help="fail if CASE is missing from the fresh record (repeatable)",
    )
    args = ap.parse_args()

    baseline = load_metrics(args.baseline)
    fresh = load_metrics(args.fresh)

    if not baseline:
        print(f"error: {args.baseline} has no events_per_sec cases", file=sys.stderr)
        return 2

    failures = []
    for case, base_rate in sorted(baseline.items()):
        floor = base_rate * (1.0 - args.tolerance)
        got = fresh.get(case)
        if got is None:
            failures.append(
                f"{case}: missing key in {args.fresh} "
                f"(baseline has {base_rate:.3g} events/s for it)"
            )
            continue
        verdict = "ok" if got >= floor else "REGRESSED"
        print(
            f"{case}: {got:.3g} events/s vs baseline {base_rate:.3g} "
            f"(floor {floor:.3g}) -> {verdict}"
        )
        if got < floor:
            failures.append(
                f"{case}: {got:.3g} < floor {floor:.3g} "
                f"({args.tolerance:.0%} below baseline {base_rate:.3g})"
            )
    for case in sorted(set(fresh) - set(baseline)):
        print(f"{case}: {fresh[case]:.3g} events/s (no baseline yet)")
    for case in sorted(set(args.require) - set(fresh)):
        # Name every file searched: the record the case is missing from and
        # whether the committed baseline still expects it (a bench refactor
        # dropped the case) or never had it (a typo'd --require).
        if case in baseline:
            detail = f"baseline {args.baseline} still lists it at {baseline[case]:.3g} events/s"
        else:
            detail = f"absent from baseline {args.baseline} too"
        failures.append(f"{case}: required case missing from {args.fresh} ({detail})")

    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
