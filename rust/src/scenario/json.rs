//! `ScenarioSpec` ↔ JSON, built on [`crate::output::json::JsonValue`].
//!
//! The schema is documented in DESIGN.md §Scenario API; bundled examples
//! live under `examples/scenarios/`. Reader philosophy matches the CLI's
//! flag handling: every field is optional with the documented (Table 1 /
//! historical CLI) default, **unknown keys are errors** — the same
//! typo-catching contract `cli::Args::check_unknown` gives flags — and all
//! error messages name the offending path.

use super::spec::{
    CostSpec, ExperimentSpec, FleetScenario, KeepAliveSpec, ObservabilitySpec, OutputFormat,
    OutputSpec, PlatformSpec, ProcessSpec, ReliabilitySpec, RunSpec, ScenarioSpec, SourceSpec,
    WorkloadSpec,
};
use crate::cluster::{ClusterConfig, SchedulerSpec};
use crate::control::ControllerSpec;
use crate::cost::Provider;
use crate::fleet::PolicyKind;
use crate::sim::fault::{DegradationWindow, FaultProfile, TimeoutAction};
use crate::sim::retry::{Backoff, RetryPolicy};
use crate::output::json::JsonValue;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

type Obj = BTreeMap<String, JsonValue>;

fn as_obj<'a>(v: &'a JsonValue, what: &str) -> Result<&'a Obj> {
    v.as_object().with_context(|| format!("{what} must be a JSON object"))
}

/// Reject unknown keys (catches typos the defaults would otherwise
/// silently swallow — the JSON analogue of an unknown CLI flag).
fn check_keys(o: &Obj, allowed: &[&str], what: &str) -> Result<()> {
    for k in o.keys() {
        if !allowed.contains(&k.as_str()) {
            bail!("{what}: unknown key {k:?} (expected one of: {})", allowed.join(", "));
        }
    }
    Ok(())
}

fn f64_field(o: &Obj, key: &str, what: &str, default: f64) -> Result<f64> {
    match o.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().with_context(|| format!("{what}.{key} must be a number")),
    }
}

fn req_f64(o: &Obj, key: &str, what: &str) -> Result<f64> {
    o.get(key)
        .with_context(|| format!("{what}.{key} is required"))?
        .as_f64()
        .with_context(|| format!("{what}.{key} must be a number"))
}

fn u64_field(o: &Obj, key: &str, what: &str, default: u64) -> Result<u64> {
    match o.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .with_context(|| format!("{what}.{key} must be a non-negative integer")),
    }
}

fn usize_field(o: &Obj, key: &str, what: &str, default: usize) -> Result<usize> {
    Ok(u64_field(o, key, what, default as u64)? as usize)
}

fn bool_field(o: &Obj, key: &str, what: &str, default: bool) -> Result<bool> {
    match o.get(key) {
        None => Ok(default),
        Some(v) => v.as_bool().with_context(|| format!("{what}.{key} must be a boolean")),
    }
}

fn str_field<'a>(o: &'a Obj, key: &str, what: &str) -> Result<&'a str> {
    o.get(key)
        .with_context(|| format!("{what}.{key} is required"))?
        .as_str()
        .with_context(|| format!("{what}.{key} must be a string"))
}

fn f64_list(v: &JsonValue, what: &str) -> Result<Vec<f64>> {
    v.as_array()
        .with_context(|| format!("{what} must be an array of numbers"))?
        .iter()
        .map(|x| x.as_f64().with_context(|| format!("{what} must contain only numbers")))
        .collect()
}

fn f64_list_field(o: &Obj, key: &str, what: &str) -> Result<Vec<f64>> {
    match o.get(key) {
        None => Ok(Vec::new()),
        Some(v) => f64_list(v, &format!("{what}.{key}")),
    }
}

/// Largest integer the reader accepts as a JSON number (2^53 - 1; matches
/// [`JsonValue::as_u64`]'s window — 2^53 itself is ambiguous with 2^53+1
/// after f64 rounding, so it goes to the string form too).
const JSON_EXACT_MAX: u64 = 9_007_199_254_740_991;

/// `run.seed` is a full u64. Values above 2^53 exceed JSON's
/// exact-integer window, so the writer emits them as decimal strings and
/// the reader accepts both forms — keeping `from_json` the exact inverse
/// of `to_json` over the whole seed range.
fn seed_value(v: &JsonValue) -> Result<u64> {
    if let Some(n) = v.as_u64() {
        return Ok(n);
    }
    if let Some(s) = v.as_str() {
        return s
            .parse::<u64>()
            .with_context(|| format!("run.seed string must be a u64 integer, got {s:?}"));
    }
    bail!("run.seed must be a non-negative integer (or a decimal string for seeds above 2^53)")
}

fn f64_pair(o: &Obj, key: &str, what: &str) -> Result<[f64; 2]> {
    let xs = f64_list(
        o.get(key).with_context(|| format!("{what}.{key} is required"))?,
        &format!("{what}.{key}"),
    )?;
    match xs.as_slice() {
        [a, b] => Ok([*a, *b]),
        _ => bail!("{what}.{key} must be an array of exactly 2 numbers"),
    }
}

// ---------------------------------------------------------------- processes

fn process_to_json(p: &ProcessSpec) -> JsonValue {
    let mut o = JsonValue::object();
    match p {
        ProcessSpec::ExpRate(r) => {
            o.set("type", "exp").set("rate", *r);
        }
        ProcessSpec::ExpMean(m) => {
            o.set("type", "exp").set("mean", *m);
        }
        ProcessSpec::Constant(v) => {
            o.set("type", "const").set("value", *v);
        }
        ProcessSpec::Gaussian { mean, std } => {
            o.set("type", "gaussian").set("mean", *mean).set("std", *std);
        }
        ProcessSpec::LogNormal { mean, cv } => {
            o.set("type", "lognormal").set("mean", *mean).set("cv", *cv);
        }
        ProcessSpec::Gamma { shape, scale } => {
            o.set("type", "gamma").set("shape", *shape).set("scale", *scale);
        }
        ProcessSpec::Weibull { shape, scale } => {
            o.set("type", "weibull").set("shape", *shape).set("scale", *scale);
        }
        ProcessSpec::Pareto { x_m, alpha } => {
            o.set("type", "pareto").set("x_m", *x_m).set("alpha", *alpha);
        }
        ProcessSpec::Empirical(samples) => {
            o.set("type", "empirical").set("samples", samples.clone());
        }
        ProcessSpec::Mmpp { rates, switch } => {
            o.set("type", "mmpp")
                .set("rates", rates.to_vec())
                .set("switch", switch.to_vec());
        }
    }
    o
}

fn process_from_json(v: &JsonValue, what: &str) -> Result<ProcessSpec> {
    let o = as_obj(v, what)?;
    let tag = str_field(o, "type", what)?;
    let spec = match tag {
        "exp" => {
            check_keys(o, &["type", "rate", "mean"], what)?;
            match (o.get("rate"), o.get("mean")) {
                (Some(r), None) => ProcessSpec::ExpRate(
                    r.as_f64().with_context(|| format!("{what}.rate must be a number"))?,
                ),
                (None, Some(m)) => ProcessSpec::ExpMean(
                    m.as_f64().with_context(|| format!("{what}.mean must be a number"))?,
                ),
                _ => bail!("{what}: exp needs exactly one of \"rate\" or \"mean\""),
            }
        }
        "const" => {
            check_keys(o, &["type", "value"], what)?;
            ProcessSpec::Constant(req_f64(o, "value", what)?)
        }
        "gaussian" => {
            check_keys(o, &["type", "mean", "std"], what)?;
            ProcessSpec::Gaussian { mean: req_f64(o, "mean", what)?, std: req_f64(o, "std", what)? }
        }
        "lognormal" => {
            check_keys(o, &["type", "mean", "cv"], what)?;
            ProcessSpec::LogNormal { mean: req_f64(o, "mean", what)?, cv: req_f64(o, "cv", what)? }
        }
        "gamma" => {
            check_keys(o, &["type", "shape", "scale"], what)?;
            ProcessSpec::Gamma {
                shape: req_f64(o, "shape", what)?,
                scale: req_f64(o, "scale", what)?,
            }
        }
        "weibull" => {
            check_keys(o, &["type", "shape", "scale"], what)?;
            ProcessSpec::Weibull {
                shape: req_f64(o, "shape", what)?,
                scale: req_f64(o, "scale", what)?,
            }
        }
        "pareto" => {
            check_keys(o, &["type", "x_m", "alpha"], what)?;
            ProcessSpec::Pareto { x_m: req_f64(o, "x_m", what)?, alpha: req_f64(o, "alpha", what)? }
        }
        "empirical" => {
            check_keys(o, &["type", "samples"], what)?;
            ProcessSpec::Empirical(f64_list(
                o.get("samples").with_context(|| format!("{what}.samples is required"))?,
                &format!("{what}.samples"),
            )?)
        }
        "mmpp" => {
            check_keys(o, &["type", "rates", "switch"], what)?;
            ProcessSpec::Mmpp {
                rates: f64_pair(o, "rates", what)?,
                switch: f64_pair(o, "switch", what)?,
            }
        }
        other => bail!(
            "{what}.type: unknown process {other:?} (expected \
             exp|const|gaussian|lognormal|gamma|weibull|pareto|empirical|mmpp)"
        ),
    };
    Ok(spec)
}

// ------------------------------------------------------------------ source

fn source_to_json(s: &SourceSpec) -> JsonValue {
    let mut o = JsonValue::object();
    match s {
        SourceSpec::Synthetic => {
            o.set("type", "synthetic");
        }
        SourceSpec::AzureDataset { dir, top_k, slice, scale_rate } => {
            o.set("type", "azure_dataset").set("dir", dir.as_str());
            if let Some(k) = top_k {
                o.set("top_k", *k);
            }
            if let Some((start, len)) = slice {
                o.set("slice", JsonValue::Array(vec![(*start).into(), (*len).into()]));
            }
            if *scale_rate != 1.0 {
                o.set("scale_rate", *scale_rate);
            }
        }
    }
    o
}

fn source_from_json(v: &JsonValue, what: &str) -> Result<SourceSpec> {
    let o = as_obj(v, what)?;
    let tag = str_field(o, "type", what)?;
    Ok(match tag {
        "synthetic" => {
            check_keys(o, &["type"], what)?;
            SourceSpec::Synthetic
        }
        "azure_dataset" => {
            check_keys(o, &["type", "dir", "top_k", "slice", "scale_rate"], what)?;
            let dir = str_field(o, "dir", what)?.to_string();
            let top_k = match o.get("top_k") {
                None => None,
                Some(v) => Some(
                    v.as_u64()
                        .with_context(|| format!("{what}.top_k must be a non-negative integer"))?
                        as usize,
                ),
            };
            let slice = match o.get("slice") {
                None => None,
                Some(v) => {
                    let xs = f64_list(v, &format!("{what}.slice"))?;
                    match xs.as_slice() {
                        [s, l]
                            if s.fract() == 0.0
                                && l.fract() == 0.0
                                && *s >= 0.0
                                && *l >= 0.0 =>
                        {
                            Some((*s as usize, *l as usize))
                        }
                        _ => bail!(
                            "{what}.slice must be [start, len] with two non-negative integers"
                        ),
                    }
                }
            };
            SourceSpec::AzureDataset {
                dir,
                top_k,
                slice,
                scale_rate: f64_field(o, "scale_rate", what, 1.0)?,
            }
        }
        other => bail!(
            "{what}.type: unknown workload source {other:?} (expected synthetic|azure_dataset)"
        ),
    })
}

// ------------------------------------------------------------------ policy

fn policy_to_json(p: &KeepAliveSpec) -> JsonValue {
    let mut o = JsonValue::object();
    match p {
        KeepAliveSpec::Fixed { threshold } => {
            o.set("type", "fixed").set("threshold", *threshold);
        }
        KeepAliveSpec::Stochastic { process } => {
            o.set("type", "stochastic").set("process", process_to_json(process));
        }
        KeepAliveSpec::HybridHistogram {
            range,
            bin_len,
            tail,
            margin,
            min_samples,
            oob_threshold,
        } => {
            o.set("type", "adaptive")
                .set("range", *range)
                .set("bin_len", *bin_len)
                .set("tail", *tail)
                .set("margin", *margin)
                .set("min_samples", *min_samples)
                .set("oob_threshold", *oob_threshold);
        }
    }
    o
}

fn policy_from_json(v: &JsonValue, what: &str) -> Result<KeepAliveSpec> {
    let o = as_obj(v, what)?;
    let tag = str_field(o, "type", what)?;
    if tag == "stochastic" {
        check_keys(o, &["type", "process"], what)?;
        let pv = o.get("process").with_context(|| format!("{what}.process is required"))?;
        return Ok(KeepAliveSpec::Stochastic {
            process: process_from_json(pv, &format!("{what}.process"))?,
        });
    }
    // "fixed"/"adaptive" (and aliases) share the CLI's parser, so names and
    // error text cannot drift between the two surfaces.
    let kind: PolicyKind = tag
        .parse()
        .with_context(|| format!("{what}.type (also accepted: \"stochastic\")"))?;
    Ok(match kind {
        PolicyKind::Fixed => {
            check_keys(o, &["type", "threshold"], what)?;
            KeepAliveSpec::Fixed { threshold: f64_field(o, "threshold", what, 600.0)? }
        }
        PolicyKind::Adaptive => {
            check_keys(
                o,
                &["type", "range", "bin_len", "tail", "margin", "min_samples", "oob_threshold"],
                what,
            )?;
            let defaults = KeepAliveSpec::HYBRID_DEFAULTS;
            KeepAliveSpec::HybridHistogram {
                range: f64_field(o, "range", what, 3_600.0)?,
                bin_len: f64_field(o, "bin_len", what, 60.0)?,
                tail: f64_field(o, "tail", what, defaults.0)?,
                margin: f64_field(o, "margin", what, defaults.1)?,
                min_samples: u64_field(o, "min_samples", what, defaults.2)?,
                oob_threshold: f64_field(o, "oob_threshold", what, defaults.3)?,
            }
        }
    })
}

// ------------------------------------------------------------- reliability

fn fault_to_json(f: &FaultProfile) -> JsonValue {
    let mut o = JsonValue::object();
    if f.invocation_failure_prob != 0.0 {
        o.set("failure_prob", f.invocation_failure_prob);
    }
    if f.coldstart_failure_prob != 0.0 {
        o.set("coldstart_failure_prob", f.coldstart_failure_prob);
    }
    if let Some(t) = f.timeout {
        o.set("timeout", t);
    }
    if f.timeout_action == TimeoutAction::KillInstance {
        o.set("timeout_kills", true);
    }
    if !f.degradation.is_empty() {
        o.set(
            "degradation",
            JsonValue::Array(
                f.degradation
                    .iter()
                    .map(|w| {
                        let mut wo = JsonValue::object();
                        wo.set("start", w.start)
                            .set("end", w.end)
                            .set("capacity_factor", w.capacity_factor);
                        wo
                    })
                    .collect(),
            ),
        );
    }
    o
}

fn fault_from_json(v: &JsonValue, what: &str) -> Result<FaultProfile> {
    let o = as_obj(v, what)?;
    check_keys(
        o,
        &["failure_prob", "coldstart_failure_prob", "timeout", "timeout_kills", "degradation"],
        what,
    )?;
    let mut f = FaultProfile::disabled();
    f.invocation_failure_prob = f64_field(o, "failure_prob", what, 0.0)?;
    f.coldstart_failure_prob = f64_field(o, "coldstart_failure_prob", what, 0.0)?;
    f.timeout = match o.get("timeout") {
        None => None,
        Some(t) => Some(t.as_f64().with_context(|| format!("{what}.timeout must be a number"))?),
    };
    f.timeout_action = if bool_field(o, "timeout_kills", what, false)? {
        TimeoutAction::KillInstance
    } else {
        TimeoutAction::KeepInstance
    };
    if let Some(dv) = o.get("degradation") {
        let windows = dv
            .as_array()
            .with_context(|| format!("{what}.degradation must be an array of windows"))?;
        for (i, wv) in windows.iter().enumerate() {
            let ww = format!("{what}.degradation[{i}]");
            let w = as_obj(wv, &ww)?;
            check_keys(w, &["start", "end", "capacity_factor"], &ww)?;
            f.degradation.push(DegradationWindow {
                start: req_f64(w, "start", &ww)?,
                end: req_f64(w, "end", &ww)?,
                capacity_factor: req_f64(w, "capacity_factor", &ww)?,
            });
        }
    }
    Ok(f)
}

fn retry_to_json(r: &RetryPolicy) -> JsonValue {
    let mut o = JsonValue::object();
    match &r.backoff {
        Backoff::None => {
            o.set("type", "none");
        }
        Backoff::Fixed { delay } => {
            o.set("type", "fixed").set("delay", *delay);
        }
        Backoff::Exponential { base, cap } => {
            o.set("type", "exponential").set("base", *base).set("cap", *cap);
        }
    }
    o.set("max_attempts", r.max_attempts as u64);
    if let Some(b) = r.budget {
        o.set("budget", b);
    }
    o
}

/// Reader: either the structured object the writer emits, or the CLI's
/// compact string form (`"exponential:0.1,5,4"`) via [`RetryPolicy::parse`].
fn retry_from_json(v: &JsonValue, what: &str) -> Result<RetryPolicy> {
    if let Some(s) = v.as_str() {
        return RetryPolicy::parse(s).with_context(|| what.to_string());
    }
    let o = as_obj(v, what)?;
    let tag = str_field(o, "type", what)?;
    let backoff = match tag {
        "none" => {
            check_keys(o, &["type", "max_attempts", "budget"], what)?;
            Backoff::None
        }
        "fixed" => {
            check_keys(o, &["type", "delay", "max_attempts", "budget"], what)?;
            Backoff::Fixed { delay: req_f64(o, "delay", what)? }
        }
        "exponential" | "exp" => {
            check_keys(o, &["type", "base", "cap", "max_attempts", "budget"], what)?;
            Backoff::Exponential { base: req_f64(o, "base", what)?, cap: req_f64(o, "cap", what)? }
        }
        other => bail!("{what}.type: unknown retry backoff {other:?} (expected none|fixed|exponential)"),
    };
    let default_attempts = if tag == "none" { 1 } else { 3 };
    Ok(RetryPolicy {
        backoff,
        max_attempts: u64_field(o, "max_attempts", what, default_attempts)? as u32,
        budget: match o.get("budget") {
            None => None,
            Some(b) => Some(
                b.as_u64()
                    .with_context(|| format!("{what}.budget must be a non-negative integer"))?,
            ),
        },
    })
}

fn reliability_to_json(r: &ReliabilitySpec) -> JsonValue {
    let mut o = JsonValue::object();
    if r.fault != FaultProfile::disabled() {
        o.set("fault", fault_to_json(&r.fault));
    }
    if r.retry != RetryPolicy::none() {
        o.set("retry", retry_to_json(&r.retry));
    }
    o
}

fn reliability_from_json(v: &JsonValue) -> Result<ReliabilitySpec> {
    let what = "reliability";
    let o = as_obj(v, what)?;
    check_keys(o, &["fault", "retry"], what)?;
    Ok(ReliabilitySpec {
        fault: match o.get("fault") {
            None => FaultProfile::disabled(),
            Some(fv) => fault_from_json(fv, "reliability.fault")?,
        },
        retry: match o.get("retry") {
            None => RetryPolicy::none(),
            Some(rv) => retry_from_json(rv, "reliability.retry")?,
        },
    })
}

// ----------------------------------------------------------- observability

fn observability_to_json(o: &ObservabilitySpec) -> JsonValue {
    let mut j = JsonValue::object();
    if let Some(path) = &o.record_trace {
        j.set("record_trace", path.as_str());
    }
    if o.metrics_interval != 0.0 {
        j.set("metrics_interval", o.metrics_interval);
    }
    j
}

fn observability_from_json(v: &JsonValue) -> Result<ObservabilitySpec> {
    let what = "observability";
    let o = as_obj(v, what)?;
    check_keys(o, &["record_trace", "metrics_interval"], what)?;
    Ok(ObservabilitySpec {
        record_trace: match o.get("record_trace") {
            None => None,
            Some(p) => Some(
                p.as_str()
                    .context("observability.record_trace must be a file-path string")?
                    .to_string(),
            ),
        },
        metrics_interval: f64_field(o, "metrics_interval", what, 0.0)?,
    })
}

// -------------------------------------------------------------- experiment

fn experiment_to_json(e: &ExperimentSpec) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("type", e.kind());
    match e {
        ExperimentSpec::Steady => {}
        ExperimentSpec::Temporal { replications, sample_interval, warm_pool } => {
            o.set("replications", *replications).set("warm_pool", *warm_pool);
            if let Some(si) = sample_interval {
                o.set("sample_interval", *si);
            }
        }
        ExperimentSpec::Ensemble { replications, threads, thresholds } => {
            o.set("replications", *replications)
                .set("threads", *threads)
                .set("thresholds", thresholds.clone());
        }
        ExperimentSpec::Sweep { rates, thresholds } => {
            o.set("rates", rates.clone()).set("thresholds", thresholds.clone());
        }
        ExperimentSpec::Compare { service_mean, markovian_expiration } => {
            o.set("service_mean", *service_mean)
                .set("markovian_expiration", *markovian_expiration);
        }
        ExperimentSpec::Fleet(f) => {
            o.set("functions", f.functions)
                .set("threads", f.threads)
                .set("policy", policy_to_json(&f.policy))
                .set("memory_mb", f.memory_mb)
                .set("top_k", f.top_k);
            if let Some(cap) = f.fleet_cap {
                o.set("fleet_cap", cap);
            }
            if f.prewarm_lead > 0.0 {
                o.set("prewarm_lead", f.prewarm_lead);
            }
            if !f.compare_thresholds.is_empty() || !f.compare_extra.is_empty() {
                o.set("compare_thresholds", f.compare_thresholds.clone()).set(
                    "compare_extra",
                    JsonValue::Array(f.compare_extra.iter().map(policy_to_json).collect()),
                );
            }
            if let Some(cl) = &f.cluster {
                o.set("cluster", cluster_to_json(cl));
            }
            // Emitted only when sharding is on, so pre-domain scenario
            // files round-trip byte-identically.
            if f.capacity_domains != 1 {
                o.set("capacity_domains", f.capacity_domains);
            }
            if let Some(ctl) = &f.controller {
                o.set("controller", ctl.as_str());
            }
        }
    }
    o
}

fn cluster_to_json(cl: &ClusterConfig) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("hosts", cl.hosts)
        .set("host_memory_mb", cl.host_memory_mb)
        .set("host_cpus", cl.host_cpus)
        .set("scheduler", cl.scheduler.as_str());
    if !cl.eviction {
        o.set("eviction", false);
    }
    if !cl.drains.is_empty() {
        o.set(
            "drains",
            JsonValue::Array(
                cl.drains
                    .iter()
                    .map(|d| {
                        let mut w = JsonValue::object();
                        w.set("host", d.host).set("start", d.start).set("end", d.end);
                        w
                    })
                    .collect(),
            ),
        );
    }
    o
}

fn cluster_from_json(v: &JsonValue) -> Result<ClusterConfig> {
    let what = "experiment.cluster";
    let o = as_obj(v, what)?;
    check_keys(
        o,
        &["hosts", "host_memory_mb", "host_cpus", "scheduler", "eviction", "drains"],
        what,
    )?;
    let mut cl = ClusterConfig::new(
        usize_field(o, "hosts", what, 1)?,
        f64_field(o, "host_memory_mb", what, 2048.0)?,
        f64_field(o, "host_cpus", what, 32.0)?,
    );
    if let Some(sv) = o.get("scheduler") {
        let s = sv
            .as_str()
            .context("experiment.cluster.scheduler must be a string")?;
        cl.scheduler = SchedulerSpec::parse(s).with_context(|| {
            format!(
                "experiment.cluster.scheduler: unknown scheduler {s:?} \
                 (expected first-fit|least-loaded|round-robin|packing)"
            )
        })?;
    }
    cl.eviction = bool_field(o, "eviction", what, true)?;
    if let Some(dv) = o.get("drains") {
        for (i, d) in dv
            .as_array()
            .context("experiment.cluster.drains must be an array")?
            .iter()
            .enumerate()
        {
            let dwhat = format!("experiment.cluster.drains[{i}]");
            let dobj = as_obj(d, &dwhat)?;
            check_keys(dobj, &["host", "start", "end"], &dwhat)?;
            cl = cl.with_drain(
                usize_field(dobj, "host", &dwhat, 0)?,
                req_f64(dobj, "start", &dwhat)?,
                req_f64(dobj, "end", &dwhat)?,
            );
        }
    }
    Ok(cl)
}

fn experiment_from_json(v: &JsonValue) -> Result<ExperimentSpec> {
    let what = "experiment";
    let o = as_obj(v, what)?;
    let tag = str_field(o, "type", what)?;
    Ok(match tag {
        "steady" => {
            check_keys(o, &["type"], what)?;
            ExperimentSpec::Steady
        }
        "temporal" => {
            check_keys(o, &["type", "replications", "sample_interval", "warm_pool"], what)?;
            ExperimentSpec::Temporal {
                replications: usize_field(o, "replications", what, 10)?,
                sample_interval: match o.get("sample_interval") {
                    None => None,
                    Some(v) => Some(
                        v.as_f64()
                            .context("experiment.sample_interval must be a number")?,
                    ),
                },
                warm_pool: usize_field(o, "warm_pool", what, 0)?,
            }
        }
        "ensemble" => {
            check_keys(o, &["type", "replications", "threads", "thresholds"], what)?;
            ExperimentSpec::Ensemble {
                replications: usize_field(o, "replications", what, 10)?,
                threads: usize_field(o, "threads", what, 0)?,
                thresholds: f64_list_field(o, "thresholds", what)?,
            }
        }
        "sweep" => {
            check_keys(o, &["type", "rates", "thresholds"], what)?;
            ExperimentSpec::Sweep {
                rates: f64_list_field(o, "rates", what)?,
                thresholds: f64_list_field(o, "thresholds", what)?,
            }
        }
        "compare" => {
            check_keys(o, &["type", "service_mean", "markovian_expiration"], what)?;
            ExperimentSpec::Compare {
                service_mean: f64_field(o, "service_mean", what, crate::figures::WARM_MEAN)?,
                markovian_expiration: bool_field(o, "markovian_expiration", what, false)?,
            }
        }
        "fleet" => {
            check_keys(
                o,
                &[
                    "type",
                    "functions",
                    "threads",
                    "policy",
                    "fleet_cap",
                    "prewarm_lead",
                    "memory_mb",
                    "top_k",
                    "compare_thresholds",
                    "compare_extra",
                    "cluster",
                    "capacity_domains",
                    "controller",
                ],
                what,
            )?;
            let mut f = FleetScenario::new(usize_field(o, "functions", what, 50)?);
            f.threads = usize_field(o, "threads", what, 0)?;
            if let Some(pv) = o.get("policy") {
                f.policy = policy_from_json(pv, "experiment.policy")?;
            }
            f.fleet_cap = match usize_field(o, "fleet_cap", what, 0)? {
                0 => None,
                cap => Some(cap),
            };
            f.prewarm_lead = f64_field(o, "prewarm_lead", what, 0.0)?;
            f.memory_mb = f64_field(o, "memory_mb", what, 128.0)?;
            f.top_k = usize_field(o, "top_k", what, 5)?;
            f.compare_thresholds = f64_list_field(o, "compare_thresholds", what)?;
            if let Some(xv) = o.get("compare_extra") {
                f.compare_extra = xv
                    .as_array()
                    .context("experiment.compare_extra must be an array of policies")?
                    .iter()
                    .map(|p| policy_from_json(p, "experiment.compare_extra[..]"))
                    .collect::<Result<Vec<_>>>()?;
            }
            if let Some(cv) = o.get("cluster") {
                f.cluster = Some(cluster_from_json(cv)?);
            }
            f.capacity_domains = usize_field(o, "capacity_domains", what, 1)?;
            if let Some(cv) = o.get("controller") {
                let s = cv
                    .as_str()
                    .context("experiment.controller must be a string")?;
                f.controller = Some(ControllerSpec::parse(s).with_context(|| {
                    format!(
                        "experiment.controller: unparseable controller {s:?} \
                         (expected target:UTIL[,COOLDOWN,STEP] | \
                         pid:KP,KI,KD[,TARGET] | step:LOW,HIGH[,STEP], with \
                         optional ;tick=SECS;min=N;max=N;delay=SECS options)"
                    )
                })?);
            }
            ExperimentSpec::Fleet(f)
        }
        other => bail!(
            "experiment.type: unknown experiment {other:?} \
             (expected steady|temporal|ensemble|sweep|compare|fleet)"
        ),
    })
}

// -------------------------------------------------------------- spec level

impl ScenarioSpec {
    /// Serialize to the canonical JSON form ([`Self::from_json`] is its
    /// exact inverse — pinned by round-trip tests).
    pub fn to_json(&self) -> JsonValue {
        let mut workload = JsonValue::object();
        workload.set("arrival", process_to_json(&self.workload.arrival));
        if let Some(b) = &self.workload.batch_size {
            workload.set("batch_size", process_to_json(b));
        }
        if let Some(s) = &self.workload.source {
            workload.set("source", source_to_json(s));
        }

        let mut platform = JsonValue::object();
        platform
            .set("warm_service", process_to_json(&self.platform.warm_service))
            .set("cold_service", process_to_json(&self.platform.cold_service))
            .set("expiration_threshold", self.platform.expiration_threshold)
            .set("max_concurrency", self.platform.max_concurrency);
        if let Some(p) = &self.platform.expiration_process {
            platform.set("expiration_process", process_to_json(p));
        }

        let mut run = JsonValue::object();
        run.set("horizon", self.run.horizon).set("skip_initial", self.run.skip_initial);
        if self.run.seed <= JSON_EXACT_MAX {
            run.set("seed", self.run.seed);
        } else {
            run.set("seed", self.run.seed.to_string());
        }

        let mut o = JsonValue::object();
        o.set("name", self.name.as_str())
            .set("workload", workload)
            .set("platform", platform)
            .set("run", run)
            .set("experiment", experiment_to_json(&self.experiment));
        if let Some(c) = &self.cost {
            let mut cj = JsonValue::object();
            cj.set("provider", c.provider.canonical_name())
                .set("memory_mb", c.memory_mb)
                .set("external_per_request", c.external_per_request);
            if let Some(w) = c.scale_to_window {
                cj.set("scale_to_window", w);
            }
            o.set("cost", cj);
        }
        if let Some(r) = &self.reliability {
            o.set("reliability", reliability_to_json(r));
        }
        if let Some(obs) = &self.observability {
            o.set("observability", observability_to_json(obs));
        }
        let mut out = JsonValue::object();
        out.set(
            "format",
            match self.output.format {
                OutputFormat::Table => "table",
                OutputFormat::Json => "json",
            },
        );
        o.set("output", out);
        o
    }

    /// Compact one-line JSON text.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Deserialize from a parsed [`JsonValue`]. Every axis is optional
    /// with Table-1 / CLI defaults except `name` and `experiment`; unknown
    /// keys anywhere are errors.
    pub fn from_json(v: &JsonValue) -> Result<ScenarioSpec> {
        let o = as_obj(v, "scenario")?;
        check_keys(
            o,
            &[
                "name",
                "workload",
                "platform",
                "run",
                "experiment",
                "cost",
                "reliability",
                "observability",
                "output",
            ],
            "scenario",
        )?;
        let name = str_field(o, "name", "scenario")?.to_string();

        let workload = match o.get("workload") {
            None => WorkloadSpec::default(),
            Some(wv) => {
                let w = as_obj(wv, "workload")?;
                check_keys(w, &["arrival", "batch_size", "source"], "workload")?;
                WorkloadSpec {
                    arrival: match w.get("arrival") {
                        None => WorkloadSpec::default().arrival,
                        Some(a) => process_from_json(a, "workload.arrival")?,
                    },
                    batch_size: match w.get("batch_size") {
                        None => None,
                        Some(b) => Some(process_from_json(b, "workload.batch_size")?),
                    },
                    source: match w.get("source") {
                        None => None,
                        Some(s) => Some(source_from_json(s, "workload.source")?),
                    },
                }
            }
        };

        let platform = match o.get("platform") {
            None => PlatformSpec::default(),
            Some(pv) => {
                let p = as_obj(pv, "platform")?;
                check_keys(
                    p,
                    &[
                        "warm_service",
                        "cold_service",
                        "expiration_threshold",
                        "expiration_process",
                        "max_concurrency",
                    ],
                    "platform",
                )?;
                let d = PlatformSpec::default();
                PlatformSpec {
                    warm_service: match p.get("warm_service") {
                        None => d.warm_service,
                        Some(v) => process_from_json(v, "platform.warm_service")?,
                    },
                    cold_service: match p.get("cold_service") {
                        None => d.cold_service,
                        Some(v) => process_from_json(v, "platform.cold_service")?,
                    },
                    expiration_threshold: f64_field(
                        p,
                        "expiration_threshold",
                        "platform",
                        d.expiration_threshold,
                    )?,
                    expiration_process: match p.get("expiration_process") {
                        None => None,
                        Some(v) => Some(process_from_json(v, "platform.expiration_process")?),
                    },
                    max_concurrency: usize_field(
                        p,
                        "max_concurrency",
                        "platform",
                        d.max_concurrency,
                    )?,
                }
            }
        };

        let run = match o.get("run") {
            None => RunSpec::default(),
            Some(rv) => {
                let r = as_obj(rv, "run")?;
                check_keys(r, &["horizon", "skip_initial", "seed"], "run")?;
                let d = RunSpec::default();
                RunSpec {
                    horizon: f64_field(r, "horizon", "run", d.horizon)?,
                    skip_initial: f64_field(r, "skip_initial", "run", d.skip_initial)?,
                    seed: match r.get("seed") {
                        None => d.seed,
                        Some(v) => seed_value(v)?,
                    },
                }
            }
        };

        let experiment = experiment_from_json(
            o.get("experiment").context("scenario.experiment is required")?,
        )?;

        let cost = match o.get("cost") {
            None => None,
            Some(cv) => {
                let c = as_obj(cv, "cost")?;
                check_keys(
                    c,
                    &["provider", "memory_mb", "external_per_request", "scale_to_window"],
                    "cost",
                )?;
                let d = CostSpec::default();
                let provider: Provider = match c.get("provider") {
                    None => d.provider,
                    Some(p) => p
                        .as_str()
                        .context("cost.provider must be a string")?
                        .parse()
                        .context("cost.provider")?,
                };
                Some(CostSpec {
                    provider,
                    memory_mb: f64_field(c, "memory_mb", "cost", d.memory_mb)?,
                    external_per_request: f64_field(
                        c,
                        "external_per_request",
                        "cost",
                        d.external_per_request,
                    )?,
                    scale_to_window: match c.get("scale_to_window") {
                        None => None,
                        Some(w) => Some(
                            w.as_f64().context("cost.scale_to_window must be a number")?,
                        ),
                    },
                })
            }
        };

        let reliability = match o.get("reliability") {
            None => None,
            Some(rv) => Some(reliability_from_json(rv)?),
        };

        let observability = match o.get("observability") {
            None => None,
            Some(ov) => Some(observability_from_json(ov)?),
        };

        let output = match o.get("output") {
            None => OutputSpec::default(),
            Some(ov) => {
                let out = as_obj(ov, "output")?;
                check_keys(out, &["format"], "output")?;
                let format = match out.get("format") {
                    None => OutputFormat::default(),
                    Some(f) => match f.as_str().context("output.format must be a string")? {
                        "table" => OutputFormat::Table,
                        "json" => OutputFormat::Json,
                        other => {
                            bail!("output.format: unknown format {other:?} (expected table|json)")
                        }
                    },
                };
                OutputSpec { format }
            }
        };

        Ok(ScenarioSpec {
            name,
            workload,
            platform,
            run,
            experiment,
            cost,
            reliability,
            observability,
            output,
        })
    }

    /// Parse JSON text into a spec (reader for `simfaas run` files).
    pub fn from_json_str(text: &str) -> Result<ScenarioSpec> {
        let v = JsonValue::parse(text).context("scenario file is not valid JSON")?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::DEFAULT_SEED;

    fn roundtrip(spec: &ScenarioSpec) {
        let text = spec.to_json_string();
        let back = ScenarioSpec::from_json_str(&text)
            .unwrap_or_else(|e| panic!("reparse failed for {text}: {e:#}"));
        assert_eq!(&back, spec, "round trip changed the spec: {text}");
    }

    #[test]
    fn default_and_rich_specs_roundtrip() {
        roundtrip(&ScenarioSpec::new("plain"));
        roundtrip(
            &ScenarioSpec::new("rich")
                .with_arrival(ProcessSpec::Mmpp { rates: [2.0, 0.2], switch: [0.01, 0.02] })
                .with_batch_size(ProcessSpec::Constant(2.0))
                .with_services(
                    ProcessSpec::LogNormal { mean: 1.5, cv: 0.4 },
                    ProcessSpec::Gamma { shape: 2.0, scale: 1.1 },
                )
                .with_expiration_process(ProcessSpec::Gaussian { mean: 600.0, std: 30.0 })
                .with_horizon(12_345.5)
                .with_seed(987_654_321)
                .with_experiment(ExperimentSpec::Ensemble {
                    replications: 7,
                    threads: 2,
                    thresholds: vec![60.0, 600.0],
                })
                .with_cost(CostSpec::monthly(Provider::IbmCloudFunctions, 256.0))
                .with_output(OutputFormat::Json),
        );
        roundtrip(
            &ScenarioSpec::new("fleet").with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(12)
                    .with_policy(KeepAliveSpec::hybrid_histogram(1_800.0, 30.0))
                    .with_fleet_cap(64)
                    .with_capacity_domains(4)
                    .with_comparison(
                        vec![120.0, 600.0],
                        vec![KeepAliveSpec::Stochastic {
                            process: ProcessSpec::ExpMean(600.0),
                        }],
                    ),
            )),
        );
        roundtrip(
            &ScenarioSpec::new("prewarm").with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(6)
                    .with_policy(KeepAliveSpec::hybrid_histogram(3_600.0, 60.0))
                    .with_prewarm_lead(20.0),
            )),
        );
        roundtrip(
            &ScenarioSpec::new("cluster").with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(8).with_cluster(
                    ClusterConfig::new(4, 2_048.0, 16.0)
                        .with_scheduler(SchedulerSpec::LeastLoaded)
                        .with_eviction(false)
                        .with_drain(1, 100.0, 250.0),
                ),
            )),
        );
        roundtrip(
            &ScenarioSpec::new("autoscale").with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(6).with_fleet_cap(32).with_controller(
                    ControllerSpec::target_tracking(0.7)
                        .with_tick(30.0)
                        .with_bounds(2, 64)
                        .with_provision_delay(45.0),
                ),
            )),
        );
        roundtrip(
            &ScenarioSpec::new("autoscale-pid").with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(4).with_cluster(ClusterConfig::new(3, 1_024.0, 8.0))
                    .with_controller(ControllerSpec::pid(0.8, 0.1, 0.05)),
            )),
        );
        roundtrip(
            &ScenarioSpec::new("temporal").with_experiment(ExperimentSpec::Temporal {
                replications: 4,
                sample_interval: Some(50.0),
                warm_pool: 3,
            }),
        );
        roundtrip(&ScenarioSpec::new("sweep").with_experiment(ExperimentSpec::Sweep {
            rates: vec![0.5, 1.0],
            thresholds: vec![120.0, 600.0],
        }));
        roundtrip(&ScenarioSpec::new("cmp").with_experiment(ExperimentSpec::Compare {
            service_mean: 2.0,
            markovian_expiration: true,
        }));
    }

    #[test]
    fn source_axis_roundtrips_and_rejects_unknowns() {
        let fleet = ExperimentSpec::Fleet(FleetScenario::new(4));
        roundtrip(
            &ScenarioSpec::new("src-syn")
                .with_experiment(fleet.clone())
                .with_source(SourceSpec::Synthetic),
        );
        roundtrip(
            &ScenarioSpec::new("src-azure").with_experiment(fleet.clone()).with_source(
                SourceSpec::AzureDataset {
                    dir: "examples/traces/azure_sample".into(),
                    top_k: Some(10),
                    slice: Some((2, 8)),
                    scale_rate: 2.5,
                },
            ),
        );
        // Defaults (no top_k/slice, scale 1.0) stay implicit in the JSON.
        let minimal = ScenarioSpec::new("src-min").with_experiment(fleet).with_source(
            SourceSpec::AzureDataset {
                dir: "d".into(),
                top_k: None,
                slice: None,
                scale_rate: 1.0,
            },
        );
        let text = minimal.to_json_string();
        assert!(!text.contains("scale_rate"), "{text}");
        roundtrip(&minimal);
        // Reader errors: unknown source type, bad slice, unknown key.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","workload":{"source":{"type":"s3"}},"experiment":{"type":"fleet"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("synthetic|azure_dataset"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","workload":{"source":{"type":"azure_dataset","dir":"d","slice":[1.5,2]}},"experiment":{"type":"fleet"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("slice"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","workload":{"source":{"type":"azure_dataset","dir":"d","topk":3}},"experiment":{"type":"fleet"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("unknown key") && err.contains("topk"), "{err}");
    }

    #[test]
    fn reliability_axis_roundtrips_and_rejects_unknowns() {
        // Rich profile: every fault knob plus budgeted exponential retry.
        roundtrip(&ScenarioSpec::new("faults").with_reliability(ReliabilitySpec::new(
            FaultProfile::disabled()
                .with_failure_prob(0.05)
                .with_coldstart_failure_prob(0.01)
                .with_timeout(30.0)
                .with_timeout_action(TimeoutAction::KillInstance)
                .with_degradation(100.0, 200.0, 0.5),
            RetryPolicy::exponential(0.1, 5.0, 4).with_budget(100),
        )));
        roundtrip(
            &ScenarioSpec::new("fleet-faults")
                .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(4)))
                .with_reliability(ReliabilitySpec::new(
                    FaultProfile::disabled().with_failure_prob(0.02),
                    RetryPolicy::fixed(1.0, 3),
                )),
        );
        // A disabled axis stays implicit field-by-field: empty object.
        let spec = ScenarioSpec::new("noop").with_reliability(ReliabilitySpec::default());
        let text = spec.to_json_string();
        assert!(text.contains("\"reliability\":{}"), "{text}");
        roundtrip(&spec);
        // The CLI's compact string form is accepted for retry.
        let spec = ScenarioSpec::from_json_str(
            r#"{"name":"s","experiment":{"type":"steady"},"reliability":{"retry":"exponential:0.1,5,4"}}"#,
        )
        .unwrap();
        assert_eq!(spec.reliability.unwrap().retry, RetryPolicy::exponential(0.1, 5.0, 4));
        // Unknown keys are errors with the path named.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"reliability":{"fault":{"failure_rate":0.1}}}"#,
            )
            .unwrap_err()
        );
        assert!(err.contains("unknown key") && err.contains("failure_rate"), "{err}");
        // Unknown retry backoff lists the accepted set.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"reliability":{"retry":{"type":"cubic"}}}"#,
            )
            .unwrap_err()
        );
        assert!(err.contains("none|fixed|exponential"), "{err}");
    }

    #[test]
    fn observability_axis_roundtrips_and_rejects_unknowns() {
        roundtrip(&ScenarioSpec::new("obs").with_observability(ObservabilitySpec::new(
            Some("/tmp/spans.jsonl".into()),
            60.0,
        )));
        roundtrip(
            &ScenarioSpec::new("obs-fleet")
                .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(4)))
                .with_observability(ObservabilitySpec::new(None, 30.0)),
        );
        // A default axis stays implicit field-by-field: empty object.
        let spec = ScenarioSpec::new("noop").with_observability(ObservabilitySpec::default());
        let text = spec.to_json_string();
        assert!(text.contains("\"observability\":{}"), "{text}");
        roundtrip(&spec);
        // Unknown keys are errors with the path named.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"observability":{"trace_path":"t"}}"#,
            )
            .unwrap_err()
        );
        assert!(err.contains("unknown key") && err.contains("trace_path"), "{err}");
        // Type errors name the path.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"observability":{"record_trace":3}}"#,
            )
            .unwrap_err()
        );
        assert!(err.contains("record_trace"), "{err}");
    }

    #[test]
    fn seeds_above_2_pow_53_roundtrip_via_strings() {
        // f64 JSON numbers cannot hold these exactly; the writer switches
        // to a decimal string and the reader accepts both forms.
        for seed in [u64::MAX, 1u64 << 60, (1u64 << 53) + 1] {
            let spec = ScenarioSpec::new("big-seed").with_seed(seed);
            let text = spec.to_json_string();
            assert!(text.contains(&format!("\"seed\":\"{seed}\"")), "{text}");
            roundtrip(&spec);
        }
        // Small seeds stay plain numbers.
        let text = ScenarioSpec::new("small").with_seed(7).to_json_string();
        assert!(text.contains("\"seed\":7"), "{text}");
        // Explicit string form parses even below the threshold.
        let spec =
            ScenarioSpec::from_json_str(r#"{"name":"s","run":{"seed":"42"},"experiment":{"type":"steady"}}"#)
                .unwrap();
        assert_eq!(spec.run.seed, 42);
        // Garbage string seeds fail with the path named.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"s","run":{"seed":"forty-two"},"experiment":{"type":"steady"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("run.seed"), "{err}");
    }

    #[test]
    fn minimal_spec_gets_all_defaults() {
        let spec =
            ScenarioSpec::from_json_str(r#"{"name":"m","experiment":{"type":"steady"}}"#).unwrap();
        assert_eq!(spec, ScenarioSpec::new("m"));
        assert_eq!(spec.run.seed, DEFAULT_SEED);
    }

    #[test]
    fn unknown_keys_are_errors_at_every_level() {
        for (text, needle) in [
            (r#"{"name":"x","experiment":{"type":"steady"},"wrkload":{}}"#, "wrkload"),
            (
                r#"{"name":"x","experiment":{"type":"steady","reps":3}}"#,
                "reps",
            ),
            (
                r#"{"name":"x","experiment":{"type":"steady"},"run":{"horizn":5}}"#,
                "horizn",
            ),
            (
                r#"{"name":"x","experiment":{"type":"fleet","policy":{"type":"fixed","range":9}}}"#,
                "range",
            ),
            (
                r#"{"name":"x","experiment":{"type":"fleet","cluster":{"hots":4}}}"#,
                "hots",
            ),
            (
                r#"{"name":"x","experiment":{"type":"fleet","cluster":{"drains":[{"host":0,"begin":5}]}}}"#,
                "begin",
            ),
        ] {
            let err = format!("{:#}", ScenarioSpec::from_json_str(text).unwrap_err());
            assert!(err.contains("unknown key"), "{text} -> {err}");
            assert!(err.contains(needle), "{text} -> {err}");
        }
    }

    #[test]
    fn malformed_specs_report_helpful_errors() {
        // Required fields.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(r#"{"experiment":{"type":"steady"}}"#).unwrap_err()
        );
        assert!(err.contains("scenario.name"), "{err}");
        let err = format!("{:#}", ScenarioSpec::from_json_str(r#"{"name":"x"}"#).unwrap_err());
        assert!(err.contains("experiment"), "{err}");
        // Enumerated values list the accepted set.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"warp-drive"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("steady|temporal|ensemble|sweep|compare|fleet"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"cost":{"provider":"ec2"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("aws|gcf|google|azure|ibm"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"fleet","cluster":{"scheduler":"best-fit"}}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("first-fit|least-loaded|round-robin|packing"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"fleet","fleet_cap":8,"controller":"bang:1"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("target:UTIL"), "{err}");
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","workload":{"arrival":{"type":"zipf"}},"experiment":{"type":"steady"}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("unknown process"), "{err}");
        // Type errors name the path.
        let err = format!(
            "{:#}",
            ScenarioSpec::from_json_str(
                r#"{"name":"x","experiment":{"type":"steady"},"run":{"seed":-3}}"#
            )
            .unwrap_err()
        );
        assert!(err.contains("run.seed"), "{err}");
        // Invalid JSON reports the parse layer.
        let err =
            format!("{:#}", ScenarioSpec::from_json_str(r#"{"name": "x", "#).unwrap_err());
        assert!(err.contains("not valid JSON"), "{err}");
    }

    #[test]
    fn exp_process_needs_exactly_one_parameterization() {
        let err = format!(
            "{:#}",
            process_from_json(
                &JsonValue::parse(r#"{"type":"exp","rate":1.0,"mean":1.0}"#).unwrap(),
                "p"
            )
            .unwrap_err()
        );
        assert!(err.contains("exactly one"), "{err}");
        assert!(process_from_json(&JsonValue::parse(r#"{"type":"exp"}"#).unwrap(), "p").is_err());
    }
}
