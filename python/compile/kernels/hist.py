"""Layer-1 Pallas kernel: batched fixed-bin histogram.

The simulator's PDF/CDF approximation tools (paper §3: "generate
approximations for PDF and CDF from the simulations") reduce multi-million
sample traces to fixed-bin histograms. This kernel computes the bin counts
as a grid reduction:

* Samples are tiled ``BLOCK_N`` per grid step (VMEM-resident block).
* Each step computes its partial counts as a one-hot mask contraction
  ``(block, nbins)`` — a dense VPU-friendly compare+reduce rather than a
  scatter (TPUs have no fast scatter; this is the standard histogram
  rewrite for SIMD machines).
* All grid steps map to the *same* output block (index_map -> 0), so the
  output behaves as an accumulator: step 0 initializes, later steps add.

VMEM per step (defaults: BLOCK_N=65536, nbins=64, f32):
  samples 64Ki x 4B      = 256 KiB
  one-hot mask (implicit) = materialized tile-by-tile by the compiler
  counts 64 x 4B          = 256 B
Fits comfortably; nbins stays in the lane dimension (64 <= 128).

Lowered with ``interpret=True`` for CPU-PJRT execution (see mlp.py note).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Samples per grid step.
BLOCK_N = 65536


def _hist_kernel(lo_ref, width_ref, x_ref, o_ref, *, nbins: int):
    """Accumulate one sample block's counts into the shared output block."""
    i = pl.program_id(0)
    x = x_ref[...]
    lo = lo_ref[0]
    width = width_ref[0]
    idx = jnp.floor((x - lo) / width).astype(jnp.int32)
    in_range = (idx >= 0) & (idx < nbins)
    idx = jnp.clip(idx, 0, nbins - 1)
    one_hot = (idx[:, None] == jnp.arange(nbins)[None, :]) & in_range[:, None]
    partial = one_hot.astype(jnp.float32).sum(axis=0)

    # First step initializes the accumulator, later steps add to it.
    @pl.when(i == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(i != 0)
    def _acc():
        o_ref[...] = o_ref[...] + partial


@functools.partial(jax.jit, static_argnames=("nbins", "block_n", "interpret"))
def histogram(samples, lo, hi, *, nbins: int = 64, block_n: int = BLOCK_N,
              interpret: bool = True):
    """Histogram counts (float32, shape (nbins,)) of ``samples`` over
    ``[lo, hi)``. ``len(samples)`` must be a multiple of ``block_n``;
    ``histogram_padded`` handles ragged sizes.
    """
    (n,) = samples.shape
    assert n % block_n == 0, f"n {n} not a multiple of {block_n}"
    lo = jnp.asarray([lo], jnp.float32)
    width = jnp.asarray([(hi - lo[0]) / nbins], jnp.float32)

    grid = (n // block_n,)
    kernel = functools.partial(_hist_kernel, nbins=nbins)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        # All steps write the same (only) output block: accumulator.
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.float32),
        interpret=interpret,
    )(lo, width, samples)


def histogram_padded(samples, lo, hi, *, nbins: int = 64, block_n: int = BLOCK_N):
    """Histogram for arbitrary sample counts: pads with out-of-range
    sentinels (hi + 1) which the kernel drops."""
    n = samples.shape[0]
    padded = ((n + block_n - 1) // block_n) * block_n
    if padded != n:
        pad = jnp.full((padded - n,), hi + 1.0, samples.dtype)
        samples = jnp.concatenate([samples, pad])
    return histogram(samples, lo, hi, nbins=nbins, block_n=block_n)
