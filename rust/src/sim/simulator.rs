//! `ServerlessSimulator` — the paper's core contribution: a discrete-event
//! simulator of scale-per-request serverless platforms (AWS Lambda, Google
//! Cloud Functions, IBM Cloud Functions, Apache OpenWhisk, Azure Functions).
//!
//! Model (paper §2):
//! * **Scale-per-request**: an arrival is served by an idle instance (warm
//!   start) if one exists, otherwise a new instance is spun up for it (cold
//!   start). No queuing.
//! * **Newest-first routing**: among idle instances the most recently
//!   created one is chosen, maximizing older instances' chance to expire.
//! * **Expiration**: an idle instance that receives no request for
//!   `expiration_threshold` seconds is terminated (deterministic on AWS et
//!   al.; a stochastic threshold process is supported too).
//! * **Maximum concurrency level**: when `max_concurrency` instances exist
//!   and none is idle, arrivals are rejected with an error status.
//! * A cold request's busy period is one draw of the *cold service process*
//!   (provisioning + service, the paper's "cold response time"); a warm
//!   request's busy period is a draw of the *warm service process*.
//!
//! The lifecycle itself (routing, billing, expiration, level accounting)
//! lives in [`super::core`]; this type is the scale-per-request
//! configuration of that core — concurrency value 1, config-driven
//! expiration ([`super::core::ConfigExpiration`]), plus the two
//! diagnostics only this engine offers: the per-request log and the
//! Fig. 4 transient samples.

use super::core::{ConfigExpiration, CoreParams, EngineCore, LifecycleHooks};
use super::event::{CalendarEventQueue, Event};
use super::fault::FaultProfile;
use super::instance::{FunctionInstance, InstanceId};
use super::process::Process;
use super::results::SimResults;
use super::retry::RetryPolicy;
use super::rng::Rng;
use super::time::SimTime;
use crate::workload::stream::ArrivalSource;

pub use super::core::RequestOutcome;

/// One per-request trace record (only collected when
/// [`SimConfig::capture_request_log`] is set).
#[derive(Debug, Clone)]
pub struct RequestLogEntry {
    pub arrived_at: f64,
    pub outcome: RequestOutcome,
    /// Response time (provisioning+service for cold); 0 for rejected.
    pub response_time: f64,
    /// Serving instance (None for rejected).
    pub instance: Option<InstanceId>,
}

/// Simulation input parameters (the paper's Table 1 input rows).
///
/// Processes are held as the monomorphic [`Process`] enum so the hot-path
/// draws dispatch statically; any [`super::process::SimProcess`] still plugs
/// in via [`Process::custom`] / `.into()`.
#[derive(Clone)]
pub struct SimConfig {
    /// Inter-arrival time process.
    pub arrival: Process,
    /// Optional batch-size process: each arrival epoch brings
    /// `max(1, round(sample))` simultaneous requests (paper §4.2/§6 calls
    /// out batch arrivals as beyond the Markovian models' reach). `None`
    /// means single arrivals.
    pub batch_size: Option<Process>,
    /// Warm-start busy-period process (service time).
    pub warm_service: Process,
    /// Cold-start busy-period process (provisioning + service).
    pub cold_service: Process,
    /// Idle expiration threshold in seconds (AWS Lambda: 600 s).
    /// A stochastic threshold can be supplied via `expiration_process`.
    pub expiration_threshold: f64,
    /// Optional stochastic expiration threshold, overriding the constant.
    pub expiration_process: Option<Process>,
    /// Maximum concurrency level (AWS Lambda default: 1000).
    pub max_concurrency: usize,
    /// Simulation horizon in seconds.
    pub horizon: f64,
    /// Warm-up window to exclude from all statistics.
    pub skip_initial: f64,
    /// RNG seed; equal seeds give bit-identical runs.
    pub seed: u64,
    /// Collect the per-request log (costs memory on long runs).
    pub capture_request_log: bool,
    /// Sample the cumulative-average instance count every this many seconds
    /// (for Fig. 4 style transient plots). 0 disables sampling.
    pub sample_interval: f64,
    /// Fault-injection profile (disabled by default — bit-identical to the
    /// pre-fault engine; see `sim::fault`).
    pub fault: FaultProfile,
    /// Retry policy for failed / timed-out requests (none by default).
    pub retry: RetryPolicy,
}

impl SimConfig {
    /// The paper's Table 1 configuration: Poisson(0.9/s) arrivals,
    /// exp(1.991 s) warm, exp(2.244 s) cold, 10 min threshold, 1e6 s
    /// horizon, 100 s warm-up skip.
    pub fn table1() -> Self {
        SimConfig {
            arrival: Process::exp_rate(0.9),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 1e6,
            skip_initial: 100.0,
            seed: 0x5EED,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_horizon(mut self, horizon: f64) -> Self {
        self.horizon = horizon;
        self
    }

    pub fn with_arrival_rate(mut self, rate: f64) -> Self {
        self.arrival = Process::exp_rate(rate);
        self
    }

    pub fn with_expiration_threshold(mut self, secs: f64) -> Self {
        self.expiration_threshold = secs;
        self
    }

    /// Enable fault injection for this run.
    pub fn with_fault(mut self, fault: FaultProfile) -> Self {
        self.fault = fault;
        self
    }

    /// Set the retry policy for failed / timed-out requests.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Clone this configuration for an independent replication: stateful
    /// processes get fresh state (see [`Process::replica`]) and the RNG is
    /// re-seeded. The ensemble and temporal engines use this so parallel
    /// replications never share mutable process state across threads —
    /// the precondition for bit-identical results at any thread count.
    pub fn replica_with_seed(&self, seed: u64) -> SimConfig {
        let mut cfg = self.clone();
        cfg.arrival = cfg.arrival.replica();
        cfg.batch_size = cfg.batch_size.as_ref().map(Process::replica);
        cfg.warm_service = cfg.warm_service.replica();
        cfg.cold_service = cfg.cold_service.replica();
        cfg.expiration_process = cfg.expiration_process.as_ref().map(Process::replica);
        cfg.seed = seed;
        cfg
    }
}

/// Expected number of concurrently *pending* events for a config: the
/// queue's steady-state occupancy is roughly one completion per request
/// in service plus one expiration per keep-alive window, i.e.
/// `arrival_rate × (mean service + expiration threshold)`, plus the next
/// arrival. Sizes [`CalendarEventQueue::with_capacity`] from the actual
/// workload instead of a fixed constant; clamped so degenerate configs
/// (unknown means, extreme rates) stay sane.
pub(crate) fn expected_pending_events(cfg: &SimConfig) -> usize {
    let gap = cfg.arrival.mean().unwrap_or(0.0);
    let rate = if gap > 0.0 { 1.0 / gap } else { 0.0 };
    let window = cfg.warm_service.mean().unwrap_or(1.0).max(0.0)
        + cfg
            .expiration_process
            .as_ref()
            .and_then(Process::mean)
            .unwrap_or(cfg.expiration_threshold)
            .max(0.0);
    let est = rate * window;
    if est.is_finite() && est > 0.0 {
        (est as usize).clamp(1024, 1 << 20)
    } else {
        1024
    }
}

/// A sampled point of the transient instance-count estimate.
#[derive(Debug, Clone, Copy)]
pub struct CountSample {
    pub t: f64,
    /// Instantaneous total instance count at t.
    pub count: f64,
    /// Cumulative time-average of the count over [skip, t].
    pub cumulative_avg: f64,
}

/// The scale-per-request hook set: config-driven expiration plus the
/// optional per-request log.
struct SprHooks {
    expiration: ConfigExpiration,
    capture: bool,
    log: Vec<RequestLogEntry>,
}

impl LifecycleHooks for SprHooks {
    fn keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        self.expiration.keep_alive(now, rng)
    }

    fn on_request(
        &mut self,
        now: f64,
        outcome: RequestOutcome,
        rt: f64,
        instance: Option<InstanceId>,
    ) {
        if self.capture {
            self.log.push(RequestLogEntry {
                arrived_at: now,
                outcome,
                response_time: rt,
                instance,
            });
        }
    }
}

/// The scale-per-request serverless platform simulator: the
/// [`EngineCore`] lifecycle at concurrency value 1.
pub struct ServerlessSimulator {
    cfg: SimConfig,
    core: EngineCore,
    events: CalendarEventQueue,
    hooks: SprHooks,
    samples: Vec<CountSample>,
    next_sample_at: SimTime,
    /// Optional replacement for the config's inter-arrival process (see
    /// [`set_arrival_source`](Self::set_arrival_source)).
    arrival_override: Option<ArrivalSource>,
}

impl ServerlessSimulator {
    pub fn new(cfg: SimConfig) -> Self {
        // Pre-reserve hot storage: a Table-1-scale run allocates thousands
        // of instances and keeps a few thousand events in flight; growing
        // these Vecs inside the event loop shows up in profiles (§Perf).
        // The event queue is sized from the config's own expected pending
        // count (arrivals in flight + one expiration per live instance)
        // rather than a fixed constant.
        let core = EngineCore::new(CoreParams {
            seed: cfg.seed,
            warm_service: cfg.warm_service.clone(),
            cold_service: cfg.cold_service.clone(),
            batch_size: cfg.batch_size.clone(),
            max_concurrency: cfg.max_concurrency,
            skip_initial: cfg.skip_initial,
            concurrency_value: 1,
            prewarm_lead: 0.0,
            instance_capacity: 1024,
            retain_instances: true,
            fault: cfg.fault.clone(),
            retry: cfg.retry.clone(),
        });
        let hooks = SprHooks {
            expiration: ConfigExpiration {
                threshold: cfg.expiration_threshold,
                process: cfg.expiration_process.clone(),
            },
            capture: cfg.capture_request_log,
            log: Vec::new(),
        };
        ServerlessSimulator {
            core,
            events: CalendarEventQueue::with_capacity(expected_pending_events(&cfg)),
            hooks,
            samples: Vec::new(),
            next_sample_at: SimTime::from_secs(cfg.skip_initial.max(0.0)),
            arrival_override: None,
            cfg,
        }
    }

    /// Replace the arrival source for the next [`run`](Self::run): any
    /// [`ArrivalSource`] — a recorded workload replay, a streaming diurnal
    /// generator — instead of the config's inter-arrival process. The
    /// single-function engine pulls arrivals through the same seam as the
    /// fleet ([`EngineCore::schedule_next_arrival`]).
    pub fn set_arrival_source(&mut self, src: ArrivalSource) {
        self.arrival_override = Some(src);
    }

    /// Seed the simulator with a custom initial state: `idle` instances idle
    /// for `idle_ages[i]` seconds already, and `running` instances that have
    /// `running_remaining[i]` seconds of service left. Used by the temporal
    /// simulator (paper's `ServerlessTemporalSimulator`).
    pub fn set_initial_state(&mut self, idle_ages: &[f64], running_remaining: &[f64]) {
        self.core
            .seed_initial_state(&mut self.events, &mut self.hooks, idle_ages, running_remaining);
    }

    /// Attach a telemetry observer for the next [`run`](Self::run)
    /// (DESIGN.md §Observability). Capture never changes results: it draws
    /// no RNG and schedules no events.
    pub fn set_observer(&mut self, observer: crate::telemetry::Observer) {
        self.core.set_observer(observer);
    }

    /// Recover the recorded telemetry after [`run`](Self::run) (`None`
    /// without an observer, or with a custom sink).
    pub fn take_recorder(&mut self) -> Option<crate::telemetry::TelemetryRecorder> {
        self.core.take_observer().and_then(crate::telemetry::Observer::into_recorder)
    }

    /// Emit Fig.4-style samples up to the current time.
    fn emit_samples(&mut self) {
        if self.cfg.sample_interval <= 0.0 || !self.core.stats_started() {
            return;
        }
        while self.next_sample_at <= self.core.now() {
            // Cumulative average over [stats_start, next_sample_at]: the
            // accumulators are synced at every level change, so the
            // remainder since the last sync is at the current level.
            let t = self.next_sample_at;
            let elapsed = t.since(self.core.stats_start());
            let (live, _, _) = self.core.live_counts();
            let cum = if elapsed > 0.0 {
                let tw = self.core.server_tw();
                let gap = t.since(tw.last_time()).max(0.0);
                (tw.integral() + tw.current() * gap) / elapsed
            } else {
                live as f64
            };
            self.samples.push(CountSample {
                t: t.as_secs(),
                count: live as f64,
                cumulative_avg: cum,
            });
            self.next_sample_at = t.after(self.cfg.sample_interval);
        }
    }

    /// Run to the horizon and produce results.
    pub fn run(&mut self) -> SimResults {
        let horizon = SimTime::from_secs(self.cfg.horizon);
        // Arrivals pull lazily through the shared seam: the config's
        // process by default, or an injected override (trace replay,
        // streaming generator). The first pull happens at t = 0, so a
        // process source draws the same first gap as ever.
        let mut arrival = self
            .arrival_override
            .take()
            .unwrap_or_else(|| ArrivalSource::process(self.cfg.arrival.clone()));
        self.core.schedule_next_arrival(&mut self.events, &mut arrival);
        // Degradation windows (if any) are part of the run's timeline; a
        // fault-free profile schedules nothing here.
        self.core.schedule_fault_timeline(&mut self.events);
        self.events.schedule(horizon, Event::Horizon);

        while let Some((t, ev)) = self.events.pop() {
            self.core.maybe_start_stats(t);
            self.core.set_now(t);
            self.emit_samples();
            self.core.sample_tick(None);
            match ev {
                Event::Arrival => {
                    self.core.handle_arrival(&mut self.events, &mut self.hooks);
                    // Schedule the next arrival epoch through the seam.
                    self.core.schedule_next_arrival(&mut self.events, &mut arrival);
                }
                Event::Departure(id) => {
                    self.core.handle_departure(&mut self.events, &mut self.hooks, id)
                }
                Event::Expiration { id, gen } => {
                    self.core.handle_expiration(&mut self.events, &mut self.hooks, id, gen)
                }
                Event::Provision => self.core.handle_provision(&mut self.events, &mut self.hooks),
                Event::ProvisioningDone(id) => {
                    self.core.handle_provisioning_done(&mut self.events, &mut self.hooks, id)
                }
                Event::RequestTimeout(id) => {
                    self.core.handle_request_timeout(&mut self.events, &mut self.hooks, id)
                }
                Event::RetryArrival { attempt, prev_delay_bits } => self.core.handle_retry_arrival(
                    &mut self.events,
                    &mut self.hooks,
                    attempt,
                    f64::from_bits(prev_delay_bits),
                ),
                Event::DegradationStart { window } => self.core.handle_degradation_start(window),
                Event::DegradationEnd { window } => self.core.handle_degradation_end(window),
                Event::ControlTick => {
                    unreachable!("control ticks are scheduled only by the fleet run loops")
                }
                Event::Horizon => break,
            }
        }
        self.core.close(horizon);
        self.emit_samples();
        self.core.sample_tick(None);
        self.core.results()
    }

    /// The per-request log (empty unless `capture_request_log`).
    pub fn request_log(&self) -> &[RequestLogEntry] {
        &self.hooks.log
    }

    /// Fig.4-style transient samples (empty unless `sample_interval > 0`).
    pub fn samples(&self) -> &[CountSample] {
        &self.samples
    }

    /// All instances ever created (for lifecycle analysis tooling),
    /// materialized from the core's struct-of-arrays arena.
    pub fn instances(&self) -> Vec<FunctionInstance> {
        self.core.instances()
    }

    /// Current live/busy/idle counts — exposed for invariant tests.
    pub fn live_counts(&self) -> (usize, usize, usize) {
        self.core.live_counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::instance::InstanceState;

    fn quick_cfg(rate: f64, horizon: f64, seed: u64) -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(rate),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 100.0,
            seed,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
        }
    }

    #[test]
    fn littles_law_running_servers() {
        // Little's law: E[running] = lambda * E[S] (rejections are nil here).
        let mut sim = ServerlessSimulator::new(quick_cfg(0.9, 200_000.0, 1));
        let r = sim.run();
        let expected = 0.9 * 1.991; // cold fraction negligible
        assert!(
            (r.avg_running_count - expected).abs() / expected < 0.03,
            "running={} expected~{}",
            r.avg_running_count,
            expected
        );
    }

    #[test]
    fn reproducible_given_seed() {
        let a = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 42)).run();
        let b = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 42)).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert!((a.avg_server_count - b.avg_server_count).abs() < 1e-12);
    }

    #[test]
    fn enum_and_custom_dispatch_runs_bit_identical() {
        // The monomorphic hot path must reproduce the trait-object ("seed
        // behavior") path exactly: same draws, same events, same stats.
        use crate::sim::process::ExpProcess;
        let base = quick_cfg(0.9, 50_000.0, 77);
        let mut custom = base.clone();
        custom.arrival = Process::custom(ExpProcess::with_rate(0.9));
        custom.warm_service = Process::custom(ExpProcess::with_mean(1.991));
        custom.cold_service = Process::custom(ExpProcess::with_mean(2.244));
        let a = ServerlessSimulator::new(base).run();
        let b = ServerlessSimulator::new(custom).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.instances_expired, b.instances_expired);
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
        assert_eq!(
            a.billed_instance_seconds.to_bits(),
            b.billed_instance_seconds.to_bits()
        );
        assert_eq!(a.response_p99.to_bits(), b.response_p99.to_bits());
    }

    #[test]
    fn different_seeds_differ() {
        let a = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 1)).run();
        let b = ServerlessSimulator::new(quick_cfg(0.9, 50_000.0, 2)).run();
        assert_ne!(a.total_requests, b.total_requests);
    }

    #[test]
    fn counts_are_consistent() {
        let mut sim = ServerlessSimulator::new(quick_cfg(1.5, 100_000.0, 3));
        let r = sim.run();
        assert_eq!(r.total_requests, r.cold_requests + r.warm_requests + r.rejected_requests);
        assert!(r.cold_start_prob > 0.0 && r.cold_start_prob < 0.05);
        assert_eq!(r.rejected_requests, 0);
        // total = running + idle (time-weighted means add up)
        assert!((r.avg_server_count - r.avg_running_count - r.avg_idle_count).abs() < 1e-9);
        // No prewarm driver on this engine: the counters stay zero.
        assert_eq!(r.prewarm_starts, 0);
        assert_eq!(r.wasted_prewarm_seconds, 0.0);
    }

    #[test]
    fn max_concurrency_causes_rejections() {
        let mut cfg = quick_cfg(10.0, 20_000.0, 4);
        cfg.max_concurrency = 5; // way below lambda * E[S] ~ 20
        let mut sim = ServerlessSimulator::new(cfg);
        let r = sim.run();
        assert!(r.rejected_requests > 0);
        assert!(r.rejection_prob > 0.3, "p_reject={}", r.rejection_prob);
        assert!(r.max_server_count <= 5.0);
    }

    #[test]
    fn deterministic_processes_no_cold_after_first() {
        // Arrivals every 5 s, service 1 s, threshold 600 s: after the first
        // cold start the single instance is always reused.
        let cfg = SimConfig {
            arrival: Process::constant(5.0),
            batch_size: None,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 10_000.0,
            skip_initial: 0.0,
            seed: 5,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
        };
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.cold_requests, 1);
        assert_eq!(r.rejected_requests, 0);
        assert!((r.max_server_count - 1.0).abs() < 1e-12);
    }

    #[test]
    fn instances_expire_when_idle_long_enough() {
        // Arrivals every 700 s > threshold 600 s: every request is cold.
        let cfg = SimConfig {
            arrival: Process::constant(700.0),
            batch_size: None,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon: 100_000.0,
            skip_initial: 0.0,
            seed: 6,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
        };
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.warm_requests, 0);
        assert!(r.cold_requests > 100);
        assert!(r.instances_expired >= r.cold_requests - 1);
        // Lifespan = busy (2 s) + idle threshold (600 s)
        assert!((r.avg_lifespan - 602.0).abs() < 1e-6, "lifespan={}", r.avg_lifespan);
    }

    #[test]
    fn request_log_captured_when_enabled() {
        let mut cfg = quick_cfg(0.9, 5_000.0, 7);
        cfg.capture_request_log = true;
        let mut sim = ServerlessSimulator::new(cfg);
        let r = sim.run();
        let log = sim.request_log();
        assert_eq!(log.len() as u64, r.total_requests);
        assert!(log.windows(2).all(|w| w[0].arrived_at <= w[1].arrived_at));
        let cold = log.iter().filter(|e| e.outcome == RequestOutcome::Cold).count() as u64;
        assert_eq!(cold, r.cold_requests);
    }

    #[test]
    fn newest_first_routing_lets_oldest_expire() {
        // Two instances get created by a burst, then load drops to one
        // request at a time: the newest instance should absorb all traffic
        // and the oldest should expire.
        let mut cfg = quick_cfg(0.9, 50_000.0, 8);
        cfg.capture_request_log = true;
        let mut sim = ServerlessSimulator::new(cfg);
        let _ = sim.run();
        // Find any instance that was reused while an older one expired -
        // structural check: among terminated instances, termination is
        // dominated by low request counts (they were starved by routing).
        let insts = sim.instances();
        let terminated: Vec<_> = insts
            .iter()
            .filter(|i| i.state == InstanceState::Terminated)
            .collect();
        assert!(!terminated.is_empty());
    }

    #[test]
    fn initial_state_seeding() {
        let mut cfg = quick_cfg(0.9, 1000.0, 9);
        cfg.skip_initial = 0.0;
        let mut sim = ServerlessSimulator::new(cfg);
        sim.set_initial_state(&[0.0, 100.0, 599.0], &[5.0, 1.0]);
        let (live, busy, idle) = sim.live_counts();
        assert_eq!((live, busy, idle), (5, 2, 3));
        let r = sim.run();
        // The instance idle for 599 s expires almost immediately unless a
        // request reaches it first; either way the run completes sanely.
        assert!(r.avg_server_count > 0.0);
    }

    #[test]
    fn recorded_workload_replays_through_the_arrival_seam() {
        use std::sync::Arc;
        let mut cfg = quick_cfg(0.9, 100.0, 1);
        cfg.skip_initial = 0.0;
        cfg.warm_service = Process::constant(1.0);
        cfg.cold_service = Process::constant(2.0);
        let mut sim = ServerlessSimulator::new(cfg);
        sim.set_arrival_source(ArrivalSource::replay(Arc::new(vec![10.0, 20.0, 30.0])).unwrap());
        let r = sim.run();
        assert_eq!(r.total_requests, 3);
        assert_eq!(r.cold_requests, 1);
        assert_eq!(r.warm_requests, 2);
    }

    #[test]
    fn samples_emitted_at_interval() {
        let mut cfg = quick_cfg(0.9, 10_000.0, 10);
        cfg.sample_interval = 100.0;
        let mut sim = ServerlessSimulator::new(cfg);
        let _ = sim.run();
        let samples = sim.samples();
        assert!(samples.len() >= 95, "samples={}", samples.len());
        assert!(samples.windows(2).all(|w| w[1].t > w[0].t));
    }

    #[test]
    fn observer_capture_matches_request_log_and_leaves_results_bit_identical() {
        use crate::telemetry::Observer;
        let mut cfg = quick_cfg(0.9, 5_000.0, 7);
        cfg.capture_request_log = true;
        let base = ServerlessSimulator::new(cfg.clone()).run();
        let mut sim = ServerlessSimulator::new(cfg);
        sim.set_observer(Observer::recording(0, 50.0));
        let r = sim.run();
        let rec = sim.take_recorder().unwrap();
        // Enabled telemetry leaves every metric bit-identical.
        assert_eq!(r.total_requests, base.total_requests);
        assert_eq!(r.avg_server_count.to_bits(), base.avg_server_count.to_bits());
        assert_eq!(r.response_p99.to_bits(), base.response_p99.to_bits());
        // One span per measured request, aligned with the request log.
        let log = sim.request_log();
        assert_eq!(rec.spans.len() as u64, r.total_requests);
        assert_eq!(rec.spans.len(), log.len());
        for (s, e) in rec.spans.iter().zip(log) {
            assert_eq!(s.started_at, e.arrived_at);
            assert_eq!(s.response_time, e.response_time);
            assert_eq!(s.instance, e.instance.map(|id| id.0));
            assert_eq!(s.attempt, 1);
        }
        // Sample ticks step by the interval from the skip boundary.
        assert!(!rec.samples.is_empty());
        assert_eq!(rec.samples[0].t, 100.0);
        assert!(rec.samples.windows(2).all(|w| w[1].t - w[0].t == 50.0));
        assert_eq!(rec.samples.last().unwrap().total_requests, r.total_requests);
    }

    // ---------------------------------------------- reliability layer

    /// Deterministic base for fault tests: arrivals every 5 s, warm 1 s,
    /// cold 2 s, no warm-up skip.
    fn fault_cfg(horizon: f64) -> SimConfig {
        let mut cfg = quick_cfg(0.9, horizon, 11);
        cfg.arrival = Process::constant(5.0);
        cfg.warm_service = Process::constant(1.0);
        cfg.cold_service = Process::constant(2.0);
        cfg.skip_initial = 0.0;
        cfg
    }

    #[test]
    fn certain_transient_failures_fail_everything_and_retry() {
        let mut cfg = fault_cfg(1000.0);
        cfg.fault = FaultProfile::disabled().with_failure_prob(1.0);
        cfg.retry = RetryPolicy::fixed(0.5, 2);
        let r = ServerlessSimulator::new(cfg).run();
        // Every dispatched attempt fails; each original request retries
        // once (max_attempts 2) and then gives up.
        assert_eq!(r.failed_requests, r.cold_requests + r.warm_requests);
        assert!(r.retry_attempts > 0);
        assert_eq!(r.retry_exhausted, r.retry_attempts);
        assert_eq!(r.goodput, 0.0);
        assert_eq!(r.success_rate(), 0.0);
        assert!(r.wasted_work_seconds > 0.0);
        // Retry amplification shows in the observed load: total includes
        // the re-arrivals.
        assert_eq!(r.total_requests, (r.total_requests - r.retry_attempts) * 2);
    }

    #[test]
    fn retry_budget_caps_reenqueues() {
        let mut cfg = fault_cfg(1000.0);
        cfg.fault = FaultProfile::disabled().with_failure_prob(1.0);
        cfg.retry = RetryPolicy::fixed(0.5, 5).with_budget(3);
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.retry_attempts, 3);
        assert!(r.retry_exhausted > 0);
    }

    #[test]
    fn timeout_truncates_response_and_counts_wasted_work() {
        let mut cfg = fault_cfg(1000.0);
        // Service longer than the timeout: every request is cut at 3 s.
        cfg.warm_service = Process::constant(10.0);
        cfg.cold_service = Process::constant(10.0);
        cfg.fault = FaultProfile::disabled().with_timeout(3.0);
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.timeout_requests, r.cold_requests + r.warm_requests);
        assert!((r.avg_response_time - 3.0).abs() < 1e-9, "rt={}", r.avg_response_time);
        assert!((r.response_p99 - 3.0).abs() < 1e-9);
        assert!(
            (r.wasted_work_seconds - 3.0 * r.timeout_requests as f64).abs() < 1e-6,
            "wasted={}",
            r.wasted_work_seconds
        );
        assert_eq!(r.goodput, 0.0);
        // KeepInstance semantics: the sandbox survives its timed-out
        // execution, so after the first cold start everything is warm.
        assert_eq!(r.cold_requests, 1);
    }

    #[test]
    fn timeout_kill_semantics_tear_down_the_instance() {
        let mut cfg = fault_cfg(1000.0);
        cfg.warm_service = Process::constant(10.0);
        cfg.cold_service = Process::constant(10.0);
        cfg.fault = FaultProfile::disabled()
            .with_timeout(3.0)
            .with_timeout_action(crate::sim::fault::TimeoutAction::KillInstance);
        let r = ServerlessSimulator::new(cfg).run();
        // Each timeout kills its instance, so every request cold-starts.
        assert_eq!(r.warm_requests, 0);
        assert_eq!(r.cold_requests, r.timeout_requests);
        assert!(r.instances_expired >= r.cold_requests - 1);
        // Billed for the truncated busy periods only.
        assert!(
            (r.billed_instance_seconds - 3.0 * r.timeout_requests as f64).abs() < 1e-6,
            "billed={}",
            r.billed_instance_seconds
        );
    }

    #[test]
    fn certain_coldstart_failures_black_hole_the_run() {
        let mut cfg = fault_cfg(1000.0);
        cfg.fault = FaultProfile::disabled().with_coldstart_failure_prob(1.0);
        let r = ServerlessSimulator::new(cfg).run();
        // No instance ever materializes: every arrival is a provisioning
        // failure, and the counter taxonomy still adds up.
        assert_eq!(r.cold_requests + r.warm_requests + r.rejected_requests, 0);
        assert_eq!(r.coldstart_failures, r.total_requests);
        assert_eq!(
            r.total_requests,
            r.cold_requests + r.warm_requests + r.rejected_requests + r.coldstart_failures
        );
    }

    #[test]
    fn full_outage_degradation_window_rejects_requests() {
        let mut cfg = fault_cfg(1000.0);
        cfg.fault = FaultProfile::disabled().with_degradation(0.0, 1000.0, 0.0);
        let r = ServerlessSimulator::new(cfg).run();
        assert_eq!(r.cold_requests + r.warm_requests, 0);
        assert_eq!(r.rejected_requests, r.total_requests);
        assert!(r.total_requests > 100);
    }

    #[test]
    fn degradation_window_is_scoped_in_time() {
        let mut cfg = fault_cfg(1000.0);
        // Keep-alive shorter than the inter-arrival gap: every request
        // needs a cold start, so the outage window (degradation blocks new
        // instances, it does not evict warm ones) turns its arrivals into
        // rejections while the rest of the run is unaffected.
        cfg.expiration_threshold = 1.0;
        cfg.fault = FaultProfile::disabled().with_degradation(400.0, 600.0, 0.0);
        let r = ServerlessSimulator::new(cfg).run();
        assert!(r.rejected_requests > 0);
        assert!(r.cold_requests > 0);
        // ~40 of ~200 arrivals land in the window.
        assert!(r.rejected_requests < r.total_requests / 2);
    }

    #[test]
    fn fault_run_is_reproducible_and_seed_sensitive() {
        let mk = |seed: u64| {
            let mut cfg = quick_cfg(0.9, 20_000.0, seed);
            cfg.fault = FaultProfile::disabled().with_failure_prob(0.2);
            cfg.retry = RetryPolicy::exponential(1.0, 60.0, 3);
            ServerlessSimulator::new(cfg).run()
        };
        let a = mk(42);
        let b = mk(42);
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.failed_requests, b.failed_requests);
        assert_eq!(a.retry_attempts, b.retry_attempts);
        assert_eq!(a.avg_response_time.to_bits(), b.avg_response_time.to_bits());
        let c = mk(43);
        assert_ne!(a.failed_requests, c.failed_requests);
    }

    #[test]
    fn failure_rate_matches_configured_probability() {
        let mut cfg = quick_cfg(0.9, 100_000.0, 12);
        cfg.fault = FaultProfile::disabled().with_failure_prob(0.1);
        let r = ServerlessSimulator::new(cfg).run();
        let served = (r.cold_requests + r.warm_requests) as f64;
        let observed = r.failed_requests as f64 / served;
        assert!((observed - 0.1).abs() < 0.01, "observed failure rate {observed}");
        // Goodput + failure throughput = served throughput.
        let served_rate = served / r.measured_time;
        let fail_rate = (r.failed_requests + r.timeout_requests) as f64 / r.measured_time;
        assert!((r.goodput + fail_rate - served_rate).abs() < 1e-9);
    }
}
