//! Fleet-internal discrete-event machinery: a function-tagged event queue
//! and the per-function engine.
//!
//! [`FunctionEngine`] is the fleet configuration of the one shared
//! lifecycle core ([`crate::sim::core::EngineCore`]) — the same
//! scale-per-request model as [`crate::sim::ServerlessSimulator`]
//! (newest-first routing, generation-guarded lazy expiration, lazy level
//! sync, O(1) bookkeeping — see DESIGN.md §Perf), differing only through
//! its [`crate::sim::core::LifecycleHooks`]:
//!
//! * expiration thresholds come from a pluggable
//!   [`super::policy::KeepAlivePolicy`] instead of a config field,
//! * cold starts are additionally admitted against the shared
//!   [`FleetCapacity`] — the flat [`FleetGate`] counter or a
//!   finite-resource [`crate::cluster::ClusterState`] — so N engines can
//!   couple through one shared capacity on a single [`FleetQueue`], and
//! * with a positive provisioning lead, the policy's head-percentile arm
//!   drives prewarm ([`Event::Provision`]) events through the core.
//!
//! **Bit-identity contract**: with a [`super::policy::FixedExpiration`]
//! policy, an unbounded gate and prewarm disabled, an engine consumes its
//! RNG in exactly the same sequence as `ServerlessSimulator`
//! (first-arrival draw, per-epoch batch/service draws, next-arrival draw)
//! and schedules events in the same order, so a 1-function fleet
//! reproduces the core simulator's [`SimResults`] bit-for-bit on the same
//! seed. Since the unification this is the same code path by
//! construction; `fleet::simulator` and `tests/engine_unification.rs`
//! still pin it.

use super::policy::KeepAlivePolicy;
use super::simulator::FunctionSpec;
use crate::cluster::ClusterState;
use crate::sim::calendar::CalendarQueue;
use crate::sim::core::{CoreParams, EngineCore, LifecycleHooks, Scheduler};
use crate::sim::event::Event;
use crate::sim::fault::FaultProfile;
use crate::sim::retry::RetryPolicy;
use crate::sim::process::Process;
use crate::sim::results::SimResults;
use crate::sim::rng::Rng;
use crate::sim::time::SimTime;
use crate::workload::stream::ArrivalSource;

/// Future event list shared by every function in a fleet run, built on
/// the same [`CalendarQueue`] as the single-function simulators with a
/// `(func, event)` payload: pops are ordered by `(time, insertion seq)`,
/// the same deterministic tie-break as `sim::event`. Private to the fleet
/// module: external callers drive fleets through
/// [`super::simulator::FleetConfig`].
#[derive(Debug, Default)]
pub(super) struct FleetQueue {
    cal: CalendarQueue<(u32, Event)>,
}

impl FleetQueue {
    pub(super) fn with_capacity(cap: usize) -> Self {
        FleetQueue { cal: CalendarQueue::with_capacity(cap) }
    }

    #[inline]
    pub(super) fn schedule(&mut self, at: SimTime, func: u32, event: Event) {
        self.cal.push(at, (func, event));
    }

    #[inline]
    pub(super) fn pop(&mut self) -> Option<(SimTime, u32, Event)> {
        self.cal.pop().map(|(at, _, (func, event))| (at, func, event))
    }
}

/// [`Scheduler`] adapter tagging every scheduled event with its function
/// index — how N cores share one [`FleetQueue`].
struct FuncScheduler<'a> {
    queue: &'a mut FleetQueue,
    func: u32,
}

impl Scheduler for FuncScheduler<'_> {
    #[inline]
    fn schedule(&mut self, at: SimTime, event: Event) {
        self.queue.schedule(at, self.func, event);
    }
}

/// Fleet-wide admission state: the shared live-instance count checked (and
/// charged) on every cold start. With `cap = usize::MAX` the gate never
/// binds and functions evolve independently — the sharded runner's case.
/// Private to the fleet module (coupling is an implementation detail of
/// `FleetConfig::run`).
#[derive(Debug, Clone)]
pub(super) struct FleetGate {
    pub(super) live: usize,
    pub(super) cap: usize,
    /// Rejections attributable to the fleet cap alone (the per-function
    /// concurrency limit would have admitted the request).
    pub(super) cap_rejections: u64,
}

impl FleetGate {
    pub(super) fn unbounded() -> Self {
        FleetGate { live: 0, cap: usize::MAX, cap_rejections: 0 }
    }

    pub(super) fn capped(cap: usize) -> Self {
        FleetGate { live: 0, cap, cap_rejections: 0 }
    }

    /// Remaining admission slots, saturating at 0 when a controller has
    /// lowered the cap below the live count (busy instances are never
    /// killed, so `live > cap` is a legal transient).
    pub(super) fn headroom(&self) -> u64 {
        self.cap.saturating_sub(self.live) as u64
    }
}

/// The capacity dimension an autoscaling controller actuates, decoupled
/// from per-event admission: observe `(utilization signal, capacity
/// units)`, then move the capacity toward a target. Implemented by the
/// flat gate (the cap is a pure admission counter, so actuation is
/// instant) and by the clustered runner's host set (scale-out waits out
/// a provisioning delay; scale-in retires hosts through the cordon/evict
/// machinery) — see `crate::control` and DESIGN.md §Control.
pub(super) trait ScalableCapacity {
    /// `(observed utilization, current capacity units)`.
    fn observe(&self) -> (f64, u64);

    /// Move toward `desired` capacity units at simulated time `now`.
    fn scale_to(&mut self, desired: u64, now: SimTime);
}

impl ScalableCapacity for FleetGate {
    fn observe(&self) -> (f64, u64) {
        let cap = self.cap as u64;
        (self.live as f64 / cap.max(1) as f64, cap)
    }

    fn scale_to(&mut self, desired: u64, _now: SimTime) {
        // Raising admits on the next cold start; lowering never kills
        // busy instances — it just stops admitting until they drain.
        self.cap = desired as usize;
    }
}

/// The fleet-wide capacity model cold starts are admitted against:
/// either the flat live-instance counter ([`FleetGate`]) or the
/// finite-resource cluster ([`ClusterState`]), whose capacity is
/// emergent from host bin-packing. The `Gate` arm performs exactly the
/// pre-cluster arithmetic, so runs without a cluster stay bit-identical.
pub(super) enum FleetCapacity<'a> {
    /// Flat counter vs. a fleet-wide cap.
    Gate(&'a mut FleetGate),
    /// Host-level placement through the cluster scheduler.
    Cluster(&'a mut ClusterState),
}

impl FleetCapacity<'_> {
    fn admit(&mut self, memory_mb: f64) -> bool {
        match self {
            FleetCapacity::Gate(g) => g.live < g.cap,
            FleetCapacity::Cluster(c) => c.admit(memory_mb),
        }
    }

    fn on_cold_start(&mut self, func: u32, memory_mb: f64) {
        match self {
            FleetCapacity::Gate(g) => g.live += 1,
            FleetCapacity::Cluster(c) => c.commit(func, memory_mb),
        }
    }

    fn on_expire(&mut self, func: u32, memory_mb: f64) {
        match self {
            FleetCapacity::Gate(g) => g.live -= 1,
            FleetCapacity::Cluster(c) => c.release(func, memory_mb),
        }
    }

    fn on_gate_only_rejection(&mut self) {
        match self {
            FleetCapacity::Gate(g) => g.cap_rejections += 1,
            FleetCapacity::Cluster(c) => c.gate_reject(),
        }
    }
}

/// The fleet hook set: policy-driven keep-alive (and its prewarm arm) plus
/// capacity-checked admission. Built per event-handler call from borrows
/// of the engine's policy and the run's shared capacity model; `func` and
/// `memory_mb` give the capacity model the container footprint the core's
/// identity-free hooks don't carry.
struct FleetHooks<'a, 'b> {
    policy: &'a mut dyn KeepAlivePolicy,
    cap: &'a mut FleetCapacity<'b>,
    func: u32,
    memory_mb: f64,
}

impl LifecycleHooks for FleetHooks<'_, '_> {
    fn keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        self.policy.keep_alive(now, rng)
    }

    fn on_arrival_epoch(&mut self, now: f64) {
        // Adaptive policies observe every arrival epoch (no RNG use, so
        // the FixedExpiration bit-identity contract is unaffected).
        self.policy.on_arrival(now);
    }

    fn admit_cold(&mut self) -> bool {
        self.cap.admit(self.memory_mb)
    }

    fn on_cold_start(&mut self) {
        self.cap.on_cold_start(self.func, self.memory_mb);
    }

    fn on_expire(&mut self) {
        self.cap.on_expire(self.func, self.memory_mb);
    }

    fn on_gate_only_rejection(&mut self) {
        self.cap.on_gate_only_rejection();
    }

    fn prewarm_ready_at(&mut self, now: f64) -> Option<f64> {
        self.policy.predict_next_arrival(now)
    }

    fn prewarm_keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        self.policy.prewarm_keep_alive(now, rng)
    }
}

/// One function's simulation state within a fleet run: an [`EngineCore`]
/// plus the fleet-specific arrival source and keep-alive policy.
pub(super) struct FunctionEngine {
    func: u32,
    arrival: ArrivalSource,
    core: EngineCore,
    policy: Box<dyn KeepAlivePolicy>,
    /// Container memory footprint (MB) charged against cluster hosts.
    memory_mb: f64,
}

impl FunctionEngine {
    pub(super) fn new(
        func: u32,
        spec: &FunctionSpec,
        mut policy: Box<dyn KeepAlivePolicy>,
        skip_initial: f64,
        prewarm_lead: f64,
        horizon: f64,
        fault: FaultProfile,
        retry: RetryPolicy,
    ) -> Self {
        // One fresh ArrivalSource per engine per run: process sources get
        // replica state (the fleet analogue of `SimConfig::replica_with_seed`
        // — shards never share mutable process state, which the determinism
        // contract requires) and streaming sources reseed from their spec.
        let arrival = spec.arrival.runtime(horizon);
        if prewarm_lead > 0.0 {
            policy.enable_prewarm(prewarm_lead);
        }
        let core = EngineCore::new(CoreParams {
            seed: spec.seed,
            warm_service: spec.warm_service.replica(),
            cold_service: spec.cold_service.replica(),
            batch_size: spec.batch_size.as_ref().map(Process::replica),
            max_concurrency: spec.max_concurrency,
            skip_initial,
            concurrency_value: 1,
            prewarm_lead,
            instance_capacity: 64,
            // Fleet runs never read per-instance history (results come
            // from core accumulators), so recycle terminated slots and
            // keep per-function memory bounded at 10k+ functions.
            retain_instances: false,
            fault,
            retry,
        });
        FunctionEngine { func, arrival, core, policy, memory_mb: spec.memory_mb }
    }

    /// Schedule this function's first arrival through the shared seam
    /// ([`EngineCore::schedule_next_arrival`] at t = 0). For process
    /// arrivals this consumes one draw — the same first draw
    /// `ServerlessSimulator::run` makes before entering its loop. Also
    /// plants the fault profile's degradation timeline (a no-op — and no
    /// scheduled events — when no windows are configured).
    pub(super) fn schedule_first_arrival(&mut self, queue: &mut FleetQueue) {
        let mut sched = FuncScheduler { queue, func: self.func };
        self.core.schedule_next_arrival(&mut sched, &mut self.arrival);
        self.core.schedule_fault_timeline(&mut sched);
    }

    #[inline]
    pub(super) fn set_now(&mut self, t: SimTime) {
        self.core.set_now(t);
    }

    /// Attach a telemetry observer to this function's core
    /// (DESIGN.md §Observability). Capture draws no RNG and schedules no
    /// events, so the bit-identity contract above is unaffected.
    pub(super) fn set_observer(&mut self, observer: crate::telemetry::Observer) {
        self.core.set_observer(observer);
    }

    /// Detach the observer (if any) and return its in-memory recording.
    pub(super) fn take_recorder(&mut self) -> Option<crate::telemetry::TelemetryRecorder> {
        self.core.take_observer().and_then(crate::telemetry::Observer::into_recorder)
    }

    /// Emit any internal-state samples due at the engine's current clock
    /// (no-op without an observer). `cap_headroom` is the fleet gate's
    /// remaining capacity for the coupled runner, the cluster's free
    /// memory (MB) for the clustered runner, `None` when uncapped.
    #[inline]
    pub(super) fn sample_tick(&mut self, cap_headroom: Option<u64>) {
        self.core.sample_tick(cap_headroom);
    }

    pub(super) fn maybe_start_stats(&mut self, event_time: SimTime) {
        self.core.maybe_start_stats(event_time);
    }

    /// Number of fully idle instances (candidates for forced eviction).
    #[inline]
    pub(super) fn idle_count(&self) -> usize {
        self.core.live_counts().2
    }

    /// This function's container memory footprint (MB).
    #[inline]
    pub(super) fn memory_mb(&self) -> f64 {
        self.memory_mb
    }

    /// Force-evict up to `n` idle instances (oldest first), releasing
    /// their capacity through the hooks. Returns how many were evicted.
    pub(super) fn evict_idle(&mut self, cap: &mut FleetCapacity<'_>, n: usize) -> usize {
        let mut hooks = FleetHooks {
            policy: self.policy.as_mut(),
            cap,
            func: self.func,
            memory_mb: self.memory_mb,
        };
        self.core.evict_idle(&mut hooks, n)
    }

    /// Dispatch one event to this engine's core — the single entry point
    /// all fleet run loops use, so a new core event variant is wired in
    /// exactly one place. [`Event::Horizon`] terminates the loops and must
    /// never reach here.
    pub(super) fn handle_event(
        &mut self,
        queue: &mut FleetQueue,
        cap: &mut FleetCapacity<'_>,
        ev: Event,
    ) {
        let mut sched = FuncScheduler { queue, func: self.func };
        let mut hooks = FleetHooks {
            policy: self.policy.as_mut(),
            cap,
            func: self.func,
            memory_mb: self.memory_mb,
        };
        match ev {
            Event::Arrival => {
                self.core.handle_arrival(&mut sched, &mut hooks);
                // Next arrival epoch through the one ArrivalSource seam
                // (process draw, trace replay, or streaming generator) —
                // after the service draws, the historical draw order.
                self.core.schedule_next_arrival(&mut sched, &mut self.arrival);
            }
            Event::Departure(id) => self.core.handle_departure(&mut sched, &mut hooks, id),
            Event::Expiration { id, gen } => {
                self.core.handle_expiration(&mut sched, &mut hooks, id, gen)
            }
            Event::Provision => self.core.handle_provision(&mut sched, &mut hooks),
            Event::ProvisioningDone(id) => {
                self.core.handle_provisioning_done(&mut sched, &mut hooks, id)
            }
            Event::RequestTimeout(id) => {
                self.core.handle_request_timeout(&mut sched, &mut hooks, id)
            }
            Event::RetryArrival { attempt, prev_delay_bits } => self.core.handle_retry_arrival(
                &mut sched,
                &mut hooks,
                attempt,
                f64::from_bits(prev_delay_bits),
            ),
            Event::DegradationStart { window } => self.core.handle_degradation_start(window),
            Event::DegradationEnd { window } => self.core.handle_degradation_end(window),
            Event::ControlTick => {
                unreachable!("the run loops intercept control ticks before dispatch")
            }
            Event::Horizon => unreachable!("the run loops terminate on Horizon"),
        }
    }

    /// Close accumulators at the horizon and produce this function's
    /// results.
    pub(super) fn finish(&mut self, horizon: SimTime) -> SimResults {
        self.core.close(horizon);
        self.core.results()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_queue_orders_by_time_then_insertion() {
        let mut q = FleetQueue::with_capacity(8);
        q.schedule(SimTime::from_secs(2.0), 0, Event::Arrival);
        q.schedule(SimTime::from_secs(1.0), 1, Event::Arrival);
        q.schedule(SimTime::from_secs(1.0), 2, Event::Arrival);
        let (t1, f1, _) = q.pop().unwrap();
        let (t2, f2, _) = q.pop().unwrap();
        let (t3, f3, _) = q.pop().unwrap();
        assert_eq!((t1.as_secs(), f1), (1.0, 1));
        assert_eq!((t2.as_secs(), f2), (1.0, 2)); // insertion order on tie
        assert_eq!((t3.as_secs(), f3), (2.0, 0));
        assert!(q.pop().is_none());
    }

    #[test]
    fn gate_defaults() {
        let g = FleetGate::unbounded();
        assert_eq!(g.cap, usize::MAX);
        let g = FleetGate::capped(5);
        assert_eq!(g.cap, 5);
        assert_eq!(g.live, 0);
    }

    #[test]
    fn gate_hooks_charge_and_release() {
        use crate::fleet::policy::FixedExpiration;
        let mut gate = FleetGate::capped(2);
        let mut policy: Box<dyn KeepAlivePolicy> = Box::new(FixedExpiration::new(600.0));
        let mut cap = FleetCapacity::Gate(&mut gate);
        let mut hooks =
            FleetHooks { policy: policy.as_mut(), cap: &mut cap, func: 0, memory_mb: 128.0 };
        assert!(hooks.admit_cold());
        hooks.on_cold_start();
        hooks.on_cold_start();
        assert!(!hooks.admit_cold());
        hooks.on_gate_only_rejection();
        hooks.on_expire();
        assert!(hooks.admit_cold());
        assert_eq!(gate.live, 1);
        assert_eq!(gate.cap_rejections, 1);
    }

    #[test]
    fn cluster_hooks_place_and_release_host_memory() {
        use crate::cluster::ClusterConfig;
        use crate::fleet::policy::FixedExpiration;
        let cfg = ClusterConfig::new(1, 256.0, 32.0);
        let mut cluster = ClusterState::new(&cfg, 1);
        let mut policy: Box<dyn KeepAlivePolicy> = Box::new(FixedExpiration::new(600.0));
        let mut cap = FleetCapacity::Cluster(&mut cluster);
        let mut hooks =
            FleetHooks { policy: policy.as_mut(), cap: &mut cap, func: 0, memory_mb: 128.0 };
        assert!(hooks.admit_cold());
        hooks.on_cold_start();
        assert!(hooks.admit_cold());
        hooks.on_cold_start();
        assert!(!hooks.admit_cold(), "host memory exhausted");
        hooks.on_gate_only_rejection();
        hooks.on_expire();
        assert!(hooks.admit_cold());
        assert_eq!(cluster.gate_rejections(), 1);
        assert_eq!(cluster.placement_failures(), 1);
    }
}
