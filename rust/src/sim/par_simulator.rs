//! `ParServerlessSimulator` — the paper's extensibility demonstration
//! (§3.1): serverless platforms whose instances admit **queuing / a
//! concurrency value > 1** (Google Cloud Run, Knative; paper Fig. 1) while
//! keeping the scale-per-request expiration behaviour.
//!
//! Each instance can hold up to `concurrency_value` requests at once. An
//! arrival is routed to the *newest* instance with spare capacity
//! (consistent with the paper's newest-first routing priority); if none has
//! capacity and the platform is below the maximum concurrency level, a new
//! instance cold-starts. Requests in excess of an instance's processor share
//! its capacity: with k requests in service the per-request rate is
//! unaffected up to `concurrency_value` (Cloud Run semantics — concurrent
//! slots, not processor sharing), which reduces to scale-per-request when
//! `concurrency_value == 1`.

use super::event::{Event, EventQueue};
use super::hist::CountDistribution;
use super::instance::InstanceId;
use super::metrics::{OnlineStats, TimeWeighted};
use super::results::SimResults;
use super::rng::Rng;
use super::simulator::SimConfig;
use super::time::SimTime;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ParState {
    Busy,
    Idle,
    Terminated,
}

#[derive(Debug, Clone)]
struct ParInstance {
    state: ParState,
    in_flight: u32,
    generation: u64,
    created_at: SimTime,
    busy_accum: f64,
    /// Start of the current "has in-flight work" period.
    busy_since: SimTime,
    terminated_at: SimTime,
}

/// Scale-per-request simulator generalized with a per-instance concurrency
/// value (paper Fig. 1: one instance absorbs `c` concurrent requests).
pub struct ParServerlessSimulator {
    cfg: SimConfig,
    pub concurrency_value: u32,
    rng: Rng,
    events: EventQueue,
    now: SimTime,
    instances: Vec<ParInstance>,
    /// Instances with spare slots, keyed by id (newest = max).
    available: BTreeMap<InstanceId, u32>,
    live_count: usize,
    /// Total in-flight requests.
    in_flight: u64,

    stats_started: bool,
    stats_start: SimTime,
    total_requests: u64,
    cold_requests: u64,
    warm_requests: u64,
    rejected_requests: u64,
    instances_created: u64,
    instances_expired: u64,
    server_tw: TimeWeighted,
    running_tw: TimeWeighted,
    busy_inst_tw: TimeWeighted,
    count_dist: CountDistribution,
    lifespan_stats: OnlineStats,
    response_stats: OnlineStats,
    warm_response_stats: OnlineStats,
    cold_response_stats: OnlineStats,
    billed_seconds: f64,
}

impl ParServerlessSimulator {
    pub fn new(cfg: SimConfig, concurrency_value: u32) -> Self {
        assert!(concurrency_value >= 1);
        let rng = Rng::new(cfg.seed);
        let start = SimTime::ZERO;
        ParServerlessSimulator {
            concurrency_value,
            rng,
            events: EventQueue::with_capacity(1024),
            now: start,
            instances: Vec::new(),
            available: BTreeMap::new(),
            live_count: 0,
            in_flight: 0,
            stats_started: cfg.skip_initial <= 0.0,
            stats_start: SimTime::from_secs(cfg.skip_initial.max(0.0)),
            total_requests: 0,
            cold_requests: 0,
            warm_requests: 0,
            rejected_requests: 0,
            instances_created: 0,
            instances_expired: 0,
            server_tw: TimeWeighted::new(start, 0.0),
            running_tw: TimeWeighted::new(start, 0.0),
            busy_inst_tw: TimeWeighted::new(start, 0.0),
            count_dist: CountDistribution::new(start, 0),
            lifespan_stats: OnlineStats::new(),
            response_stats: OnlineStats::new(),
            warm_response_stats: OnlineStats::new(),
            cold_response_stats: OnlineStats::new(),
            billed_seconds: 0.0,
            cfg,
        }
    }

    fn sync(&mut self) {
        self.server_tw.update(self.now, self.live_count as f64);
        self.running_tw.update(self.now, self.in_flight as f64);
        let busy_instances = self
            .instances
            .iter()
            .filter(|i| i.state == ParState::Busy)
            .count() as f64;
        self.busy_inst_tw.update(self.now, busy_instances);
        self.count_dist.update(self.now, self.live_count);
    }

    fn maybe_start_stats(&mut self, t: SimTime) {
        if self.stats_started || t < self.stats_start {
            return;
        }
        let b = self.stats_start;
        self.server_tw.advance(b);
        self.running_tw.advance(b);
        self.busy_inst_tw.advance(b);
        self.count_dist.finish(b);
        self.server_tw.reset_at(b);
        self.running_tw.reset_at(b);
        self.busy_inst_tw.reset_at(b);
        self.count_dist.reset_at(b);
        self.stats_started = true;
    }

    fn handle_arrival(&mut self) {
        if self.stats_started {
            self.total_requests += 1;
        }
        // Newest instance with spare capacity.
        let target = self.available.iter().next_back().map(|(&id, &slots)| (id, slots));
        if let Some((id, slots)) = target {
            let inst = &mut self.instances[id.0 as usize];
            if inst.state == ParState::Idle {
                inst.state = ParState::Busy;
                inst.busy_since = self.now;
                inst.generation += 1; // cancel pending expiration
            }
            inst.in_flight += 1;
            self.in_flight += 1;
            if slots <= 1 {
                self.available.remove(&id);
            } else {
                self.available.insert(id, slots - 1);
            }
            let service = self.cfg.warm_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.warm_requests += 1;
                self.response_stats.push(service);
                self.warm_response_stats.push(service);
            }
        } else if self.live_count < self.cfg.max_concurrency {
            let id = InstanceId(self.instances.len() as u64);
            self.instances.push(ParInstance {
                state: ParState::Busy,
                in_flight: 1,
                generation: 0,
                created_at: self.now,
                busy_accum: 0.0,
                busy_since: self.now,
                terminated_at: self.now,
            });
            self.live_count += 1;
            self.in_flight += 1;
            if self.concurrency_value > 1 {
                self.available.insert(id, self.concurrency_value - 1);
            }
            let service = self.cfg.cold_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.cold_requests += 1;
                self.instances_created += 1;
                self.response_stats.push(service);
                self.cold_response_stats.push(service);
            }
        } else if self.stats_started {
            self.rejected_requests += 1;
        }
        self.sync();
        let gap = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(self.now.after(gap), Event::Arrival);
    }

    fn handle_departure(&mut self, id: InstanceId) {
        let schedule_expiration;
        let gen;
        {
            let inst = &mut self.instances[id.0 as usize];
            debug_assert!(inst.in_flight > 0);
            inst.in_flight -= 1;
            self.in_flight -= 1;
            if inst.in_flight == 0 {
                // Busy period ends; bill it once (slots share the instance).
                let busy = self.now.since(inst.busy_since).max(0.0);
                inst.busy_accum += busy;
                if self.stats_started {
                    self.billed_seconds += busy;
                }
                inst.state = ParState::Idle;
                inst.generation += 1;
                schedule_expiration = true;
                gen = inst.generation;
            } else {
                schedule_expiration = false;
                gen = inst.generation;
            }
        }
        // Free one slot.
        let slots = self.available.get(&id).copied().unwrap_or(0) + 1;
        self.available.insert(id, slots.min(self.concurrency_value));
        if schedule_expiration {
            let threshold = self.cfg.expiration_threshold;
            self.events.schedule(self.now.after(threshold), Event::Expiration { id, gen });
        }
        self.sync();
    }

    fn handle_expiration(&mut self, id: InstanceId, gen: u64) {
        let inst = &mut self.instances[id.0 as usize];
        if inst.generation != gen || inst.state != ParState::Idle {
            return;
        }
        inst.state = ParState::Terminated;
        inst.terminated_at = self.now;
        let lifespan = self.now.since(inst.created_at);
        self.available.remove(&id);
        self.live_count -= 1;
        if self.stats_started {
            self.instances_expired += 1;
            self.lifespan_stats.push(lifespan);
        }
        self.sync();
    }

    pub fn run(&mut self) -> SimResults {
        let horizon = SimTime::from_secs(self.cfg.horizon);
        let first = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(SimTime::from_secs(first), Event::Arrival);
        self.events.schedule(horizon, Event::Horizon);
        while let Some((t, ev)) = self.events.pop() {
            self.maybe_start_stats(t);
            self.now = t;
            match ev {
                Event::Arrival => self.handle_arrival(),
                Event::Departure(id) => self.handle_departure(id),
                Event::Expiration { id, gen } => self.handle_expiration(id, gen),
                Event::ProvisioningDone(_) => unreachable!(),
                Event::Horizon => break,
            }
        }
        self.now = horizon;
        self.server_tw.advance(horizon);
        self.running_tw.advance(horizon);
        self.busy_inst_tw.advance(horizon);
        self.count_dist.finish(horizon);

        let measured = horizon.since(self.stats_start).max(0.0);
        let served = self.cold_requests + self.warm_requests;
        let avg_server = self.server_tw.average();
        let avg_busy_inst = self.busy_inst_tw.average();
        SimResults {
            measured_time: measured,
            total_requests: self.total_requests,
            cold_requests: self.cold_requests,
            warm_requests: self.warm_requests,
            rejected_requests: self.rejected_requests,
            cold_start_prob: if served > 0 {
                self.cold_requests as f64 / served as f64
            } else {
                0.0
            },
            rejection_prob: if self.total_requests > 0 {
                self.rejected_requests as f64 / self.total_requests as f64
            } else {
                0.0
            },
            avg_lifespan: self.lifespan_stats.mean(),
            instances_created: self.instances_created,
            instances_expired: self.instances_expired,
            avg_server_count: avg_server,
            avg_running_count: self.running_tw.average(),
            avg_idle_count: avg_server - avg_busy_inst,
            max_server_count: self.server_tw.max_level(),
            wasted_capacity: if avg_server > 0.0 {
                (avg_server - avg_busy_inst) / avg_server
            } else {
                0.0
            },
            avg_response_time: self.response_stats.mean(),
            avg_warm_response_time: self.warm_response_stats.mean(),
            avg_cold_response_time: self.cold_response_stats.mean(),
            response_p50: f64::NAN,
            response_p95: f64::NAN,
            response_p99: f64::NAN,
            billed_instance_seconds: self.billed_seconds,
            observed_arrival_rate: if measured > 0.0 {
                self.total_requests as f64 / measured
            } else {
                0.0
            },
            instance_count_pmf: self.count_dist.pmf(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::ExpProcess;
    use crate::sim::simulator::ServerlessSimulator;
    use std::sync::Arc;

    fn cfg(rate: f64, horizon: f64, seed: u64) -> SimConfig {
        SimConfig {
            arrival: Arc::new(ExpProcess::with_rate(rate)),
            batch_size: None,
            warm_service: Arc::new(ExpProcess::with_mean(1.991)),
            cold_service: Arc::new(ExpProcess::with_mean(2.244)),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 100.0,
            seed,
            capture_request_log: false,
            sample_interval: 0.0,
        }
    }

    #[test]
    fn concurrency_one_matches_scale_per_request() {
        // With c=1 the generalized simulator must agree (statistically)
        // with ServerlessSimulator on the same workload.
        let r1 = ParServerlessSimulator::new(cfg(0.9, 100_000.0, 1), 1).run();
        let r2 = ServerlessSimulator::new(cfg(0.9, 100_000.0, 1)).run();
        assert!((r1.avg_server_count - r2.avg_server_count).abs() / r2.avg_server_count < 0.05);
        assert!((r1.avg_running_count - r2.avg_running_count).abs() / r2.avg_running_count < 0.05);
        // Cold start probabilities are both sub-1%.
        assert!(r1.cold_start_prob < 0.01 && r2.cold_start_prob < 0.01);
    }

    #[test]
    fn higher_concurrency_needs_fewer_instances() {
        // Paper Fig. 1: c=3 absorbs the same traffic with fewer instances.
        let r1 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 1).run();
        let r3 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 3).run();
        assert!(
            r3.avg_server_count < r1.avg_server_count,
            "c=3 {} vs c=1 {}",
            r3.avg_server_count,
            r1.avg_server_count
        );
        assert!(r3.cold_start_prob <= r1.cold_start_prob + 0.01);
    }

    #[test]
    fn in_flight_never_exceeds_capacity() {
        let mut sim = ParServerlessSimulator::new(cfg(5.0, 5_000.0, 3), 4);
        let _ = sim.run();
        for inst in &sim.instances {
            assert!(inst.in_flight <= 4);
        }
    }

    #[test]
    fn rejection_when_capacity_exhausted() {
        let mut c = cfg(50.0, 5_000.0, 4);
        c.max_concurrency = 3;
        let r = ParServerlessSimulator::new(c, 2).run();
        // Offered load 50*2 ~ 100 >> 6 slots.
        assert!(r.rejection_prob > 0.5);
    }
}
