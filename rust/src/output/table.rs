//! ASCII table rendering for CLI reports and bench output.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Convenience: numeric row formatted with `prec` decimals.
    pub fn row_f64(&mut self, cells: &[f64], prec: usize) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|v| format!("{v:.prec$}")).collect();
        self.row(cells)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for i in 0..ncols {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["rate", "p_cold"]);
        t.row_f64(&[0.9, 0.0014], 4);
        t.row_f64(&[10.0, 0.0001], 4);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("rate"));
        assert!(lines[2].contains("0.9000"));
        // Columns aligned: the two data rows have equal prefix width.
        let c1 = lines[2].find("0.0014").unwrap();
        let c2 = lines[3].find("0.0001").unwrap();
        assert_eq!(c1, c2);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
