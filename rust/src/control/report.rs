//! Per-tick control records and the end-of-run control report.

use std::collections::BTreeMap;

use super::spec::{ControllerKind, ControllerSpec};

/// One control-tick record: what the controller saw and what it did.
/// Flows out through the telemetry seam into `<stem>.control.csv` and the
/// report's §Control section.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControlSample {
    /// Capacity domain that took this tick.
    pub domain: u32,
    /// Simulated time of the tick.
    pub t: f64,
    /// Observed utilization signal (gate: live/cap; cluster: memory
    /// used/capacity over non-retired hosts).
    pub observed: f64,
    /// `observed - setpoint`.
    pub error: f64,
    /// Applied capacity delta after bound clamping (0 = held).
    pub actuation: i64,
    /// Effective capacity after actuation (domain-local units).
    pub capacity: u64,
}

/// Width of the settling band around the setpoint (for `target`/`pid`;
/// `step` uses its own `[low, high]` band).
pub const SETTLING_BAND: f64 = 0.1;

/// Signal level treated as "at capacity" when no upper bound is set.
const AT_CAP_SIGNAL: f64 = 0.999;

/// End-of-run summary of a controlled fleet: the raw per-tick samples
/// plus the classic control-theory digest (settling time, overshoot, %
/// time at cap, scale events). Multi-domain runs are aggregated per tick
/// time — capacities sum, observed signals average capacity-weighted.
#[derive(Debug, Clone)]
pub struct ControlReport {
    /// Canonical spec string (`ControllerSpec::as_str`).
    pub spec: String,
    /// The signal value the controller steered toward.
    pub setpoint: f64,
    /// Settling band `[low, high]` used for `settling_time`.
    pub band: (f64, f64),
    /// Number of capacity domains that ran a controller share.
    pub domains: usize,
    /// Distinct control-tick times.
    pub ticks: usize,
    /// Per-domain scale-out actuations (positive deltas).
    pub scale_up_events: u64,
    /// Per-domain scale-in actuations (negative deltas).
    pub scale_down_events: u64,
    /// Smallest fleet-wide capacity reached after any tick.
    pub min_capacity: u64,
    /// Largest fleet-wide capacity reached after any tick.
    pub max_capacity: u64,
    /// Fleet-wide capacity after the final tick.
    pub final_capacity: u64,
    /// Fraction of ticks pinned at the configured max capacity or with
    /// the observed signal saturated (>= 0.999).
    pub pct_ticks_at_cap: f64,
    /// Max positive excursion of the observed signal above the setpoint.
    pub overshoot: f64,
    /// Simulated time after which the observed signal stayed inside the
    /// settling band until the end of the run; `None` if it never did.
    pub settling_time: Option<f64>,
    /// All per-domain tick records, in (domain, tick) order.
    pub samples: Vec<ControlSample>,
}

impl ControlReport {
    /// Digest `samples` (per-domain tick records, domains concatenated in
    /// domain order) for the controller described by `spec`.
    pub fn from_samples(samples: Vec<ControlSample>, spec: &ControllerSpec) -> ControlReport {
        let setpoint = spec.kind.setpoint();
        let band = match spec.kind {
            ControllerKind::Step { low, high, .. } => (low, high),
            _ => (setpoint - SETTLING_BAND, setpoint + SETTLING_BAND),
        };
        let domains = samples.iter().map(|s| s.domain as usize + 1).max().unwrap_or(0);
        let scale_up_events = samples.iter().filter(|s| s.actuation > 0).count() as u64;
        let scale_down_events = samples.iter().filter(|s| s.actuation < 0).count() as u64;

        // Aggregate domains per tick time: capacities sum, observed
        // signals average capacity-weighted. Tick times are positive, so
        // ordering by bits is ordering by value.
        let mut per_tick: BTreeMap<u64, (f64, f64, f64, u64)> = BTreeMap::new();
        for s in &samples {
            let e = per_tick.entry(s.t.to_bits()).or_insert((0.0, 0.0, 0.0, 0));
            e.0 += s.observed * s.capacity as f64;
            e.1 += s.capacity as f64;
            e.2 += s.observed;
            e.3 += s.capacity;
        }
        let agg: Vec<(f64, f64, u64)> = per_tick
            .iter()
            .map(|(&bits, &(wsum, w, osum, cap))| {
                let t = f64::from_bits(bits);
                let n = samples.iter().filter(|s| s.t.to_bits() == bits).count().max(1);
                // Capacity-weighted mean; plain mean when every domain
                // scaled to zero capacity.
                let observed = if w > 0.0 { wsum / w } else { osum / n as f64 };
                (t, observed, cap)
            })
            .collect();

        let ticks = agg.len();
        let min_capacity = agg.iter().map(|&(_, _, c)| c).min().unwrap_or(0);
        let max_capacity = agg.iter().map(|&(_, _, c)| c).max().unwrap_or(0);
        let final_capacity = agg.last().map(|&(_, _, c)| c).unwrap_or(0);
        let at_cap = agg
            .iter()
            .filter(|&&(_, observed, cap)| {
                (spec.max_capacity != 0 && cap >= spec.max_capacity) || observed >= AT_CAP_SIGNAL
            })
            .count();
        let pct_ticks_at_cap = if ticks > 0 { at_cap as f64 / ticks as f64 } else { 0.0 };
        let overshoot =
            agg.iter().map(|&(_, observed, _)| observed - setpoint).fold(0.0, f64::max);
        // Settling time: the start of the longest suffix of ticks whose
        // observed signal stays inside the band through the end of the run.
        let mut settling_time = None;
        for &(t, observed, _) in agg.iter().rev() {
            if observed >= band.0 && observed <= band.1 {
                settling_time = Some(t);
            } else {
                break;
            }
        }

        ControlReport {
            spec: spec.as_str(),
            setpoint,
            band,
            domains,
            ticks,
            scale_up_events,
            scale_down_events,
            min_capacity,
            max_capacity,
            final_capacity,
            pct_ticks_at_cap,
            overshoot,
            settling_time,
            samples,
        }
    }

    /// Human-readable report lines for the §Control section.
    pub fn to_lines(&self) -> Vec<String> {
        let mut lines = vec![
            format!("Controller {} (setpoint {:.3}, {} domain(s))", self.spec, self.setpoint, self.domains),
            format!(
                "  ticks {} | scale events +{} / -{} | capacity min {} max {} final {}",
                self.ticks,
                self.scale_up_events,
                self.scale_down_events,
                self.min_capacity,
                self.max_capacity,
                self.final_capacity
            ),
            format!(
                "  at cap {:.1}% of ticks | overshoot {:.3} | settling {}",
                self.pct_ticks_at_cap * 100.0,
                self.overshoot,
                match self.settling_time {
                    Some(t) => format!("{t:.0} s"),
                    None => "never".to_string(),
                }
            ),
        ];
        if self.ticks == 0 {
            lines.push("  (no control ticks fired within the horizon)".to_string());
        }
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(domain: u32, t: f64, observed: f64, actuation: i64, capacity: u64) -> ControlSample {
        ControlSample { domain, t, observed, error: observed - 0.7, actuation, capacity }
    }

    #[test]
    fn aggregates_domains_per_tick() {
        let spec = ControllerSpec::parse("target:0.7;max=20").unwrap();
        let samples = vec![
            // domain 0: two ticks
            sample(0, 10.0, 1.0, 2, 6),
            sample(0, 20.0, 0.7, 0, 6),
            // domain 1: same tick times
            sample(1, 10.0, 0.5, -1, 2),
            sample(1, 20.0, 0.7, 0, 2),
        ];
        let r = ControlReport::from_samples(samples, &spec);
        assert_eq!(r.domains, 2);
        assert_eq!(r.ticks, 2);
        assert_eq!(r.scale_up_events, 1);
        assert_eq!(r.scale_down_events, 1);
        assert_eq!((r.min_capacity, r.max_capacity, r.final_capacity), (8, 8, 8));
        // Tick 1 weighted observed: (1.0*6 + 0.5*2) / 8 = 0.875.
        assert!((r.overshoot - 0.175).abs() < 1e-12);
        // Tick 2 is in band, tick 1 is not: settles at t = 20.
        assert_eq!(r.settling_time, Some(20.0));
    }

    #[test]
    fn at_cap_and_never_settling() {
        let spec = ControllerSpec::parse("target:0.7;max=4").unwrap();
        let samples = vec![
            sample(0, 10.0, 1.0, 1, 4), // pinned at max
            sample(0, 20.0, 1.2, 0, 4), // saturated signal
            sample(0, 30.0, 0.2, -1, 3), // below band at the end
        ];
        let r = ControlReport::from_samples(samples, &spec);
        assert!((r.pct_ticks_at_cap - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(r.settling_time, None);
        assert!((r.overshoot - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_samples_are_harmless() {
        let spec = ControllerSpec::parse("step:0.3,0.8").unwrap();
        let r = ControlReport::from_samples(Vec::new(), &spec);
        assert_eq!(r.ticks, 0);
        assert_eq!(r.settling_time, None);
        assert_eq!(r.band, (0.3, 0.8));
        assert!(!r.to_lines().is_empty());
    }
}
