//! END-TO-END driver (the DESIGN.md §5 validation experiment, Figs. 6–8,
//! with all three layers composing):
//!
//!   1. The **platform emulator** (L3, threads + virtual clock) serves a
//!      Poisson workload; each request's function body executes the
//!      **AOT-compiled JAX/Pallas MLP payload** (L2/L1) through the PJRT
//!      runtime — Python never runs here.
//!   2. The emulator's trace is written as CSV, re-parsed, and fed through
//!      **parameter identification** (paper §5.2).
//!   3. The **discrete-event simulator** is configured with the identified
//!      parameters and predicts the platform's behaviour.
//!   4. Predictions are compared against the emulator's measurements with
//!      the paper's error metrics (Fig 6: P(cold); Fig 7: instance count;
//!      Fig 8: wasted capacity), and the PDF/CDF analysis of the measured
//!      response times runs on the **PJRT histogram kernel**, cross-checked
//!      against the pure-Rust histogram.
//!
//! Run with: `cargo run --release --example validate_end_to_end`

use simfaas::emulator::{EmulatorConfig, Platform};
use simfaas::output::Table;
use simfaas::runtime::{ComputePool, Engine, PayloadKind, HIST_NBINS};
use simfaas::sim::{Process, ServerlessSimulator, SimConfig};
use simfaas::trace;
use simfaas::workload;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let artifacts = simfaas::runtime::default_artifacts_dir();
    println!("loading artifacts from {}...", artifacts.display());
    let pool = Arc::new(ComputePool::new(&artifacts, 8)?);
    // Warm the executables (first PJRT execution pays lazy-init costs that
    // would otherwise distort the first cold starts).
    for _ in 0..8 {
        let k = PayloadKind::Small;
        pool.run_payload(k, vec![0.0; k.input_len()])?;
    }
    let engine = Engine::load_dir(&artifacts)?;

    // --- 1. emulate the platform with real compute payloads -------------
    let time_scale = 100.0;
    let horizon = 2_500.0; // virtual seconds
    let rate = 1.0;
    let mut cfg = EmulatorConfig::lambda_like(time_scale);
    cfg.payload = Some(PayloadKind::Small);
    cfg.payload_reps = 1;
    cfg.app_init_reps = 1; // "load the model" on cold start
    cfg.provisioning_delay = 0.25;
    cfg.expiration_threshold = 600.0;
    cfg.synthetic_service = Some(Arc::new(simfaas::sim::ExpProcess::with_mean(1.8)));
    cfg.tick = 2.0;

    let mut rng = simfaas::sim::Rng::new(99);
    let w = workload::poisson(rate, horizon, &mut rng);
    println!(
        "emulating {} requests over {horizon} virtual s at {time_scale}x (payload: MLP small via PJRT)...",
        w.len()
    );
    let t0 = std::time::Instant::now();
    let res = Platform::new(cfg, Some(pool)).run(&w)?;
    println!("emulation done in {:.1} s wall", t0.elapsed().as_secs_f64());

    // --- 2. trace out/in + parameter identification ----------------------
    let mut buf = Vec::new();
    trace::write_csv(&mut buf, &res.records)?;
    let records = trace::read_csv(&buf[..])?;
    let params = trace::identify(&records);
    println!(
        "\nidentified: rate {:.3}/s, warm {:.3} s (std {:.3}), cold {:.3} s, p_cold {:.3}%",
        params.arrival_rate,
        params.warm_mean,
        params.warm_std,
        params.cold_mean,
        params.cold_start_prob * 100.0
    );

    // --- 3. simulator with identified parameters -------------------------
    let warm: Vec<f64> = records
        .iter()
        .filter(|r| r.outcome == trace::Outcome::Warm)
        .map(|r| r.response_time)
        .collect();
    let cold: Vec<f64> = records
        .iter()
        .filter(|r| r.outcome == trace::Outcome::Cold)
        .map(|r| r.response_time)
        .collect();
    let mut sim_cfg = SimConfig::table1()
        .with_arrival_rate(params.arrival_rate)
        .with_horizon(300_000.0);
    sim_cfg.skip_initial = 300.0;
    sim_cfg.warm_service = Process::empirical(warm);
    sim_cfg.cold_service = if cold.len() >= 10 {
        Process::empirical(cold)
    } else {
        Process::gaussian(params.cold_mean, params.cold_std.max(0.01))
    };
    let sim = ServerlessSimulator::new(sim_cfg).run();

    // --- 4. compare -------------------------------------------------------
    let emu = res.metrics(300.0);
    let mut t = Table::new(vec!["metric", "simulator", "emulator", "|err| %"]);
    let mut add = |name: &str, s: f64, e: f64| {
        let err = if e != 0.0 { 100.0 * ((s - e) / e).abs() } else { 0.0 };
        t.row(vec![
            name.to_string(),
            format!("{s:.4}"),
            format!("{e:.4}"),
            format!("{err:.2}"),
        ]);
    };
    add("P(cold) %", sim.cold_start_prob * 100.0, emu.cold_start_prob * 100.0);
    add("avg server count", sim.avg_server_count, emu.avg_server_count);
    add("avg running", sim.avg_running_count, emu.avg_running_count);
    add("wasted capacity %", sim.wasted_capacity * 100.0, emu.wasted_capacity * 100.0);
    add("avg warm response s", sim.avg_warm_response_time, emu.avg_warm_response);
    println!();
    print!("{t}");
    println!("(paper Fig 6-8 errors: 12.75% / 3.43% / 0.17%)");

    // --- PDF/CDF tooling on the PJRT histogram kernel --------------------
    let resp: Vec<f32> = records
        .iter()
        .filter(|r| r.outcome != trace::Outcome::Rejected)
        .map(|r| r.response_time as f32)
        .collect();
    let hi = 10.0f32;
    let counts = engine.run_histogram(&resp, 0.0, hi)?;
    let mut h = simfaas::sim::Histogram::new(0.0, hi as f64, HIST_NBINS);
    for r in &records {
        if r.outcome != trace::Outcome::Rejected {
            h.push(r.response_time);
        }
    }
    let rust_counts: Vec<f64> = h.counts().iter().map(|&c| c as f64).collect();
    anyhow::ensure!(counts == rust_counts, "PJRT histogram != pure-Rust histogram");
    let total: f64 = counts.iter().sum();
    let p50_bin = {
        let mut acc = 0.0;
        let mut bin = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= total / 2.0 {
                bin = i;
                break;
            }
        }
        bin
    };
    println!(
        "\nresponse-time CDF via PJRT histogram kernel: {} samples, median bin {} (~{:.2} s); pure-Rust cross-check OK",
        total as u64,
        p50_bin,
        (p50_bin as f32 + 0.5) * hi / HIST_NBINS as f32
    );
    println!("\nEND-TO-END OK: L1 Pallas kernels -> L2 JAX graphs -> AOT HLO -> L3 rust emulator+simulator");
    Ok(())
}
