//! Trace substrate: the shared request-trace schema (CSV), bridges from the
//! simulator's and emulator's logs, and the parameter-identification
//! procedures of paper §5.2.

pub mod ident;
pub mod record;

pub use ident::{
    identify, mean_warm_pool, probe_expiration_threshold, warm_pool_series, ColdStartProbe,
    IdentifiedParams,
};
pub use record::{from_sim_log, read_csv, write_csv, Outcome, RequestRecord};
