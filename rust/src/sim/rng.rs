//! Deterministic pseudo-random number generation and samplers.
//!
//! SimFaaS results must be bit-reproducible given a seed, across platforms
//! and library versions, so we implement the generator in-repo instead of
//! depending on an external crate:
//!
//! * [`SplitMix64`] — seed expansion (Steele et al., used to initialize the
//!   main generator from a single `u64`).
//! * [`Rng`] — xoshiro256++ (Blackman & Vigna), a fast, high-quality,
//!   non-cryptographic generator; plus the samplers the simulator needs:
//!   uniform, exponential, normal (Box–Muller), lognormal, gamma
//!   (Marsaglia–Tsang), Weibull, Pareto, Erlang and integer ranges.

/// SplitMix64: used for seeding xoshiro state from a single u64.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG with sampling helpers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-component streams).
    /// Uses the 2^128 jump polynomial so streams are provably disjoint for
    /// any realistic simulation length.
    pub fn split(&mut self) -> Rng {
        let child = self.clone();
        self.jump();
        let mut c = child;
        c.gauss_spare = None;
        c
    }

    /// xoshiro256++ jump: advances this generator by 2^128 steps.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut s0 = 0u64;
        let mut s1 = 0u64;
        let mut s2 = 0u64;
        let mut s3 = 0u64;
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s0 ^= self.s[0];
                    s1 ^= self.s[1];
                    s2 ^= self.s[2];
                    s3 ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = [s0, s1, s2, s3];
        self.gauss_spare = None;
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1). 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as the argument of `ln`.
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Exponential with rate `rate` (mean 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.uniform_pos().ln() / rate
    }

    /// Standard normal via Box–Muller (with caching of the paired variate).
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let u1 = self.uniform_pos();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mean, std).
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.standard_normal()
    }

    /// LogNormal with the given *underlying* normal parameters mu, sigma.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang; handles k < 1 by
    /// boosting (Gamma(k) = Gamma(k+1) * U^{1/k}).
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            let u = self.uniform_pos();
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.standard_normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.uniform_pos();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v * scale;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Erlang(k, rate) = sum of k exponentials — exact, O(1) via Gamma.
    #[inline]
    pub fn erlang(&mut self, k: u32, rate: f64) -> f64 {
        self.gamma(k as f64, 1.0 / rate)
    }

    /// Weibull(shape k, scale lambda).
    #[inline]
    pub fn weibull(&mut self, shape: f64, scale: f64) -> f64 {
        scale * (-self.uniform_pos().ln()).powf(1.0 / shape)
    }

    /// Pareto (Lomax-free, classic): x_m * U^{-1/alpha}, support [x_m, inf).
    #[inline]
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m * self.uniform_pos().powf(-1.0 / alpha)
    }

    /// Poisson(lambda) count via inversion for small lambda, normal
    /// approximation fallback for large lambda (used by batch arrivals).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // PTRS would be exact; the normal approximation is adequate for
            // the batch sizes the simulator uses and keeps the code small.
            let x = self.normal(lambda, lambda.sqrt()).round();
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element index weighted by `weights`.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_and_var(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
        (mean, var)
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.split();
        let mut b = root.split();
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            let v = r.uniform_pos();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..200_000).map(|_| r.exponential(0.5)).collect();
        let (mean, var) = mean_and_var(&xs);
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..200_000).map(|_| r.normal(5.0, 2.0)).collect();
        let (mean, var) = mean_and_var(&xs);
        assert!((mean - 5.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(4);
        // shape 3, scale 2 => mean 6, var 12
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(3.0, 2.0)).collect();
        let (mean, var) = mean_and_var(&xs);
        assert!((mean - 6.0).abs() < 0.1, "mean={mean}");
        assert!((var - 12.0).abs() < 0.7, "var={var}");
        // shape < 1 boosting path
        let xs: Vec<f64> = (0..200_000).map(|_| r.gamma(0.5, 1.0)).collect();
        let (mean, _) = mean_and_var(&xs);
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn weibull_mean() {
        let mut r = Rng::new(5);
        // k=2, lambda=1 => mean = Gamma(1.5) = sqrt(pi)/2 ~= 0.8862
        let xs: Vec<f64> = (0..200_000).map(|_| r.weibull(2.0, 1.0)).collect();
        let (mean, _) = mean_and_var(&xs);
        assert!((mean - 0.8862).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn pareto_support_and_median() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..100_000).map(|_| r.pareto(1.0, 2.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // median = x_m * 2^{1/alpha} = 2^{0.5}
        let med = sorted[sorted.len() / 2];
        assert!((med - 2f64.sqrt()).abs() < 0.02, "median={med}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..100_000).map(|_| r.poisson(3.0) as f64).collect();
        let (mean, var) = mean_and_var(&xs);
        assert!((mean - 3.0).abs() < 0.05);
        assert!((var - 3.0).abs() < 0.15);
        let xs: Vec<f64> = (0..50_000).map(|_| r.poisson(100.0) as f64).collect();
        let (mean, _) = mean_and_var(&xs);
        assert!((mean - 100.0).abs() < 0.5);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(8);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio={ratio}");
    }

    #[test]
    fn erlang_is_sum_of_exponentials() {
        let mut r = Rng::new(10);
        let xs: Vec<f64> = (0..100_000).map(|_| r.erlang(4, 2.0)).collect();
        let (mean, var) = mean_and_var(&xs);
        assert!((mean - 2.0).abs() < 0.03); // k/rate
        assert!((var - 1.0).abs() < 0.05); // k/rate^2
    }
}
