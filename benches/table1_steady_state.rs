//! Bench: regenerate the paper's Table 1 (steady-state example) and report
//! simulator wall time for the 1e6-second horizon.
#[path = "harness.rs"]
mod harness;

use simfaas::figures;

fn main() {
    harness::header(
        "Table 1",
        "steady-state example: lambda=0.9/s, warm 1.991 s, cold 2.244 s, threshold 600 s",
        "P(cold)=0.14%, P(rej)=0%, lifespan 6307.74 s, servers 7.6795, running 1.7902, idle 5.8893",
    );
    let horizon = if harness::quick() { 1e5 } else { 1e6 };
    let (_, r) = harness::bench("table1/simulate_1e6s", 3, || figures::table1(horizon, 0x5EED));
    println!();
    print!("{r}");
    println!("paper: 0.14% | 0% | 6307.7389 s | 7.6795 | 1.7902 | 5.8893");
}
