//! Observability layer: per-request span records, periodic internal-state
//! samples, and timeline exporters (DESIGN.md §Observability).
//!
//! The paper's pitch is that a simulator can expose platform internal
//! states that are "otherwise hard (mostly impossible) to extract from
//! real platforms" — this module turns those states into artifacts.
//! Capture is injected through the unified `sim::core` seam: an
//! [`Observer`] attached to an `EngineCore` receives
//!
//! * one [`SpanRecord`] per dispatch attempt (outcome, verdict, phase
//!   timestamps, instance id, retry attempt number), and
//! * one [`StateSample`] per sampling interval (instance levels, in-flight
//!   requests, cumulative cold-start counters, degradation windows, fleet
//!   cap headroom),
//!
//! so every engine built on the core (steady, par, temporal, fleet) records
//! through the same code. Capture draws **no RNG and schedules no
//! events**: attaching an observer never changes simulation results, and a
//! detached core pays one `Option` branch per dispatch (the zero-overhead
//! contract, pinned with the engine-unification goldens). Fleet recording
//! buffers per function and merges in function order, so recorded bytes
//! are identical at any shard count.
//!
//! Exporters ([`export`]): JSONL span streams (`read_spans_jsonl` is the
//! inverse, closing the loop with `trace::ident` via `simfaas inspect`),
//! CSV time-series, and Chrome trace-event JSON that `ui.perfetto.dev`
//! opens as a per-instance timeline.
#![warn(missing_docs)]

pub mod export;
pub mod recorder;
pub mod span;

pub use export::{
    chrome_trace, read_spans_jsonl, write_control_csv, write_samples_csv, write_spans_jsonl,
    CONTROL_CSV_HEADER, SAMPLES_CSV_HEADER,
};
pub use recorder::{Observer, TelemetryRecorder, TelemetrySink};
pub use span::{SpanOutcome, SpanRecord, SpanVerdict, StateSample};
