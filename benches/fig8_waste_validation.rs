//! Bench: regenerate Fig. 8 — average wasted capacity (idle/total) vs
//! arrival rate, simulation vs emulated platform. Paper MAPE: 0.17%.
#[path = "harness.rs"]
mod harness;

use simfaas::figures::{self, ValidationOpts};

fn main() {
    harness::header(
        "Fig 8",
        "average wasted capacity vs arrival rate: simulator vs emulator",
        "MAPE 0.17%; waste decreases as the arrival rate grows",
    );
    // NOTE: this testbed has a single CPU core; the emulator's threads
    // timeshare it, so validation is restricted to arrival rates whose
    // thread count the core can serve faithfully (see EXPERIMENTS.md).
    let quick = harness::quick();
    let rates: Vec<f64> =
        if quick { vec![0.25, 0.5, 1.0] } else { vec![0.25, 0.5, 0.75, 1.0] };
    let opts = ValidationOpts {
        emu_horizon: if quick { 6_000.0 } else { 30_000.0 },
        time_scale: 500.0,
        sim_horizon: 400_000.0,
        skip: 600.0,
        seed: 0x818,
    };
    let (_, rows) = harness::bench("fig8/validation_sweep", 1, || {
        figures::validation_rows(&rates, &opts)
    });
    println!();
    println!("rate    sim waste%   emu waste%");
    for r in &rows {
        println!(
            "{:<7.2} {:>9.3}   {:>9.3}",
            r.rate,
            r.sim.wasted_capacity * 100.0,
            r.emu.wasted_capacity * 100.0
        );
    }
    let (_, _, e8) = figures::validation_errors(&rows);
    println!("MAPE (waste): {e8:.2}%   (paper: 0.17%)");
    // Shape: waste decreases with rate (pool utilization improves).
    let w: Vec<f64> = rows.iter().map(|r| r.emu.wasted_capacity).collect();
    assert!(w.first().unwrap() > w.last().unwrap(), "waste should fall with rate");
    println!("shape OK: wasted capacity falls as the arrival rate grows");
}
