//! Fault-injection profiles: the "what can go wrong" half of the
//! reliability layer (see DESIGN.md §Reliability).
//!
//! A [`FaultProfile`] is plain data describing how a platform fails:
//! transient invocation failures, provisioning (cold-start) failures, a
//! hard per-request execution timeout with configurable
//! timeout-vs-instance semantics, and scheduled degradation windows during
//! which effective capacity shrinks (the precursor to full host-failure
//! modeling). The profile is interpreted by
//! [`crate::sim::core::EngineCore`], which draws every fault decision from
//! a **dedicated SplitMix64-derived RNG lane** so the arrival and service
//! streams are untouched: a [`FaultProfile::disabled`] run is bit-identical
//! to the pre-fault engines (pinned in `tests/engine_unification.rs`).
//!
//! Retry behaviour lives separately in [`crate::sim::retry`]; the two are
//! combined by the engines (`SimConfig`/`FleetConfig` carry one of each).

use anyhow::{bail, Result};

/// What happens to the serving instance when a request hits the execution
/// timeout.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimeoutAction {
    /// The execution is killed at the deadline but the instance survives
    /// and returns to the warm pool (AWS Lambda semantics: the sandbox
    /// outlives the timed-out invocation).
    #[default]
    KeepInstance,
    /// The instance is torn down with the execution (crash-on-timeout
    /// semantics; frees the concurrency slot immediately). On a
    /// concurrency-valued instance the teardown waits until the last
    /// in-flight request drains.
    KillInstance,
}

/// One scheduled degradation window: between `start` and `end` the
/// engine's effective maximum concurrency is scaled by `capacity_factor`
/// (overlapping windows compose by taking the minimum factor).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradationWindow {
    /// Window start, absolute simulation seconds.
    pub start: f64,
    /// Window end, absolute simulation seconds (must exceed `start`).
    pub end: f64,
    /// Fraction of the concurrency cap still usable while the window is
    /// active, in `[0, 1]` (0 = full outage: every cold start rejected).
    pub capacity_factor: f64,
}

/// Deterministic fault-injection profile for one engine run.
///
/// All fault decisions draw from the engine's dedicated fault RNG lane,
/// and each mechanism draws **only when it can fire** (probability > 0,
/// timeout set, windows present) so enabling one mechanism never perturbs
/// another's stream more than necessary — and a disabled profile draws
/// nothing at all.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultProfile {
    /// Probability that a dispatched request fails transiently at the end
    /// of its busy period (the execution runs — and is billed — but
    /// returns an error).
    pub invocation_failure_prob: f64,
    /// Probability that an admitted cold start fails before the instance
    /// materializes (provisioning failure: no instance, no service draw,
    /// the request errors immediately).
    pub coldstart_failure_prob: f64,
    /// Hard per-request execution timeout in seconds (`None` = no
    /// timeout). A request whose drawn busy period exceeds it is cut off
    /// at the deadline; the truncated busy time is billed and counted as
    /// wasted work.
    pub timeout: Option<f64>,
    /// What the timeout does to the serving instance.
    pub timeout_action: TimeoutAction,
    /// Scheduled capacity-degradation windows.
    pub degradation: Vec<DegradationWindow>,
}

impl FaultProfile {
    /// The no-fault profile: nothing fires, nothing draws — engines run
    /// bit-identical to the pre-fault code.
    pub fn disabled() -> Self {
        FaultProfile {
            invocation_failure_prob: 0.0,
            coldstart_failure_prob: 0.0,
            timeout: None,
            timeout_action: TimeoutAction::KeepInstance,
            degradation: Vec::new(),
        }
    }

    /// True when no fault mechanism can ever fire.
    pub fn is_disabled(&self) -> bool {
        self.invocation_failure_prob <= 0.0
            && self.coldstart_failure_prob <= 0.0
            && self.timeout.is_none()
            && self.degradation.is_empty()
    }

    /// Set the transient invocation-failure probability.
    pub fn with_failure_prob(mut self, p: f64) -> Self {
        self.invocation_failure_prob = p;
        self
    }

    /// Set the provisioning (cold-start) failure probability.
    pub fn with_coldstart_failure_prob(mut self, p: f64) -> Self {
        self.coldstart_failure_prob = p;
        self
    }

    /// Set the per-request execution timeout.
    pub fn with_timeout(mut self, secs: f64) -> Self {
        self.timeout = Some(secs);
        self
    }

    /// Set the timeout-vs-instance semantics.
    pub fn with_timeout_action(mut self, action: TimeoutAction) -> Self {
        self.timeout_action = action;
        self
    }

    /// Append a degradation window.
    pub fn with_degradation(mut self, start: f64, end: f64, capacity_factor: f64) -> Self {
        self.degradation.push(DegradationWindow { start, end, capacity_factor });
        self
    }

    /// Check parameters; scenario files and CLI flags must fail with an
    /// error, not an engine panic. `what` prefixes messages (e.g.
    /// `"reliability"`).
    pub fn validate(&self, what: &str) -> Result<()> {
        for (name, p) in [
            ("failure_prob", self.invocation_failure_prob),
            ("coldstart_failure_prob", self.coldstart_failure_prob),
        ] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                bail!("{what}.{name} must be a probability in [0, 1], got {p}");
            }
        }
        if let Some(t) = self.timeout {
            if !(t.is_finite() && t > 0.0) {
                bail!("{what}.timeout must be a positive number of seconds, got {t}");
            }
        }
        for (i, w) in self.degradation.iter().enumerate() {
            if !(w.start.is_finite() && w.start >= 0.0 && w.end.is_finite() && w.end > w.start) {
                bail!(
                    "{what}.degradation[{i}] needs finite 0 <= start < end, \
                     got [{}, {}]",
                    w.start,
                    w.end
                );
            }
            if !(w.capacity_factor.is_finite() && (0.0..=1.0).contains(&w.capacity_factor)) {
                bail!(
                    "{what}.degradation[{i}].capacity_factor must be in [0, 1], got {}",
                    w.capacity_factor
                );
            }
        }
        Ok(())
    }
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_profile_is_default_and_inert() {
        let p = FaultProfile::default();
        assert!(p.is_disabled());
        assert_eq!(p, FaultProfile::disabled());
        p.validate("reliability").unwrap();
    }

    #[test]
    fn builders_enable_mechanisms() {
        let p = FaultProfile::disabled().with_failure_prob(0.1);
        assert!(!p.is_disabled());
        let p = FaultProfile::disabled().with_timeout(30.0);
        assert!(!p.is_disabled());
        let p = FaultProfile::disabled().with_degradation(10.0, 20.0, 0.5);
        assert!(!p.is_disabled());
        p.validate("x").unwrap();
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        for (p, needle) in [
            (FaultProfile::disabled().with_failure_prob(1.5), "failure_prob"),
            (FaultProfile::disabled().with_failure_prob(-0.1), "failure_prob"),
            (
                FaultProfile::disabled().with_coldstart_failure_prob(f64::NAN),
                "coldstart_failure_prob",
            ),
            (FaultProfile::disabled().with_timeout(0.0), "timeout"),
            (FaultProfile::disabled().with_timeout(-5.0), "timeout"),
            (FaultProfile::disabled().with_degradation(20.0, 10.0, 0.5), "degradation[0]"),
            (FaultProfile::disabled().with_degradation(0.0, 10.0, 2.0), "capacity_factor"),
        ] {
            let err = p.validate("reliability").unwrap_err().to_string();
            assert!(err.contains(needle), "{err}");
        }
    }
}
