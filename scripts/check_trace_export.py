#!/usr/bin/env python3
"""Fail CI when a recorded telemetry export is malformed.

Usage: check_trace_export.py PERFETTO.json [--spans TRACE.jsonl]
                             [--metrics METRICS.csv]

PERFETTO.json is the Chrome trace-event document `simfaas --record-trace`
derives next to the span stream ({"displayTimeUnit": ..., "traceEvents":
[...]}). The gate checks that it parses, that it contains at least one
complete ("X") span event and one counter ("C") sample event, and that
timestamps are nondecreasing within every (pid, phase) track — the order
the exporter guarantees by emitting records in per-function event order.

With --spans / --metrics the side files are checked too: every JSONL line
must parse as a span object with the schema's keys, and the CSV must carry
the samples header plus at least one row.
"""

import argparse
import json
import sys

SPAN_KEYS = {
    "attempt",
    "function",
    "instance",
    "outcome",
    "queued_at",
    "response_time",
    "started_at",
    "verdict",
}

METRICS_HEADER = (
    "function,t,live,busy,idle,in_flight,total_requests,"
    "cold_requests,warm_requests,cold_start_rate,degradation_active,cap_headroom"
)


def check_perfetto(path: str) -> list:
    errors = []
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"{path}: no traceEvents array (keys: {sorted(doc)})"]
    counts = {}
    last_ts = {}
    for i, e in enumerate(events):
        ph = e.get("ph")
        counts[ph] = counts.get(ph, 0) + 1
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{path}: event {i} ({ph}) has no numeric ts")
            continue
        key = (e.get("pid"), ph)
        if key in last_ts and ts < last_ts[key]:
            errors.append(
                f"{path}: event {i} ts {ts} goes backwards on pid={key[0]} "
                f"ph={ph} (prev {last_ts[key]})"
            )
        last_ts[key] = ts
    if counts.get("X", 0) == 0:
        errors.append(f"{path}: no complete ('X') span events")
    if counts.get("C", 0) == 0:
        errors.append(f"{path}: no counter ('C') sample events")
    summary = ", ".join(f"{ph}={n}" for ph, n in sorted(counts.items()))
    print(f"{path}: {len(events)} events ({summary})")
    return errors


def check_spans(path: str) -> list:
    errors = []
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            if not line.strip():
                continue
            try:
                span = json.loads(line)
            except json.JSONDecodeError as e:
                errors.append(f"{path}:{lineno}: bad JSON ({e})")
                continue
            missing = SPAN_KEYS - set(span)
            if missing:
                errors.append(f"{path}:{lineno}: missing keys {sorted(missing)}")
            n += 1
    if n == 0:
        errors.append(f"{path}: no span records")
    print(f"{path}: {n} spans")
    return errors


def check_metrics(path: str) -> list:
    errors = []
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines or lines[0] != METRICS_HEADER:
        errors.append(f"{path}: bad or missing header")
    rows = [l for l in lines[1:] if l.strip()]
    if not rows:
        errors.append(f"{path}: no sample rows")
    print(f"{path}: {len(rows)} sample rows")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("perfetto")
    ap.add_argument("--spans", help="span JSONL stream to validate too")
    ap.add_argument("--metrics", help="time-series CSV to validate too")
    args = ap.parse_args()

    errors = check_perfetto(args.perfetto)
    if args.spans:
        errors += check_spans(args.spans)
    if args.metrics:
        errors += check_metrics(args.metrics)

    if errors:
        print("\ntrace export gate FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("\ntrace export gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
