//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 maps each to its experiment). The CLI (`simfaas figures`),
//! the examples and the benches all call these, so the numbers in
//! EXPERIMENTS.md come from exactly this code.

use crate::emulator::{EmulatorConfig, EmuMetrics, Platform};
use crate::sim::process::ExpProcess;
use crate::sim::{
    InitialState, Process, ServerlessSimulator, ServerlessTemporalSimulator, SimConfig,
    SimResults,
};
use crate::whatif::sweep::sweep;
use crate::workload;
use std::sync::Arc;

/// Table 1: the paper's steady-state example.
pub fn table1(horizon: f64, seed: u64) -> SimResults {
    let cfg = SimConfig::table1().with_horizon(horizon).with_seed(seed);
    ServerlessSimulator::new(cfg).run()
}

/// Fig. 3: instance-count distribution (portion of time at each count)
/// under the Table 1 workload.
pub fn fig3_distribution(horizon: f64, seed: u64) -> Vec<f64> {
    table1(horizon, seed).instance_count_pmf
}

/// Fig. 4: mean instance count over time across replications, with 95% CI.
/// Returns (t, mean, ci_half_width) samples.
pub fn fig4_band(
    horizon: f64,
    sample_interval: f64,
    replications: usize,
    seed: u64,
) -> Vec<(f64, f64, f64)> {
    let mut cfg = SimConfig::table1().with_horizon(horizon).with_seed(seed);
    cfg.sample_interval = sample_interval;
    let sim = ServerlessTemporalSimulator::new(cfg, InitialState::empty(), replications);
    sim.run().average_count_band()
}

/// Fig. 5: cold-start probability vs arrival rate for several expiration
/// thresholds, over the paper's Table 1 platform. Returns one series per
/// threshold: (threshold, [(rate, p)]).
pub fn fig5_sweep(
    rates: &[f64],
    thresholds: &[f64],
    horizon: f64,
    seed: u64,
) -> Vec<(f64, Vec<(f64, f64)>)> {
    fig5_sweep_from(&SimConfig::table1(), rates, thresholds, horizon, seed)
}

/// [`fig5_sweep`] over an arbitrary base platform (service processes,
/// concurrency limit, warm-up skip come from `base`; arrival rate,
/// threshold, horizon and seed are overridden per grid point). The
/// scenario layer routes sweep experiments here so a non-Table-1 platform
/// can be swept; with `base == SimConfig::table1()` the output is
/// bit-identical to [`fig5_sweep`].
pub fn fig5_sweep_from(
    base: &SimConfig,
    rates: &[f64],
    thresholds: &[f64],
    horizon: f64,
    seed: u64,
) -> Vec<(f64, Vec<(f64, f64)>)> {
    let points: Vec<(f64, f64)> = thresholds
        .iter()
        .flat_map(|&th| rates.iter().map(move |&r| (r, th)))
        .collect();
    let results = sweep(&points, |&(rate, th)| {
        // replica_with_seed (not clone) so stateful processes in `base`
        // never share mutable state across the parallel grid jobs.
        let cfg = base
            .replica_with_seed(seed ^ ((th as u64) << 20) ^ (rate * 1e4) as u64)
            .with_arrival_rate(rate)
            .with_expiration_threshold(th)
            .with_horizon(horizon);
        ServerlessSimulator::new(cfg).run().cold_start_prob
    });
    thresholds
        .iter()
        .map(|&th| {
            let series = results
                .iter()
                .filter(|((_, t), _)| *t == th)
                .map(|((r, _), p)| (*r, *p))
                .collect();
            (th, series)
        })
        .collect()
}

/// One row of the Figs. 6–8 validation: simulator predictions vs emulator
/// ("experiment") measurements at a given arrival rate.
#[derive(Debug, Clone, Copy)]
pub struct ValidationRow {
    pub rate: f64,
    pub sim: ValidationMetrics,
    pub emu: ValidationMetrics,
}

#[derive(Debug, Clone, Copy)]
pub struct ValidationMetrics {
    pub cold_start_prob: f64,
    pub avg_server_count: f64,
    pub wasted_capacity: f64,
}

impl From<&SimResults> for ValidationMetrics {
    fn from(r: &SimResults) -> Self {
        ValidationMetrics {
            cold_start_prob: r.cold_start_prob,
            avg_server_count: r.avg_server_count,
            wasted_capacity: r.wasted_capacity,
        }
    }
}

impl From<&EmuMetrics> for ValidationMetrics {
    fn from(m: &EmuMetrics) -> Self {
        ValidationMetrics {
            cold_start_prob: m.cold_start_prob,
            avg_server_count: m.avg_server_count,
            wasted_capacity: m.wasted_capacity,
        }
    }
}

/// Validation experiment options.
#[derive(Debug, Clone, Copy)]
pub struct ValidationOpts {
    /// Virtual horizon per emulator run (the paper used 28-h windows; the
    /// emulator compresses via `time_scale`).
    pub emu_horizon: f64,
    /// Virtual-clock speedup.
    pub time_scale: f64,
    /// Simulator horizon (cheap; run long for tight predictions).
    pub sim_horizon: f64,
    /// Warm-up skip for both sides.
    pub skip: f64,
    pub seed: u64,
}

impl Default for ValidationOpts {
    fn default() -> Self {
        ValidationOpts {
            emu_horizon: 40_000.0,
            // 1000x keeps wall-clock sleep jitter (~0.1 ms) under 0.1
            // virtual seconds — small relative to ~2 s service times.
            time_scale: 1_000.0,
            sim_horizon: 400_000.0,
            skip: 600.0,
            seed: 0xF16,
        }
    }
}

/// The paper's warm/cold service means (measured from its Lambda workload).
pub const WARM_MEAN: f64 = 1.991;
pub const COLD_MEAN: f64 = 2.244;

/// Emulator configuration matching the paper's measured workload: exp warm
/// service with mean 1.991 s; provisioning pads cold responses to mean
/// 2.244 s.
pub fn paper_emulator_cfg(opts: &ValidationOpts) -> EmulatorConfig {
    let mut cfg = EmulatorConfig::lambda_like(opts.time_scale);
    cfg.synthetic_service = Some(Arc::new(ExpProcess::with_mean(WARM_MEAN)));
    cfg.provisioning_delay = COLD_MEAN - WARM_MEAN;
    cfg.expiration_threshold = 600.0;
    cfg.tick = 2.0;
    cfg.seed = opts.seed;
    cfg
}

/// Simulator configuration mirroring [`paper_emulator_cfg`].
pub fn paper_sim_cfg(rate: f64, opts: &ValidationOpts) -> SimConfig {
    let mut cfg = SimConfig::table1()
        .with_arrival_rate(rate)
        .with_horizon(opts.sim_horizon)
        .with_seed(opts.seed ^ 0x51AB ^ (rate * 1e4) as u64);
    cfg.skip_initial = opts.skip;
    cfg
}

/// Run the Figs. 6–8 validation at each arrival rate, following the paper's
/// §5.2 methodology exactly: run the "experiment" (emulator), **identify**
/// the workload parameters from its measured trace (arrival rate, warm/cold
/// response means), configure the simulator with the identified parameters,
/// and compare predictions against the experiment's measurements. Emulator
/// runs execute sequentially (each is itself heavily threaded); simulator
/// runs are cheap.
pub fn validation_rows(rates: &[f64], opts: &ValidationOpts) -> Vec<ValidationRow> {
    rates
        .iter()
        .map(|&rate| {
            // 1. "Experiment": emulated platform under a Poisson client.
            let emu_cfg = paper_emulator_cfg(opts);
            let mut rng = crate::sim::Rng::new(opts.seed ^ (rate * 1e3) as u64);
            let w = workload::poisson(rate, opts.emu_horizon, &mut rng);
            let res = Platform::new(emu_cfg, None).run(&w).expect("emulation failed");
            let emu = res.metrics(opts.skip);

            // 2. Parameter identification from the measured trace
            //    (paper §5.2). We feed the simulator the *empirical*
            //    warm/cold response-time distributions (bootstrap) rather
            //    than fitted exponentials — the capability the paper
            //    highlights over Markovian models ("the user can pass a
            //    random generator function with a custom distribution").
            let params = crate::trace::identify(&res.records);
            let warm_samples: Vec<f64> = res
                .records
                .iter()
                .filter(|r| r.outcome == crate::trace::Outcome::Warm)
                .map(|r| r.response_time)
                .collect();
            let cold_samples: Vec<f64> = res
                .records
                .iter()
                .filter(|r| r.outcome == crate::trace::Outcome::Cold)
                .map(|r| r.response_time)
                .collect();

            // 3. Simulator configured with the identified parameters.
            let mut cfg = paper_sim_cfg(params.arrival_rate, opts);
            cfg.warm_service = if warm_samples.len() >= 50 {
                Process::empirical(warm_samples)
            } else {
                Process::exp_mean(params.warm_mean)
            };
            cfg.cold_service = if cold_samples.len() >= 20 {
                Process::empirical(cold_samples)
            } else {
                Process::exp_mean(params.cold_mean)
            };
            let sim = ServerlessSimulator::new(cfg).run();

            ValidationRow { rate, sim: (&sim).into(), emu: (&emu).into() }
        })
        .collect()
}

/// Error metrics over validation rows, as the paper reports them:
/// (avg % error on P(cold) — Fig. 6; MAPE on server count — Fig. 7;
/// MAPE on wasted capacity — Fig. 8).
pub fn validation_errors(rows: &[ValidationRow]) -> (f64, f64, f64) {
    let pick =
        |f: fn(&ValidationMetrics) -> f64| -> (Vec<f64>, Vec<f64>) {
            (
                rows.iter().map(|r| f(&r.sim)).collect(),
                rows.iter().map(|r| f(&r.emu)).collect(),
            )
        };
    let (sim_p, emu_p) = pick(|m| m.cold_start_prob);
    let (sim_s, emu_s) = pick(|m| m.avg_server_count);
    let (sim_w, emu_w) = pick(|m| m.wasted_capacity);
    (
        crate::sim::mape(&sim_p, &emu_p),
        crate::sim::mape(&sim_s, &emu_s),
        crate::sim::mape(&sim_w, &emu_w),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_series_shapes() {
        let out = fig5_sweep(&[0.5, 1.0], &[120.0, 600.0], 30_000.0, 1);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1.len(), 2);
        // Longer threshold gives lower cold-start probability at same rate.
        let p_short = out[0].1[0].1;
        let p_long = out[1].1[0].1;
        assert!(p_long < p_short, "short={p_short} long={p_long}");
    }

    #[test]
    fn validation_row_sim_tracks_emulator() {
        let _guard = crate::emulator::emu_test_guard();
        // Single-core testbed: low rate + low time scale keep the
        // emulator's thread population and jitter small (EXPERIMENTS.md).
        let opts = ValidationOpts {
            emu_horizon: 8_000.0,
            time_scale: 500.0,
            sim_horizon: 120_000.0,
            skip: 300.0,
            seed: 3,
        };
        let rows = validation_rows(&[0.5], &opts);
        let r = &rows[0];
        // Server counts within 25% on a short single-core window.
        let err =
            (r.sim.avg_server_count - r.emu.avg_server_count).abs() / r.emu.avg_server_count;
        assert!(err < 0.25, "sim={} emu={}", r.sim.avg_server_count, r.emu.avg_server_count);
        // Wasted capacity within a few points.
        assert!((r.sim.wasted_capacity - r.emu.wasted_capacity).abs() < 0.12);
    }
}
