//! `ServerlessTemporalSimulator` — transient analysis (paper §4.2, Fig. 4).
//!
//! Performs simulations like `ServerlessSimulator` but with a **customized
//! initial state** (a warm pool with given idle ages and in-flight requests
//! with given remaining service) and **time-bounded** result windows, plus
//! multi-run replication with 95% confidence intervals so short-horizon
//! estimates come with error bars (the paper's Fig. 4 runs 10 replications
//! and reports <1% CI deviation).

use super::ensemble::run_indexed;
use super::metrics::confidence_interval_95;
use super::results::SimResults;
use super::simulator::{CountSample, ServerlessSimulator, SimConfig};

/// Initial platform state for a transient simulation.
#[derive(Debug, Clone, Default)]
pub struct InitialState {
    /// Idle instances, each with the time (seconds) it has already spent
    /// idle. An instance idle for `a` expires after `threshold - a` more
    /// seconds unless reused.
    pub idle_ages: Vec<f64>,
    /// Running instances, each with its remaining busy time in seconds.
    pub running_remaining: Vec<f64>,
}

impl InitialState {
    /// Empty platform (no warm pool) — the steady-state simulator's start.
    pub fn empty() -> Self {
        Self::default()
    }

    /// A warm pool of `n` instances that just became idle.
    pub fn warm_pool(n: usize) -> Self {
        InitialState { idle_ages: vec![0.0; n], running_remaining: vec![] }
    }

    pub fn total_instances(&self) -> usize {
        self.idle_ages.len() + self.running_remaining.len()
    }
}

/// Result of one replication set: per-run results plus CI summaries.
#[derive(Debug, Clone)]
pub struct TemporalResults {
    pub runs: Vec<SimResults>,
    /// (mean, 95% half-width) across runs.
    pub cold_start_prob_ci: (f64, f64),
    pub avg_server_count_ci: (f64, f64),
    pub avg_running_count_ci: (f64, f64),
    pub avg_idle_count_ci: (f64, f64),
    /// Sampled cumulative-average instance count per run (Fig. 4 series);
    /// aligned time grids, one inner Vec per run.
    pub sample_series: Vec<Vec<CountSample>>,
}

impl TemporalResults {
    /// Fig. 4: per-grid-point mean and 95% CI half-width of the cumulative
    /// average instance count across runs. Returns (t, mean, half_width).
    pub fn average_count_band(&self) -> Vec<(f64, f64, f64)> {
        if self.sample_series.is_empty() {
            return vec![];
        }
        let min_len = self.sample_series.iter().map(|s| s.len()).min().unwrap_or(0);
        (0..min_len)
            .map(|i| {
                let t = self.sample_series[0][i].t;
                let vals: Vec<f64> =
                    self.sample_series.iter().map(|s| s[i].cumulative_avg).collect();
                let (mean, hw) = confidence_interval_95(&vals);
                (t, mean, hw)
            })
            .collect()
    }
}

/// Transient (time-bounded, custom-initial-state, replicated) simulator.
pub struct ServerlessTemporalSimulator {
    cfg: SimConfig,
    initial: InitialState,
    replications: usize,
}

impl ServerlessTemporalSimulator {
    /// `cfg.skip_initial` is ignored (transient analysis measures from t=0);
    /// `cfg.sample_interval` should be set for Fig.4-style series.
    pub fn new(cfg: SimConfig, initial: InitialState, replications: usize) -> Self {
        assert!(replications >= 1);
        let mut cfg = cfg;
        cfg.skip_initial = 0.0;
        ServerlessTemporalSimulator { cfg, initial, replications }
    }

    /// Run all replications (seeds `seed..seed+replications`) across all
    /// available cores. Results are bit-identical to the sequential run:
    /// see [`run_with_threads`](Self::run_with_threads).
    pub fn run(&self) -> TemporalResults {
        self.run_with_threads(0)
    }

    /// Run the replications on `threads` worker threads (0 = one per core).
    /// Replication `i` always simulates seed `root + i` on a fresh process
    /// replica and aggregation happens in replication order, so the output
    /// is bit-identical for any thread count.
    pub fn run_with_threads(&self, threads: usize) -> TemporalResults {
        let outs = run_indexed(self.replications, threads, |i| {
            let cfg = self.cfg.replica_with_seed(self.cfg.seed.wrapping_add(i as u64));
            let mut sim = ServerlessSimulator::new(cfg);
            sim.set_initial_state(&self.initial.idle_ages, &self.initial.running_remaining);
            let res = sim.run();
            let samples = sim.samples().to_vec();
            (res, samples)
        });
        let mut runs = Vec::with_capacity(outs.len());
        let mut series = Vec::with_capacity(outs.len());
        for (res, samples) in outs {
            runs.push(res);
            series.push(samples);
        }
        let ci = |f: fn(&SimResults) -> f64| {
            let xs: Vec<f64> = runs.iter().map(f).collect();
            if xs.len() >= 2 {
                confidence_interval_95(&xs)
            } else {
                (xs[0], 0.0)
            }
        };
        TemporalResults {
            cold_start_prob_ci: ci(|r| r.cold_start_prob),
            avg_server_count_ci: ci(|r| r.avg_server_count),
            avg_running_count_ci: ci(|r| r.avg_running_count),
            avg_idle_count_ci: ci(|r| r.avg_idle_count),
            sample_series: series,
            runs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::Process;

    fn cfg(horizon: f64) -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(0.9),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 0.0,
            seed: 123,
            capture_request_log: false,
            sample_interval: 50.0,
            fault: crate::sim::fault::FaultProfile::disabled(),
            retry: crate::sim::retry::RetryPolicy::none(),
        }
    }

    #[test]
    fn replications_and_ci() {
        let sim = ServerlessTemporalSimulator::new(cfg(5_000.0), InitialState::empty(), 5);
        let res = sim.run();
        assert_eq!(res.runs.len(), 5);
        let (mean, hw) = res.avg_server_count_ci;
        assert!(mean > 0.0 && hw >= 0.0);
        let band = res.average_count_band();
        assert!(band.len() >= 90, "band={}", band.len());
        // CI shrinks over time: late half-width (relative) below early.
        let early = band[4];
        let late = *band.last().unwrap();
        assert!(late.2 / late.1 <= early.2 / early.1 + 0.05);
    }

    #[test]
    fn warm_pool_start_reduces_early_cold_starts() {
        // With a big warm pool there should be fewer cold starts in a short
        // window than starting empty.
        let empty = ServerlessTemporalSimulator::new(cfg(600.0), InitialState::empty(), 3).run();
        let warm =
            ServerlessTemporalSimulator::new(cfg(600.0), InitialState::warm_pool(10), 3).run();
        assert!(warm.cold_start_prob_ci.0 <= empty.cold_start_prob_ci.0);
        // Warm start run begins with 10 instances.
        assert!(warm.avg_server_count_ci.0 > empty.avg_server_count_ci.0);
    }

    #[test]
    fn parallel_replications_bit_identical_to_sequential() {
        let sim = ServerlessTemporalSimulator::new(cfg(2_000.0), InitialState::warm_pool(3), 6);
        let seq = sim.run_with_threads(1);
        for threads in [2, 6] {
            let par = sim.run_with_threads(threads);
            assert_eq!(par.runs.len(), seq.runs.len());
            for (a, b) in par.runs.iter().zip(&seq.runs) {
                assert_eq!(a.total_requests, b.total_requests);
                assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
            }
            assert_eq!(
                par.avg_server_count_ci.0.to_bits(),
                seq.avg_server_count_ci.0.to_bits()
            );
            assert_eq!(par.sample_series.len(), seq.sample_series.len());
            for (sa, sb) in par.sample_series.iter().zip(&seq.sample_series) {
                assert_eq!(sa.len(), sb.len());
                for (ca, cb) in sa.iter().zip(sb) {
                    assert_eq!(ca.t.to_bits(), cb.t.to_bits());
                    assert_eq!(ca.cumulative_avg.to_bits(), cb.cumulative_avg.to_bits());
                }
            }
        }
    }

    #[test]
    fn single_replication_zero_ci() {
        let sim = ServerlessTemporalSimulator::new(cfg(1_000.0), InitialState::empty(), 1);
        let res = sim.run();
        assert_eq!(res.runs.len(), 1);
        assert_eq!(res.cold_start_prob_ci.1, 0.0);
    }

    #[test]
    fn running_initial_state_counts_in_flight() {
        let init = InitialState { idle_ages: vec![], running_remaining: vec![100.0, 100.0] };
        assert_eq!(init.total_instances(), 2);
        let sim = ServerlessTemporalSimulator::new(cfg(50.0), init, 2);
        let res = sim.run();
        // For the whole 50 s window those two instances are running.
        assert!(res.avg_running_count_ci.0 >= 2.0 * 0.9); // plus arrival traffic
    }
}
