//! Bench: regenerate Fig. 3 (instance-count distribution under the Table 1
//! workload) — the internal-state distribution invisible on real platforms.
#[path = "harness.rs"]
mod harness;

use simfaas::figures;

fn main() {
    harness::header(
        "Fig 3",
        "portion of simulated time spent at each total instance count",
        "unimodal distribution centered near 7-8 instances",
    );
    let horizon = if harness::quick() { 1e5 } else { 1e6 };
    let (_, pmf) = harness::bench("fig3/distribution", 3, || {
        figures::fig3_distribution(horizon, 0x5EED)
    });
    println!();
    println!("count  p");
    for (i, p) in pmf.iter().enumerate() {
        println!("{i:>5}  {p:.5} {}", "#".repeat((p * 200.0) as usize));
    }
    let mode = pmf
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let mean: f64 = pmf.iter().enumerate().map(|(i, p)| i as f64 * p).sum();
    println!("mode={mode} mean={mean:.3} (paper's Table 1 mean: 7.6795)");
}
