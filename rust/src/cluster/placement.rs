//! Pluggable invoker-selection schedulers.
//!
//! A [`Scheduler`] picks which [`Host`] receives a new container. All
//! implementations are deterministic (no RNG) and break ties toward the
//! lowest host index so cluster runs stay bit-reproducible. The
//! [`SchedulerSpec`] enum is the serializable handle used by scenarios
//! and the CLI; [`SchedulerSpec::build`] instantiates the boxed trait
//! object.

use super::host::Host;

/// Invoker-selection strategy: pick the host for a new container.
pub trait Scheduler {
    /// Index of a host where a container with the given footprint fits,
    /// or `None` when no host has room (a placement failure).
    fn select(&mut self, hosts: &[Host], memory_mb: f64, cpus: f64) -> Option<usize>;

    /// Stable human-readable name (used as the sweep label).
    fn name(&self) -> &'static str;
}

/// First host (lowest index) with room.
#[derive(Debug, Default, Clone, Copy)]
pub struct FirstFit;

impl Scheduler for FirstFit {
    fn select(&mut self, hosts: &[Host], memory_mb: f64, cpus: f64) -> Option<usize> {
        hosts.iter().position(|h| h.fits(memory_mb, cpus))
    }

    fn name(&self) -> &'static str {
        "first-fit"
    }
}

/// Fitting host with the most free memory (ties → lowest index).
/// Spreads load; the opposite of [`PackingAware`].
#[derive(Debug, Default, Clone, Copy)]
pub struct LeastLoaded;

impl Scheduler for LeastLoaded {
    fn select(&mut self, hosts: &[Host], memory_mb: f64, cpus: f64) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, h) in hosts.iter().enumerate() {
            if !h.fits(memory_mb, cpus) {
                continue;
            }
            let free = h.free_memory_mb();
            match best {
                Some((_, best_free)) if free <= best_free => {}
                _ => best = Some((i, free)),
            }
        }
        best.map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "least-loaded"
    }
}

/// Rotate through hosts, starting the scan after the previous pick.
#[derive(Debug, Default, Clone, Copy)]
pub struct RoundRobin {
    cursor: usize,
}

impl Scheduler for RoundRobin {
    fn select(&mut self, hosts: &[Host], memory_mb: f64, cpus: f64) -> Option<usize> {
        if hosts.is_empty() {
            return None;
        }
        for step in 0..hosts.len() {
            let i = (self.cursor + step) % hosts.len();
            if hosts[i].fits(memory_mb, cpus) {
                self.cursor = (i + 1) % hosts.len();
                return Some(i);
            }
        }
        None
    }

    fn name(&self) -> &'static str {
        "round-robin"
    }
}

/// Best-fit bin packing: the fitting host that would be left with the
/// least free memory (ties → lowest index). Consolidates containers onto
/// few hosts, keeping the rest drained for locality/power.
#[derive(Debug, Default, Clone, Copy)]
pub struct PackingAware;

impl Scheduler for PackingAware {
    fn select(&mut self, hosts: &[Host], memory_mb: f64, cpus: f64) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, h) in hosts.iter().enumerate() {
            if !h.fits(memory_mb, cpus) {
                continue;
            }
            let left = h.free_memory_mb() - memory_mb;
            match best {
                Some((_, best_left)) if left >= best_left => {}
                _ => best = Some((i, left)),
            }
        }
        best.map(|(i, _)| i)
    }

    fn name(&self) -> &'static str {
        "packing"
    }
}

/// Serializable scheduler selector for scenarios and the CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerSpec {
    /// First host with room ([`FirstFit`]).
    #[default]
    FirstFit,
    /// Most free memory ([`LeastLoaded`]).
    LeastLoaded,
    /// Rotating cursor ([`RoundRobin`]).
    RoundRobin,
    /// Best-fit bin packing ([`PackingAware`]).
    PackingAware,
}

impl SchedulerSpec {
    /// Every variant, in a stable sweep order.
    pub fn all() -> [SchedulerSpec; 4] {
        [
            SchedulerSpec::FirstFit,
            SchedulerSpec::LeastLoaded,
            SchedulerSpec::RoundRobin,
            SchedulerSpec::PackingAware,
        ]
    }

    /// Parse the CLI/JSON spelling (`first-fit`, `least-loaded`,
    /// `round-robin`, `packing`).
    pub fn parse(s: &str) -> Option<SchedulerSpec> {
        match s {
            "first-fit" => Some(SchedulerSpec::FirstFit),
            "least-loaded" => Some(SchedulerSpec::LeastLoaded),
            "round-robin" => Some(SchedulerSpec::RoundRobin),
            "packing" | "packing-aware" => Some(SchedulerSpec::PackingAware),
            _ => None,
        }
    }

    /// Canonical spelling (round-trips through [`parse`](Self::parse)).
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulerSpec::FirstFit => "first-fit",
            SchedulerSpec::LeastLoaded => "least-loaded",
            SchedulerSpec::RoundRobin => "round-robin",
            SchedulerSpec::PackingAware => "packing",
        }
    }

    /// Instantiate the scheduler this spec names.
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerSpec::FirstFit => Box::new(FirstFit),
            SchedulerSpec::LeastLoaded => Box::new(LeastLoaded),
            SchedulerSpec::RoundRobin => Box::new(RoundRobin::default()),
            SchedulerSpec::PackingAware => Box::new(PackingAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hosts_with_free(free: &[f64]) -> Vec<Host> {
        // Each host has 1000 MB capacity; pre-fill so `free[i]` remains.
        free.iter()
            .map(|&f| {
                let mut h = Host::new(1000.0, 1000.0);
                h.allocate(1000.0 - f, 0.0, 0.0);
                h
            })
            .collect()
    }

    #[test]
    fn first_fit_picks_lowest_fitting_index() {
        let hosts = hosts_with_free(&[10.0, 500.0, 900.0]);
        let mut s = FirstFit;
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(1));
        assert_eq!(s.select(&hosts, 5.0, 1.0), Some(0));
        assert_eq!(s.select(&hosts, 2000.0, 1.0), None);
    }

    #[test]
    fn least_loaded_picks_most_free_memory() {
        let hosts = hosts_with_free(&[10.0, 500.0, 900.0]);
        let mut s = LeastLoaded;
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(2));
    }

    #[test]
    fn least_loaded_tie_breaks_to_lowest_index() {
        let hosts = hosts_with_free(&[400.0, 400.0]);
        let mut s = LeastLoaded;
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(0));
    }

    #[test]
    fn round_robin_rotates_and_skips_full_hosts() {
        let hosts = hosts_with_free(&[500.0, 10.0, 500.0]);
        let mut s = RoundRobin::default();
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(0));
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(2), "skips full host 1");
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(0), "wraps around");
        assert_eq!(s.select(&hosts, 2000.0, 1.0), None);
    }

    #[test]
    fn packing_aware_picks_tightest_fit() {
        let hosts = hosts_with_free(&[900.0, 150.0, 500.0]);
        let mut s = PackingAware;
        assert_eq!(s.select(&hosts, 100.0, 1.0), Some(1));
    }

    #[test]
    fn spec_parse_round_trips() {
        for spec in SchedulerSpec::all() {
            assert_eq!(SchedulerSpec::parse(spec.as_str()), Some(spec));
            assert_eq!(spec.build().name(), spec.as_str());
        }
        assert_eq!(
            SchedulerSpec::parse("packing-aware"),
            Some(SchedulerSpec::PackingAware)
        );
        assert_eq!(SchedulerSpec::parse("random"), None);
    }

    #[test]
    fn schedulers_ignore_cordoned_hosts() {
        let mut hosts = hosts_with_free(&[900.0, 500.0]);
        hosts[0].set_cordoned(true);
        assert_eq!(FirstFit.select(&hosts, 100.0, 1.0), Some(1));
        assert_eq!(LeastLoaded.select(&hosts, 100.0, 1.0), Some(1));
        assert_eq!(PackingAware.select(&hosts, 100.0, 1.0), Some(1));
    }
}
