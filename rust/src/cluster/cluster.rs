//! Cluster configuration and runtime state: the cluster-gate that
//! replaces the flat fleet counter when hosts are configured.
//!
//! [`ClusterConfig`] is the declarative shape (scenario/CLI);
//! [`ClusterState`] is the runtime bookkeeping the fleet's
//! `LifecycleHooks` drive. The protocol mirrors `sim::core`'s cold-start
//! sequence exactly:
//!
//! 1. `admit_cold` → [`ClusterState::admit`] asks the scheduler for a
//!    host with room and parks it as *pending* (a failure counts as a
//!    placement failure and raises memory pressure);
//! 2. `on_cold_start` → [`ClusterState::commit`] charges the pending
//!    host and records the placement on the function's stack;
//! 3. `on_expire` → [`ClusterState::release`] frees the newest placement
//!    (or a pinned host's placement during forced eviction).
//!
//! Containers are fungible per function: hooks carry no instance
//! identity, so placements are tracked as per-function LIFO stacks of
//! host indices. Forced eviction (memory pressure, host drains) pins the
//! host to release so resources come off the right machine; which
//! *physical* idle container dies is decided by the engine's oldest-idle
//! order. This approximation keeps the hooks seam unchanged and the
//! no-cluster path bit-identical.

use super::host::Host;
use super::placement::{Scheduler, SchedulerSpec};

/// CPU cores charged per container. The paper's model is
/// memory-centric; a flat per-container core cost lets `host_cpus` act
/// as a per-host container cap without a second footprint column.
pub const CONTAINER_CPUS: f64 = 1.0;

/// A maintenance/failure window during which one host accepts no new
/// placements and its idle containers are evicted.
#[derive(Debug, Clone, PartialEq)]
pub struct HostDrain {
    /// Index of the host to drain.
    pub host: usize,
    /// Window start (seconds).
    pub start: f64,
    /// Window end (seconds, exclusive).
    pub end: f64,
}

/// Declarative cluster shape: homogeneous hosts plus a scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of invoker hosts.
    pub hosts: usize,
    /// Memory capacity per host (MB).
    pub host_memory_mb: f64,
    /// CPU capacity per host (cores); each container costs
    /// [`CONTAINER_CPUS`].
    pub host_cpus: f64,
    /// Invoker-selection strategy.
    pub scheduler: SchedulerSpec,
    /// Evict idle containers under memory pressure and on host drains
    /// (on by default; off leaves capacity emergent from expiry alone).
    pub eviction: bool,
    /// Host drain windows (maintenance / failure).
    pub drains: Vec<HostDrain>,
}

impl ClusterConfig {
    /// A cluster of `hosts` identical hosts with the default
    /// (first-fit) scheduler and eviction enabled.
    pub fn new(hosts: usize, host_memory_mb: f64, host_cpus: f64) -> ClusterConfig {
        ClusterConfig {
            hosts,
            host_memory_mb,
            host_cpus,
            scheduler: SchedulerSpec::default(),
            eviction: true,
            drains: Vec::new(),
        }
    }

    /// A cluster whose hosts have unbounded memory and CPU — placement
    /// always succeeds, so results must match the uncapped fleet.
    pub fn unbounded(hosts: usize) -> ClusterConfig {
        ClusterConfig::new(hosts, f64::INFINITY, f64::INFINITY)
    }

    /// Set the placement scheduler.
    pub fn with_scheduler(mut self, scheduler: SchedulerSpec) -> ClusterConfig {
        self.scheduler = scheduler;
        self
    }

    /// Enable/disable pressure + drain eviction.
    pub fn with_eviction(mut self, eviction: bool) -> ClusterConfig {
        self.eviction = eviction;
        self
    }

    /// Add a host drain window.
    pub fn with_drain(mut self, host: usize, start: f64, end: f64) -> ClusterConfig {
        self.drains.push(HostDrain { host, start, end });
        self
    }

    /// Check structural validity. Unbounded (infinite) capacities are
    /// allowed; zero or negative capacities are not — a zero-memory host
    /// could never place a container.
    pub fn validate(&self) -> Result<(), String> {
        if self.hosts == 0 {
            return Err("hosts must be at least 1".into());
        }
        if !(self.host_memory_mb > 0.0) {
            return Err(format!(
                "host_memory_mb must be positive (a zero-memory host cannot place any container), got {}",
                self.host_memory_mb
            ));
        }
        if !(self.host_cpus > 0.0) {
            return Err(format!("host_cpus must be positive, got {}", self.host_cpus));
        }
        for (i, d) in self.drains.iter().enumerate() {
            if d.host >= self.hosts {
                return Err(format!(
                    "drains[{i}].host {} out of range for {} hosts",
                    d.host, self.hosts
                ));
            }
            if !d.start.is_finite() || d.start < 0.0 {
                return Err(format!("drains[{i}].start must be finite and non-negative"));
            }
            if !d.end.is_finite() || d.end <= d.start {
                return Err(format!("drains[{i}].end must be finite and after start"));
            }
        }
        Ok(())
    }

    /// Report-line warnings for drain windows that cannot complete within
    /// the run horizon: a window still open at the horizon cordons its
    /// host for the rest of the run, silently leaking capacity. Not a
    /// [`validate`](Self::validate) error — such specs were always legal
    /// — but worth a line in the report.
    pub fn drain_horizon_warnings(&self, horizon: f64) -> Vec<String> {
        self.drains
            .iter()
            .enumerate()
            .filter(|(_, d)| d.start < horizon && d.end > horizon)
            .map(|(i, d)| {
                format!(
                    "warning: drains[{i}] on host {} ([{:.0}, {:.0}) s) never completes within the {:.0} s horizon; the cordoned host leaks capacity for the rest of the run",
                    d.host, d.start, d.end, horizon
                )
            })
            .collect()
    }
}

/// Per-run cluster report: placement failures, forced evictions, and
/// per-host time-averaged memory utilization.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClusterUsage {
    /// Placement attempts (cold starts and prewarms) no host could fit.
    pub placement_failures: u64,
    /// Idle containers force-evicted by pressure or drains.
    pub evictions: u64,
    /// Time-averaged memory utilization per host over the run.
    pub host_utilization: Vec<f64>,
}

/// Runtime cluster bookkeeping driven from the fleet's lifecycle hooks.
pub struct ClusterState {
    config: ClusterConfig,
    hosts: Vec<Host>,
    scheduler: Box<dyn Scheduler>,
    /// Per-function LIFO stacks of host indices (one entry per live
    /// container of that function).
    allocations: Vec<Vec<usize>>,
    /// Host chosen by the last successful [`admit`](Self::admit),
    /// consumed by [`commit`](Self::commit).
    pending: Option<usize>,
    /// During forced eviction: release placements from this host.
    pinned_release: Option<usize>,
    /// Hosts retired by an autoscaling controller: a permanent cordon
    /// that survives drain-window recomputation (parallel to `hosts`).
    retired: Vec<bool>,
    /// Memory footprint (MB) of the most recent failed placement;
    /// taken by the pressure-relief sweep.
    pressure: Option<f64>,
    now: f64,
    placement_failures: u64,
    gate_rejections: u64,
    evictions: u64,
}

impl ClusterState {
    /// Build the runtime state for `functions` functions.
    pub fn new(config: &ClusterConfig, functions: usize) -> ClusterState {
        ClusterState {
            hosts: (0..config.hosts)
                .map(|_| Host::new(config.host_memory_mb, config.host_cpus))
                .collect(),
            scheduler: config.scheduler.build(),
            allocations: vec![Vec::new(); functions],
            pending: None,
            pinned_release: None,
            retired: vec![false; config.hosts],
            pressure: None,
            now: 0.0,
            placement_failures: 0,
            gate_rejections: 0,
            evictions: 0,
            config: config.clone(),
        }
    }

    /// The configuration this state was built from.
    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// The hosts (for reporting).
    pub fn hosts(&self) -> &[Host] {
        &self.hosts
    }

    /// Placement attempts no host could fit.
    pub fn placement_failures(&self) -> u64 {
        self.placement_failures
    }

    /// Requests rejected solely by cluster capacity (feeds the fleet's
    /// `cap_rejections` aggregate).
    pub fn gate_rejections(&self) -> u64 {
        self.gate_rejections
    }

    /// Containers force-evicted so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Advance simulation time and recompute drain cordons. Returns the
    /// hosts that just became cordoned (their idle containers should be
    /// evicted). Windows that fall entirely between two events are
    /// never observed — deterministic, since event times are.
    pub fn advance_to(&mut self, now: f64) -> Vec<usize> {
        self.now = now;
        if self.config.drains.is_empty() {
            return Vec::new();
        }
        let mut newly = Vec::new();
        for host in 0..self.hosts.len() {
            // Controller retirement is a permanent cordon: OR it in so the
            // per-window recomputation cannot silently uncordon the host.
            let cordon = self.retired[host]
                || self
                    .config
                    .drains
                    .iter()
                    .any(|d| d.host == host && d.start <= now && now < d.end);
            if cordon && !self.hosts[host].is_cordoned() {
                newly.push(host);
            }
            self.hosts[host].set_cordoned(cordon);
        }
        newly
    }

    /// Advance the accounting clock without recomputing drain cordons.
    /// Control ticks use this: recomputing windows at tick times would
    /// move cordon boundaries off the event timeline and break the
    /// inert-controller bit-identity contract.
    pub fn set_now(&mut self, now: f64) {
        self.now = now;
    }

    /// Add one freshly provisioned host (controller scale-out). It joins
    /// warm and uncordoned; its time-averaged utilization integrates from
    /// zero over the pre-provisioning span, which slightly under-reports
    /// late-added hosts in [`usage`](Self::usage) — deterministic, and
    /// consistent with "the host did not exist yet".
    pub fn add_host(&mut self) {
        self.hosts
            .push(Host::new(self.config.host_memory_mb, self.config.host_cpus));
        self.retired.push(false);
    }

    /// Retire `host` (controller scale-in): a permanent cordon — no new
    /// placements; busy containers drain naturally through the same
    /// cordon/evict machinery as drain windows. Never un-retired.
    pub fn retire_host(&mut self, host: usize) {
        self.retired[host] = true;
        self.hosts[host].set_cordoned(true);
    }

    /// Retirement target for controller scale-in: the non-retired,
    /// non-cordoned host with the fewest containers (ties → highest
    /// index, so late-added hosts retire first). `None` when every host
    /// is already retired or cordoned.
    pub fn retire_target(&self) -> Option<usize> {
        let mut best: Option<(usize, u32)> = None;
        for (i, h) in self.hosts.iter().enumerate() {
            if self.retired[i] || h.is_cordoned() {
                continue;
            }
            match best {
                Some((_, count)) if count < h.containers() => {}
                _ => best = Some((i, h.containers())),
            }
        }
        best.map(|(i, _)| i)
    }

    /// Hosts not retired by the controller (the controller's capacity
    /// unit; drain-window cordons are temporary and still count).
    pub fn active_hosts(&self) -> u64 {
        self.retired.iter().filter(|&&r| !r).count() as u64
    }

    /// Instantaneous memory utilization across non-retired hosts — the
    /// cluster backend's observed control signal. 0 for an empty or
    /// unbounded cluster.
    pub fn memory_utilization(&self) -> f64 {
        let mut used = 0.0;
        let mut total = 0.0;
        for (host, &retired) in self.hosts.iter().zip(&self.retired) {
            if retired || !host.memory_mb().is_finite() {
                continue;
            }
            used += host.memory_mb() - host.free_memory_mb();
            total += host.memory_mb();
        }
        if total > 0.0 {
            (used / total).max(0.0)
        } else {
            0.0
        }
    }

    /// Ask the scheduler for a host with room for one container of
    /// `memory_mb`. On success the host is parked as pending for
    /// [`commit`](Self::commit); on failure the placement failure is
    /// counted and memory pressure is raised.
    pub fn admit(&mut self, memory_mb: f64) -> bool {
        match self
            .scheduler
            .select(&self.hosts, memory_mb, CONTAINER_CPUS)
        {
            Some(host) => {
                self.pending = Some(host);
                true
            }
            None => {
                self.pending = None;
                self.placement_failures += 1;
                self.pressure = Some(memory_mb);
                false
            }
        }
    }

    /// Charge the pending host for `func`'s new container. Must follow
    /// a successful [`admit`](Self::admit) (the core calls `admit_cold`
    /// immediately before every `on_cold_start`).
    pub fn commit(&mut self, func: u32, memory_mb: f64) {
        let host = self
            .pending
            .take()
            .expect("cluster commit without a prior successful admit");
        self.hosts[host].allocate(memory_mb, CONTAINER_CPUS, self.now);
        self.allocations[func as usize].push(host);
    }

    /// Release one of `func`'s containers: the newest placement, or —
    /// during forced eviction — the newest placement on the pinned host.
    pub fn release(&mut self, func: u32, memory_mb: f64) {
        let stack = &mut self.allocations[func as usize];
        let host = match self.pinned_release {
            Some(pin) => match stack.iter().rposition(|&h| h == pin) {
                Some(pos) => {
                    self.evictions += 1;
                    Some(stack.remove(pos))
                }
                None => stack.pop(),
            },
            None => stack.pop(),
        };
        if let Some(host) = host {
            self.hosts[host].release(memory_mb, CONTAINER_CPUS, self.now);
        }
    }

    /// Count a request rejected solely by cluster capacity.
    pub fn gate_reject(&mut self) {
        self.gate_rejections += 1;
    }

    /// Pin forced releases to `host` (drain / pressure eviction).
    pub fn pin_release(&mut self, host: usize) {
        self.pinned_release = Some(host);
    }

    /// Clear the forced-release pin.
    pub fn clear_pin(&mut self) {
        self.pinned_release = None;
    }

    /// Take the pending memory-pressure signal, if any.
    pub fn take_pressure(&mut self) -> Option<f64> {
        self.pressure.take()
    }

    /// Functions with at least one container on `host`, ascending.
    pub fn functions_on(&self, host: usize) -> Vec<u32> {
        self.allocations
            .iter()
            .enumerate()
            .filter(|(_, stack)| stack.contains(&host))
            .map(|(f, _)| f as u32)
            .collect()
    }

    /// Whether `host` currently fits one container of `memory_mb`.
    pub fn host_fits(&self, host: usize, memory_mb: f64) -> bool {
        self.hosts[host].fits(memory_mb, CONTAINER_CPUS)
    }

    /// Whether any host currently fits one container of `memory_mb`.
    pub fn any_host_fits(&self, memory_mb: f64) -> bool {
        self.hosts.iter().any(|h| h.fits(memory_mb, CONTAINER_CPUS))
    }

    /// Eviction target for pressure relief: the non-cordoned host with
    /// containers to evict and the most free memory (ties → lowest
    /// index), i.e. the host closest to fitting the failed placement.
    pub fn pressure_target(&self) -> Option<usize> {
        let mut best: Option<(usize, f64)> = None;
        for (i, h) in self.hosts.iter().enumerate() {
            if h.is_cordoned() || h.containers() == 0 {
                continue;
            }
            let free = h.free_memory_mb();
            match best {
                Some((_, best_free)) if free <= best_free => {}
                _ => best = Some((i, free)),
            }
        }
        best.map(|(i, _)| i)
    }

    /// Total free memory across hosts, saturated to `u64::MAX` for
    /// unbounded hosts — exported through the telemetry `cap_headroom`
    /// channel.
    pub fn headroom(&self) -> u64 {
        let free: f64 = self.hosts.iter().map(Host::free_memory_mb).sum();
        if free.is_finite() {
            free.max(0.0) as u64
        } else {
            u64::MAX
        }
    }

    /// Finalize host accounting at `horizon` and report usage.
    pub fn usage(&mut self, horizon: f64) -> ClusterUsage {
        for h in &mut self.hosts {
            h.advance(horizon);
        }
        ClusterUsage {
            placement_failures: self.placement_failures,
            evictions: self.evictions,
            host_utilization: self
                .hosts
                .iter()
                .map(|h| h.time_avg_memory_utilization(horizon))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validate_rejects_bad_shapes() {
        assert!(ClusterConfig::new(0, 1024.0, 4.0).validate().is_err());
        let zero_mem = ClusterConfig::new(2, 0.0, 4.0).validate().unwrap_err();
        assert!(zero_mem.contains("zero-memory"), "{zero_mem}");
        assert!(ClusterConfig::new(2, 1024.0, 0.0).validate().is_err());
        assert!(ClusterConfig::new(2, 1024.0, 4.0)
            .with_drain(5, 0.0, 10.0)
            .validate()
            .is_err());
        assert!(ClusterConfig::new(2, 1024.0, 4.0)
            .with_drain(1, 10.0, 10.0)
            .validate()
            .is_err());
        assert!(ClusterConfig::new(2, 1024.0, 4.0)
            .with_drain(1, 10.0, 20.0)
            .validate()
            .is_ok());
        assert!(ClusterConfig::unbounded(1).validate().is_ok());
    }

    #[test]
    fn admit_commit_release_cycle_tracks_capacity() {
        let cfg = ClusterConfig::new(1, 256.0, 32.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.admit(128.0));
        st.commit(0, 128.0);
        assert!(st.admit(128.0));
        st.commit(0, 128.0);
        assert!(!st.admit(64.0), "host full");
        assert_eq!(st.placement_failures(), 1);
        assert_eq!(st.take_pressure(), Some(64.0));
        assert_eq!(st.take_pressure(), None, "pressure is taken once");
        st.release(0, 128.0);
        assert!(st.admit(64.0));
        st.commit(0, 64.0);
        assert_eq!(st.headroom(), 64);
    }

    #[test]
    fn pinned_release_frees_the_pinned_host() {
        // Two containers of func 0: one on each host (first-fit packs
        // host 0 first, so size them to force the spill).
        let cfg = ClusterConfig::new(2, 128.0, 32.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.admit(128.0));
        st.commit(0, 128.0); // host 0
        assert!(st.admit(128.0));
        st.commit(0, 128.0); // host 1
        assert_eq!(st.functions_on(0), vec![0]);
        assert_eq!(st.functions_on(1), vec![0]);

        st.pin_release(0);
        st.release(0, 128.0);
        st.clear_pin();
        assert_eq!(st.evictions(), 1);
        assert!(st.host_fits(0, 128.0), "pinned host 0 was freed");
        assert!(!st.host_fits(1, 128.0), "host 1 untouched");
    }

    #[test]
    fn unpinned_release_pops_newest_placement() {
        let cfg = ClusterConfig::new(2, 128.0, 32.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.admit(128.0));
        st.commit(0, 128.0); // host 0
        assert!(st.admit(128.0));
        st.commit(0, 128.0); // host 1 (newest)
        st.release(0, 128.0);
        assert!(st.host_fits(1, 128.0), "newest placement (host 1) freed");
        assert!(!st.host_fits(0, 128.0));
    }

    #[test]
    fn drain_windows_cordon_and_uncordon() {
        let cfg = ClusterConfig::new(2, 1024.0, 32.0).with_drain(0, 10.0, 20.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.advance_to(5.0).is_empty());
        assert_eq!(st.advance_to(10.0), vec![0], "window opens");
        assert!(st.hosts()[0].is_cordoned());
        assert!(st.advance_to(15.0).is_empty(), "already cordoned");
        assert!(st.advance_to(25.0).is_empty(), "window closed");
        assert!(!st.hosts()[0].is_cordoned());
    }

    #[test]
    fn pressure_target_prefers_freest_busy_host() {
        let cfg = ClusterConfig::new(3, 1024.0, 32.0);
        let mut st = ClusterState::new(&cfg, 2);
        // host 0: two containers (first-fit), host 1: none, host 2: none.
        assert!(st.admit(512.0));
        st.commit(0, 512.0);
        assert!(st.admit(256.0));
        st.commit(1, 256.0);
        // Only host 0 has containers, so it is the only candidate.
        assert_eq!(st.pressure_target(), Some(0));
        assert_eq!(st.functions_on(0), vec![0, 1]);
    }

    #[test]
    fn drain_horizon_warnings_flag_unfinished_windows() {
        let cfg = ClusterConfig::new(2, 1024.0, 32.0)
            .with_drain(0, 10.0, 20.0)
            .with_drain(1, 50.0, 500.0);
        assert!(cfg.drain_horizon_warnings(1000.0).is_empty());
        let warns = cfg.drain_horizon_warnings(100.0);
        assert_eq!(warns.len(), 1);
        assert!(warns[0].contains("drains[1]") && warns[0].contains("host 1"), "{}", warns[0]);
        // A window entirely after the horizon never opens, so it cannot
        // leak a cordon — no warning.
        assert!(cfg.drain_horizon_warnings(40.0).is_empty());
    }

    #[test]
    fn retirement_is_a_permanent_cordon() {
        let cfg = ClusterConfig::new(2, 1024.0, 32.0).with_drain(0, 10.0, 20.0);
        let mut st = ClusterState::new(&cfg, 1);
        st.retire_host(1);
        assert!(st.hosts()[1].is_cordoned());
        assert_eq!(st.active_hosts(), 1);
        // The drain-window recomputation must not uncordon host 1.
        st.advance_to(15.0);
        assert!(st.hosts()[1].is_cordoned());
        st.advance_to(25.0);
        assert!(st.hosts()[1].is_cordoned(), "retired survives window close");
        assert!(!st.hosts()[0].is_cordoned(), "drain window did close");
    }

    #[test]
    fn added_hosts_accept_placements_and_retire_targets_prefer_idle() {
        let cfg = ClusterConfig::new(1, 128.0, 32.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.admit(128.0));
        st.commit(0, 128.0);
        assert!(!st.admit(64.0), "single host is full");
        st.take_pressure();
        st.add_host();
        assert_eq!(st.active_hosts(), 2);
        assert!(st.admit(64.0), "new host has room");
        st.commit(0, 64.0);
        // Fewest containers wins; ties go to the highest index.
        assert_eq!(st.retire_target(), Some(1));
        st.add_host();
        assert_eq!(st.retire_target(), Some(2), "empty late host preferred");
        st.retire_host(2);
        assert_eq!(st.retire_target(), Some(1));
        st.retire_host(1);
        st.retire_host(0);
        assert_eq!(st.retire_target(), None);
        assert_eq!(st.active_hosts(), 0);
    }

    #[test]
    fn memory_utilization_skips_retired_hosts() {
        let cfg = ClusterConfig::new(2, 128.0, 32.0);
        let mut st = ClusterState::new(&cfg, 1);
        assert!(st.admit(128.0));
        st.commit(0, 128.0); // host 0 full
        assert!((st.memory_utilization() - 0.5).abs() < 1e-12);
        st.retire_host(1);
        assert!((st.memory_utilization() - 1.0).abs() < 1e-12);
        assert_eq!(ClusterState::new(&ClusterConfig::unbounded(2), 1).memory_utilization(), 0.0);
    }

    #[test]
    fn unbounded_cluster_always_admits() {
        let cfg = ClusterConfig::unbounded(1);
        let mut st = ClusterState::new(&cfg, 1);
        for _ in 0..1000 {
            assert!(st.admit(512.0));
            st.commit(0, 512.0);
        }
        assert_eq!(st.placement_failures(), 0);
        assert_eq!(st.headroom(), u64::MAX);
        let usage = st.usage(100.0);
        assert_eq!(usage.host_utilization, vec![0.0]);
    }
}
