//! API-compatible stand-ins for the PJRT runtime when the `pjrt` feature
//! (and the vendored `xla` bindings it needs) is not compiled in.
//!
//! Construction fails with a clear error at *runtime*; every consumer (the
//! emulator, benches, examples, the CLI) keeps *compiling*. Consumers that
//! treat PJRT as optional degrade gracefully — `benches/engine_throughput.rs`
//! prints "(pjrt benches skipped: ...)" and moves on, the emulator runs with
//! synthetic service times — while PJRT-dependent entry points
//! (`examples/validate_end_to_end.rs`) exit early with this error.
//!
//! Note the `pjrt` feature itself only builds on a host that also provides
//! the vendored `xla` bindings as a crate; the dependency is deliberately
//! not declared in Cargo.toml so the default build works offline.

use super::payload::PayloadKind;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const UNAVAILABLE: &str = "PJRT support not compiled in: requires the `pjrt` feature and a host \
     providing the vendored `xla` bindings (add the dependency in rust/Cargo.toml there)";

/// Stand-in for the PJRT engine; [`Engine::load_dir`] always fails.
pub struct Engine {
    dir: PathBuf,
}

impl Engine {
    pub fn load_dir<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let _ = dir.as_ref();
        bail!("{UNAVAILABLE}")
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }

    pub fn run_payload(&self, _kind: PayloadKind, _x: &[f32]) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run_histogram_block(&self, _samples: &[f32], _lo: f32, _hi: f32) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run_histogram(&self, _samples: &[f32], _lo: f32, _hi: f32) -> Result<Vec<f64>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stand-in for the PJRT worker pool; [`ComputePool::new`] always fails.
pub struct ComputePool {
    n_workers: usize,
}

impl ComputePool {
    pub fn new<P: Into<PathBuf>>(dir: P, n_workers: usize) -> Result<Self> {
        let _: PathBuf = dir.into();
        let _ = n_workers;
        bail!("{UNAVAILABLE}")
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    pub fn run_payload(&self, _kind: PayloadKind, _x: Vec<f32>) -> Result<Vec<f32>> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run_histogram(&self, _samples: Vec<f32>, _lo: f32, _hi: f32) -> Result<Vec<f64>> {
        bail!("{UNAVAILABLE}")
    }
}
